"""Multi-device checks in a subprocess (8 fake CPU devices), so the rest
of the suite keeps the default single device."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_distributed_checks():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "dist_checks.py")],
        capture_output=True, text=True, timeout=900, env=env)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed checks failed"
    assert "ALL DISTRIBUTED CHECKS PASSED" in proc.stdout
