"""Serving engine + disaggregated KV store."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.params import init_params
from repro.serve.disagg import DisaggKV, KVStoreParams
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_all_requests(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, slots=3, max_len=64, impl="ref")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=5) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done and len(r.out_tokens) == 5 for r in reqs)


def test_engine_greedy_matches_offline(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref")
    rng = np.random.default_rng(1)
    r = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4)
    eng.submit(r)
    eng.run()
    full = jnp.asarray(np.concatenate([r.prompt, np.asarray(r.out_tokens[:-1], np.int32)]))[None]
    res = M.forward(cfg, params, full, impl="ref", remat="none")
    nxt = int(jnp.argmax(M.logits_for(cfg, params, res.hidden[:, -1:])[0, 0]))
    assert nxt == r.out_tokens[-1]


def test_engine_mixed_lengths(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, slots=4, max_len=64, impl="ref")
    rng = np.random.default_rng(2)
    reqs = []
    for i, (plen, new) in enumerate([(4, 3), (12, 6), (8, 2), (16, 4), (6, 5)]):
        r = Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=new)
        reqs.append(r)
        eng.submit(r)
    eng.run()
    for r, (_, new) in zip(reqs, [(4, 3), (12, 6), (8, 2), (16, 4), (6, 5)]):
        assert r.done and len(r.out_tokens) == new


def test_engine_run_returns_completed_requests(small_lm):
    """Regression: run() used to always return [] — it must hand back
    every request retired during the call, in retirement order."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref")
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(r.done for r in done)
    # a second run with nothing queued completes nothing new
    assert eng.run() == []
    # late submissions are returned by the call that retires them
    late = Request(rid=99, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   max_new_tokens=2)
    eng.submit(late)
    assert [r.rid for r in eng.run()] == [99]


def test_engine_fabric_placement(small_lm):
    """§5.2 wired into serving: the engine consults the fabric router
    for the decode cache placement."""
    cfg, params = small_lm
    kv = DisaggKV(KVStoreParams(n_keys=10_000, soc_cache_keys=1_000))
    eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                      fabric=kv.fabric(), cache_hit_mass=kv.cache_hit_mass())
    assert eng.placement is not None
    assert eng.placement.location == "soc_cache"
    assert eng.placement.rate > eng.placement.baseline_rate
    # without a fabric there is no placement plan
    eng2 = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref")
    assert eng2.placement is None


def test_disagg_data_plane_correct():
    kv = DisaggKV(KVStoreParams(n_keys=5000, soc_cache_keys=500))
    rng = np.random.default_rng(0)
    for alt in ["A1", "A2", "A3", "A4", "A5"]:
        for k in rng.integers(0, 5000, 50):
            v, lat = kv.get(int(k), alt)
            assert (v == kv.values[int(k)]).all()
            assert 0 < lat < 1e-4


def test_disagg_latency_ordering():
    kv = DisaggKV(KVStoreParams(n_keys=5000, soc_cache_keys=5000))  # all cached
    _, l5 = kv.get(1, "A5")
    _, l4 = kv.get(1, "A4")
    _, l1 = kv.get(1, "A1")
    _, l2 = kv.get(1, "A2")
    assert l5 < l4 < l1 < l2   # Fig 17(a)


def test_disagg_combined_beats_components():
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    paths, alts = kv.fabric(), kv.alternatives()
    total, allocs = kv.combined_a4_a5()
    assert total > alts["A4"].solo_rate(paths)
    assert sum(a.rate for a in allocs) == pytest.approx(total)
