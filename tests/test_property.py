"""Property-based tests (hypothesis) on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (ErrorFeedback, compress_with_feedback,
                                    dequantize_int8_blockwise,
                                    quantize_int8_blockwise)
from repro.core.fabric import Alternative, Fabric, Path, Use
from repro.core.paths import collective_bytes_per_chip

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 2000), st.integers(3, 9), st.floats(0.1, 100.0))
def test_quant_roundtrip_error_bound(n, logblock, scale):
    """|deq(q(x)) - x| <= half a quantization step, per block."""
    block = 2 ** logblock
    x = np.random.RandomState(n).randn(n).astype(np.float32) * scale
    qt = quantize_int8_blockwise(jnp.asarray(x), block)
    back = np.asarray(dequantize_int8_blockwise(qt, (n,)))
    step = np.repeat(np.asarray(qt.scale), block)[:n]
    assert (np.abs(back - x) <= step * 0.5 + 1e-6).all()


@given(st.integers(2, 6), st.integers(1, 64))
def test_error_feedback_is_unbiased_over_time(steps, n):
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.RandomState(steps * 100 + n)
    ef = ErrorFeedback.init((n,))
    total_true = np.zeros(n, np.float32)
    total_sent = np.zeros(n, np.float32)
    for _ in range(steps):
        g = jnp.asarray(rng.randn(n).astype(np.float32))
        qt, ef = compress_with_feedback(g, ef, block=16)
        total_true += np.asarray(g)
        total_sent += np.asarray(dequantize_int8_blockwise(qt, (n,)))
    resid = np.asarray(ef.residual)
    assert np.allclose(total_sent + resid, total_true, atol=1e-4)


@given(st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all"]),
       st.integers(2, 64), st.integers(1, 10**9))
def test_collective_traffic_monotone_in_group(op, n, nbytes):
    a = collective_bytes_per_chip(op, nbytes, n)
    b = collective_bytes_per_chip(op, nbytes, n + 1)
    assert 0 <= a <= b or op == "all-reduce" and a <= b
    assert collective_bytes_per_chip(op, nbytes, 1) == 0.0


def _mk_fabric(bw1, bw2):
    return Fabric.of(Path("p1", bw1, kind="ici", shared_group="g1"),
                     Path("p2", bw2, kind="ici", shared_group="g2"))


@given(st.floats(1.0, 1e3), st.floats(1.0, 1e3),
       st.floats(0.1, 4.0), st.floats(0.1, 4.0))
def test_greedy_combine_bounded_by_solo_sum(bw1, bw2, u1, u2):
    """Combined rate never exceeds the sum of solo rates, and never
    falls below the best solo rate (greedy picks it first)."""
    fabric = _mk_fabric(bw1, bw2)
    a = Alternative("a", uses=[Use("p1", out=u1)])
    b = Alternative("b", uses=[Use("p2", out=u2)])
    router = fabric.router()
    ranked = router.rank([a, b])
    _, total = router.allocate(ranked)
    solos = [a.solo_rate(fabric), b.solo_rate(fabric)]
    assert total <= sum(solos) + 1e-6
    assert total >= max(solos) - 1e-6


@given(st.floats(1.0, 1e3), st.floats(0.1, 4.0), st.integers(1, 4))
def test_shared_path_conserves_budget(bw, use, nalts):
    """N alternatives on one shared path: allocations sum to <= budget."""
    fabric = _mk_fabric(bw, bw)
    alts = [Alternative(f"a{i}", uses=[Use("p1", out=use)])
            for i in range(nalts)]
    allocs, total = fabric.router().allocate(alts)
    spent = sum(al.rate * use for al in allocs)
    assert spent <= bw * (1 + 1e-9)


@given(st.floats(1.0, 1e3), st.integers(1, 5), st.floats(0.0, 0.3))
def test_runtime_transfers_conserve_ledger(bw, n, disc):
    """N concurrent transfers on one discounted path: all finish, the
    ledger returns to zero, and the makespan is bracketed by the
    undiscounted and fully-discounted aggregate rates."""
    from repro.core.runtime import FabricRuntime
    fabric = Fabric.of(Path("p", bw), concurrency_discount=disc)
    rt = FabricRuntime(fabric)
    trs = [rt.transfer("p", 10.0 * (i + 1)) for i in range(n)]
    rt.clock.run()
    assert all(t.done for t in trs)
    assert rt.ledger.reserved("p", "out") == pytest.approx(0.0, abs=1e-9)
    total = sum(t.amount for t in trs)
    assert rt.clock.now >= total / bw * (1 - 1e-9)
    assert rt.clock.now <= total / (bw * (1.0 - disc)) * (1 + 1e-9)


@given(st.integers(0, 10_000), st.integers(0, 10_000))
def test_pipeline_statelessness(s1, s2):
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    cfg = get_config("internlm2-1.8b").reduced()
    pipe = TokenPipeline(cfg, ShapeConfig("t", 16, 2, "train"), seed=0)
    a, b = pipe.batch_at(s1), pipe.batch_at(s1)
    assert np.array_equal(a["tokens"], b["tokens"])
    if s1 != s2:
        c = pipe.batch_at(s2)
        assert not np.array_equal(a["tokens"], c["tokens"])


@given(st.integers(1, 512))
def test_elastic_mesh_never_exceeds_devices(n):
    from repro.ft.elastic import best_mesh_for
    shape, names = best_mesh_for(n, model=16)
    assert int(np.prod(shape)) <= n
    assert len(shape) == len(names)
