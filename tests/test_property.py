"""Property-based tests (hypothesis) on system invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compression import (ErrorFeedback, compress_with_feedback,
                                    dequantize_int8_blockwise,
                                    quantize_int8_blockwise)
from repro.core.paths import collective_bytes_per_chip
from repro.core.planner import Alternative, PathPlanner, PathUse
from repro.core.paths import PathSpec

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 2000), st.integers(3, 9), st.floats(0.1, 100.0))
def test_quant_roundtrip_error_bound(n, logblock, scale):
    """|deq(q(x)) - x| <= half a quantization step, per block."""
    block = 2 ** logblock
    x = np.random.RandomState(n).randn(n).astype(np.float32) * scale
    qt = quantize_int8_blockwise(jnp.asarray(x), block)
    back = np.asarray(dequantize_int8_blockwise(qt, (n,)))
    step = np.repeat(np.asarray(qt.scale), block)[:n]
    assert (np.abs(back - x) <= step * 0.5 + 1e-6).all()


@given(st.integers(2, 6), st.integers(1, 64))
def test_error_feedback_is_unbiased_over_time(steps, n):
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.RandomState(steps * 100 + n)
    ef = ErrorFeedback.init((n,))
    total_true = np.zeros(n, np.float32)
    total_sent = np.zeros(n, np.float32)
    for _ in range(steps):
        g = jnp.asarray(rng.randn(n).astype(np.float32))
        qt, ef = compress_with_feedback(g, ef, block=16)
        total_true += np.asarray(g)
        total_sent += np.asarray(dequantize_int8_blockwise(qt, (n,)))
    resid = np.asarray(ef.residual)
    assert np.allclose(total_sent + resid, total_true, atol=1e-4)


@given(st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all"]),
       st.integers(2, 64), st.integers(1, 10**9))
def test_collective_traffic_monotone_in_group(op, n, nbytes):
    a = collective_bytes_per_chip(op, nbytes, n)
    b = collective_bytes_per_chip(op, nbytes, n + 1)
    assert 0 <= a <= b or op == "all-reduce" and a <= b
    assert collective_bytes_per_chip(op, nbytes, 1) == 0.0


def _mk_paths(bw1, bw2):
    return {
        "p1": PathSpec("p1", "ici", None, 2, bw1, 0, True, "g1"),
        "p2": PathSpec("p2", "ici", None, 2, bw2, 0, True, "g2"),
    }


@given(st.floats(1.0, 1e3), st.floats(1.0, 1e3),
       st.floats(0.1, 4.0), st.floats(0.1, 4.0))
def test_greedy_combine_bounded_by_solo_sum(bw1, bw2, u1, u2):
    """Combined rate never exceeds the sum of solo rates, and never
    falls below the best solo rate (greedy picks it first)."""
    paths = _mk_paths(bw1, bw2)
    a = Alternative("a", uses=[PathUse("p1", out_bytes=u1)])
    b = Alternative("b", uses=[PathUse("p2", out_bytes=u2)])
    pl = PathPlanner(paths)
    ranked = pl.rank([a, b])
    _, total = pl.combine_greedy(ranked)
    solos = [a.solo_rate(paths), b.solo_rate(paths)]
    assert total <= sum(solos) + 1e-6
    assert total >= max(solos) - 1e-6


@given(st.floats(1.0, 1e3), st.floats(0.1, 4.0), st.integers(1, 4))
def test_shared_path_conserves_budget(bw, use, nalts):
    """N alternatives on one shared path: allocations sum to <= budget."""
    paths = _mk_paths(bw, bw)
    alts = [Alternative(f"a{i}", uses=[PathUse("p1", out_bytes=use)])
            for i in range(nalts)]
    pl = PathPlanner(paths)
    allocs, total = pl.combine_greedy(alts)
    spent = sum(al.rate * use for al in allocs)
    assert spent <= bw * (1 + 1e-9)


@given(st.integers(0, 10_000), st.integers(0, 10_000))
def test_pipeline_statelessness(s1, s2):
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    cfg = get_config("internlm2-1.8b").reduced()
    pipe = TokenPipeline(cfg, ShapeConfig("t", 16, 2, "train"), seed=0)
    a, b = pipe.batch_at(s1), pipe.batch_at(s1)
    assert np.array_equal(a["tokens"], b["tokens"])
    if s1 != s2:
        c = pipe.batch_at(s2)
        assert not np.array_equal(a["tokens"], c["tokens"])


@given(st.integers(1, 512))
def test_elastic_mesh_never_exceeds_devices(n):
    from repro.ft.elastic import best_mesh_for
    shape, names = best_mesh_for(n, model=16)
    assert int(np.prod(shape)) <= n
    assert len(shape) == len(names)
