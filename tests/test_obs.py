"""Fabric observability (PR 10): per-flow tracing, metrics, export.

The load-bearing assertions:
  (a) transfer spans close with exact step-function rate timelines —
      the integral of a span's timeline is *exactly* the units moved;
  (b) every rate annotation agrees with the BudgetLedger reservation
      for that transfer to the digit (the hook fires after ``t.rate``
      and ``t._res`` are set from the same rebalance);
  (c) tracing is record-only: simulated results are bit-identical with
      the tracer on vs off;
  (d) the Chrome-trace export is schema-valid and carries one process
      per tenant;
  (e) the tracer's busy-fraction attribution agrees with the sampled
      ``InterferenceReport`` occupancy on the real colocation scenario;
  (f) weighted bucket plans from the real parameter tree sum exactly.
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.core.fabric import Fabric, IN, OUT, Path
from repro.core.runtime import FabricRuntime
from repro.obs.export import (chrome_trace, dump, summary,
                              validate_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               OccupancyTimeSeries)
from repro.obs.trace import (BARRIER, COMPUTE, NULL_TRACER, PHASE, PROCESS,
                             TRANSFER, NullTracer, Tracer)


# ----------------------------------------------------------------------
# shared scenario: staggered transfers that rebalance mid-flight
# ----------------------------------------------------------------------

CAP, DISC = 100.0, 0.125


def _staggered(tracer=None):
    """t0: A starts solo; t=0.5: B joins (both drop to the discounted
    share); A finishes first and B speeds back up — two rebalances."""
    fabric = Fabric.of(Path("link", CAP), concurrency_discount=DISC)
    rt = FabricRuntime(fabric, tracer=tracer)
    a = rt.transfer("link", 60.0, flow="a", tenant="t0")
    b = []
    rt.clock.schedule(0.5, lambda: b.append(
        rt.transfer("link", 40.0, flow="b", tenant="t1")))
    rt.clock.run()
    return rt, a, b[0]


def test_transfer_spans_close_with_exact_rate_timelines():
    tracer = Tracer()
    rt, a, b = _staggered(tracer)
    spans = [s for s in tracer.spans if s.kind == TRANSFER]
    assert len(spans) == 2
    for s in spans:
        assert s.closed and s.t_end > s.t_start
        assert s.path == "link" and s.direction == OUT
        # a closed step function: starts at the initial rate, ends at 0
        assert s.rate_timeline[0][0] == s.t_start
        assert s.rate_timeline[0][1] > 0.0
        assert s.rate_timeline[-1] == (s.t_end, 0.0)
    by_flow = {s.flow: s for s in spans}
    # the integral of the rate timeline is exactly the units moved
    assert by_flow["a"].busy_units() == pytest.approx(60.0, rel=1e-12)
    assert by_flow["b"].busy_units() == pytest.approx(40.0, rel=1e-12)
    assert by_flow["a"].tenant == "t0" and by_flow["b"].tenant == "t1"
    # B saw the join (discounted share) and A's departure (solo rate)
    rates = [r for _, r in by_flow["b"].rate_timeline]
    assert CAP * (1 - DISC) / 2 in rates           # 43.75, shared
    assert CAP in rates                            # solo again


def test_rate_annotations_match_ledger_reservations_to_the_digit():
    tracer = Tracer()
    fabric = Fabric.of(Path("link", CAP), concurrency_discount=DISC)
    rt = FabricRuntime(fabric, tracer=tracer)
    ts = [rt.transfer("link", 100.0, flow=f"f{i}", tenant=f"t{i % 2}")
          for i in range(3)]
    rt.clock.schedule(0.7, lambda: rt.transfer("link", 50.0, flow="late"))
    probes = []

    def probe():
        now = rt.clock.now
        open_spans = [s for s in tracer.open_spans() if s.kind == TRANSFER]
        probes.append((now,
                       {s.flow: s.rate_at(now) for s in open_spans},
                       rt.ledger.reserved("link", OUT)))
        # per-transfer: the span's current rate IS the reservation
        by_flow = {t.flow: t for t in ts}
        for s in open_spans:
            t = by_flow.get(s.flow)
            if t is not None:
                assert s.rate_at(now) == t._res          # exact, not approx

    for at in (0.3, 0.9, 1.5):
        rt.clock.schedule(at, probe)
    rt.clock.run()
    assert len(probes) == 3
    for now, rates, reserved in probes:
        assert rates, f"no open spans at t={now}"
        # aggregate: annotated rates sum to the ledger's reservation
        assert math.fsum(rates.values()) == pytest.approx(reserved,
                                                          rel=1e-12)


def test_simulated_results_bit_identical_tracer_on_vs_off():
    rt_off, a_off, b_off = _staggered()             # NULL_TRACER default
    rt_on, a_on, b_on = _staggered(Tracer())
    assert rt_off.tracer is NULL_TRACER and not rt_off._trace
    assert a_on.finished_at == a_off.finished_at    # bit-identical
    assert b_on.finished_at == b_off.finished_at
    assert rt_on.clock.now == rt_off.clock.now
    assert rt_on.clock.processed == rt_off.clock.processed


def test_null_tracer_records_nothing_and_reads_empty():
    rt, _, _ = _staggered(NullTracer())
    assert rt.tracer.spans == () and not rt._trace
    assert rt.tracer.open_spans() == []
    assert rt.tracer.busy_units() == {}
    assert rt.tracer.busy_fraction() == {}
    with rt.tracer.phase("nope") as span:
        assert span is None


def test_phase_nesting_parent_links_and_closure():
    tracer = Tracer()
    fabric = Fabric.of(Path("p", 10.0))
    FabricRuntime(fabric, tracer=tracer)            # attaches the clock
    with tracer.phase("outer", tenant="t0") as outer:
        with tracer.phase("inner") as inner:
            assert inner.parent is outer
            assert not inner.closed
        assert inner.closed and not outer.closed
    assert outer.closed
    # explicit begin/end pairs close too, and merge end-time meta
    span = tracer.begin_phase("manual", step=3)
    assert span in tracer.open_spans()
    tracer.end_phase(span, aborted=True)
    assert span.closed and span.meta["step"] == 3 and span.meta["aborted"]
    tracer.end_phase(None)                          # no-op, never raises


def test_barrier_and_process_spans():
    tracer = Tracer()
    fabric = Fabric.of(Path("p", 10.0))
    rt = FabricRuntime(fabric, tracer=tracer)
    bar = rt.barrier(2, name="sync")

    def worker(delay):
        yield delay
        yield bar.arrive()

    rt.process(worker(0.25), name="w0")
    rt.process(worker(0.5), name="w1")
    rt.clock.run()
    bspans = [s for s in tracer.spans if s.kind == BARRIER]
    assert len(bspans) == 1 and bspans[0].t_start == 0.5
    assert bspans[0].meta["parties"] == 2
    pspans = [s for s in tracer.spans if s.kind == PROCESS]
    assert {s.name for s in pspans} == {"w0", "w1"}
    assert all(s.closed and s.t_end == 0.5 for s in pspans)


def test_chrome_trace_schema_and_per_tenant_processes(tmp_path):
    tracer = Tracer()
    _staggered(tracer)
    doc = chrome_trace(tracer)
    assert validate_chrome_trace(doc) == []
    names = {(e["pid"], e["args"]["name"]) for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {n for _, n in names} >= {"tenant:t0", "tenant:t1"}
    # rate-change instants ride the X events
    assert any(e["ph"] == "i" for e in doc["traceEvents"])
    out = tmp_path / "trace.json"
    dump(tracer, str(out))
    assert validate_chrome_trace(json.loads(out.read_text())) == []
    text = summary(tracer)
    assert "t0" in text and "link:out" in text


def test_busy_fraction_agrees_with_occupancy_sampler():
    """The exact span integrals and the every-10ms ledger sampler are
    two estimators of the same attribution — they must agree."""
    tracer = Tracer()
    fabric = Fabric.of(Path("link", CAP), concurrency_discount=DISC)
    rt = FabricRuntime(fabric, tracer=tracer)
    sampler = OccupancyTimeSeries(rt, every=0.01)
    rng = np.random.default_rng(3)
    for i in range(12):
        rt.clock.schedule(0.2 * i, lambda i=i: rt.transfer(
            "link", float(rng.uniform(5, 40)), flow=f"f{i}",
            tenant=f"t{i % 3}"))
    rt.clock.run(until=5.0)          # the sampler is periodic: bound the run
    sampled = sampler.averages(OUT)["link"]
    exact = {t: f for (t, p, d), f in tracer.busy_fraction().items()
             if p == "link" and d == OUT}
    assert set(sampled) == set(exact)
    for tenant, frac in exact.items():
        assert sampled[tenant] == pytest.approx(frac, abs=0.05), tenant


# ----------------------------------------------------------------------
# (e) the acceptance scenario: colocation trace vs InterferenceReport
# ----------------------------------------------------------------------

def test_colocation_trace_agrees_with_interference_report(tmp_path):
    import argparse

    from repro.launch.colocate import build_pieces
    from repro.tenancy import AdmissionConfig, Colocation, QoSPolicy
    args = argparse.Namespace(
        arch="internlm2-1.8b", reduced=True, nodes=2, requests=6,
        train_steps=3, slots=2, prompt_len=8, max_new=4, spacing=0.3,
        host_bw=16.0, soc_frac=0.7, discount=0.1, prefill_units=0.25,
        decode_units=0.25, grad_units=16.0, ckpt_units=8.0, ckpt_every=2,
        ckpt_staging="soc", compute_s=0.3, tokens_per_step=1024, seed=7)
    fabric, make_engine, make_cluster, requests = build_pieces(args)
    tracer = Tracer()
    rep = Colocation(fabric=fabric(), make_engine=make_engine,
                     make_cluster=make_cluster,
                     qos=QoSPolicy.serve_train(16.0, 1.0),
                     tracer=tracer).run(requests(), args.train_steps)
    # the trace exports clean
    out = tmp_path / "coloc.json"
    dump(tracer, str(out))
    doc = json.loads(out.read_text())
    assert validate_chrome_trace(doc) == []
    # per-tenant busy time agrees with the report's sampled occupancy
    frac = tracer.busy_fraction()
    checked = 0
    for path, per_tenant in rep.occupancy.items():
        for tenant, sampled in per_tenant.items():
            exact = frac.get((tenant, path, OUT), 0.0)
            assert sampled == pytest.approx(exact, abs=0.05), (path, tenant)
            checked += 1
    assert checked >= 4
    # every tenant in the report shows up as a trace process
    pids = {e["args"]["name"] for e in doc["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"tenant:serve", "tenant:train"} <= pids


# ----------------------------------------------------------------------
# (f) weighted bucket plans from the real parameter tree
# ----------------------------------------------------------------------

def test_layer_group_weights_track_the_param_tree():
    from repro.configs import get_config
    from repro.configs.base import _param_tree_sizes
    from repro.train.cluster import layer_group_weights
    cfg = get_config("internlm2-1.8b").reduced()
    total = float(sum(_param_tree_sizes(cfg).values()))
    for k in (1, 2, cfg.num_layers):
        w = layer_group_weights(cfg, k)
        assert len(w) == k and all(x > 0 for x in w)
        assert math.fsum(w) == pytest.approx(total, rel=1e-12)
    # the embedding rides group 0 and the head/final norm the last
    # group, on top of each group's own layer parameters
    sizes = _param_tree_sizes(cfg)
    w = layer_group_weights(cfg, cfg.num_layers)

    def layer_sum(i):
        return sum(v for n, v in sizes.items()
                   if n.startswith(f"layer{i}."))

    assert w[0] == layer_sum(0) + sizes["embed.table"]
    assert w[-1] == (layer_sum(cfg.num_layers - 1) + sizes["lm_head"]
                     + sizes["final_norm"])
    with pytest.raises(ValueError):
        layer_group_weights(cfg, cfg.num_layers + 1)


def test_weighted_bucket_plan_sums_exactly():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.train.cluster import ClusterTimeModel
    cfg = get_config("internlm2-1.8b")     # full depth: sizes only, no jax
    shape = ShapeConfig("t", 128, 8, "train")
    for k in (2, 3, 4):
        tm = ClusterTimeModel.from_config(cfg, shape, nodes=2, buckets=k,
                                          weighted_buckets=True)
        assert tm.bucket_weights is not None and len(tm.bucket_weights) == k
        plan = tm.bucket_plan()
        # bit-exact conservation regardless of the weights
        assert sum(b.grad_bytes for b in plan) == tm.grad_bytes
        assert sum(b.compute_s for b in plan) == tm.compute_s
        # the split actually follows the weights (not uniform)
        heavy = max(range(k), key=lambda i: tm.bucket_weights[i])
        assert plan[heavy].grad_bytes == max(b.grad_bytes for b in plan)
    # replace() back to one bucket drops the weights cleanly
    import dataclasses
    tm1 = dataclasses.replace(tm, buckets=1, bucket_weights=None)
    assert [b.grad_bytes for b in tm1.bucket_plan()] == [tm.grad_bytes]
    with pytest.raises(ValueError):
        ClusterTimeModel(compute_s=1.0, grad_bytes=4.0, buckets=2,
                         bucket_weights=(1.0, -1.0))


def test_cluster_rejects_tracer_plus_shared_runtime():
    from repro.serve.engine import StagedServeEngine  # noqa: F401
    from repro.train.cluster import ClusterTimeModel, TrainCluster
    tm = ClusterTimeModel(compute_s=0.1, grad_bytes=4.0)
    rt = FabricRuntime(Fabric.of(Path("host:0", 10.0), Path("soc:0", 7.0),
                                 Path("net", 10.0)))
    with pytest.raises(ValueError):
        TrainCluster(1, tm, fabric=rt.fabric, runtime=rt, tracer=Tracer())
    # a cluster that owns its runtime traces by default: the bucket
    # timeline accessor works with zero setup
    cluster = TrainCluster(2, ClusterTimeModel(compute_s=0.05,
                                               grad_bytes=4.0, buckets=2))
    cluster.run(2)
    tl = cluster.bucket_timeline
    assert len(tl) == 2 * 2                         # steps x buckets
    assert all(row["t_done"] >= row["t_issue"] for row in tl)
    assert {row["bucket"] for row in tl} == {0, 1}


# ----------------------------------------------------------------------
# metrics primitives + the re-platformed OffloadStats
# ----------------------------------------------------------------------

def test_metrics_primitives():
    c = Counter("n")
    assert c.value == 0 and isinstance(c.value, int)
    c.inc()
    c.inc(2)
    assert c.value == 3
    g = Gauge("depth")
    g.set(4.5)
    assert g.value == 4.5
    h = Histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    assert h.count == 5 and h.mean == 3.0
    assert h.percentile(0) == 1.0
    assert h.percentile(50) == 3.0 and h.percentile(100) == 5.0
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")     # get-or-create
    reg.counter("x").inc(5)
    reg.gauge("y").set(1.0)
    snap = reg.snapshot()
    assert snap["counters"]["x"] == 5 and snap["gauges"]["y"] == 1.0


def test_offload_stats_ride_the_metrics_registry():
    from repro.offload.program import OffloadStats
    st = OffloadStats()
    st.record_program(100.0)
    st.record_compression(1000.0, 300.0)
    st.record_filter(100, 20)
    c = st.counters
    assert c["programs_run"] == 1
    assert c["compression_bytes_in"] == 1000.0
    assert c["compression_bytes_out"] == 300.0
    assert c["packets_offloaded"] == 80 and c["packets_total"] == 100
    perf = st.get_performance_stats()
    assert perf["compression_ratio"] == pytest.approx(0.3)
    assert perf["offload_hit_rate"] == pytest.approx(0.8)
    assert c["cpu_cycles_saved"] > 0
    # a shared registry sees the same numbers
    assert st.metrics.counter("programs_run").value == 1
