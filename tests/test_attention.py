"""Attention: blocked == ref across shapes/masks; decode == last row."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (attention_blocked, attention_ref,
                                    decode_attention)

CASES = [
    # B, S, Hq, Hkv, d, window, softcap
    (2, 256, 4, 2, 16, None, None),
    (1, 512, 8, 8, 32, 128, 50.0),
    (2, 1024, 4, 1, 16, None, 30.0),
    (1, 512, 4, 2, 16, 100, None),
    (1, 384, 6, 3, 24, None, None),   # non-pow2 heads/dims
]


@pytest.mark.parametrize("case", CASES)
def test_blocked_matches_ref(case):
    b, s, hq, hkv, d, win, cap = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    ref = attention_ref(q, k, v, causal=True, window=win, softcap=cap)
    blk = attention_blocked(q, k, v, causal=True, window=win, softcap=cap,
                            q_block=128, kv_block=128)
    assert float(jnp.abs(ref - blk).max()) < 1e-4


@pytest.mark.parametrize("clen", [1, 7, 64, 128])
def test_decode_matches_causal_last_row(clen):
    b, s, hq, hkv, d = 2, 128, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    full = attention_ref(q[:, :clen], k[:, :clen], v[:, :clen], causal=True)
    dec = decode_attention(q[:, clen - 1:clen], k, v, jnp.asarray(clen))
    assert float(jnp.abs(full[:, -1:] - dec).max()) < 1e-4


def test_decode_per_row_cache_len():
    b, s, hq, hkv, d = 3, 64, 4, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    lens = jnp.asarray([3, 17, 64])
    out = decode_attention(q, k, v, lens)
    for i, L in enumerate([3, 17, 64]):
        one = decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1], jnp.asarray(L))
        assert float(jnp.abs(out[i:i + 1] - one).max()) < 1e-5


def test_sliding_window_strictness():
    """With window=w, token t must ignore anything <= t-w."""
    b, s, h, d = 1, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    w = 8
    out = attention_ref(q, k, v, causal=True, window=w)
    # perturb kv far outside the window of the last token: no change
    k2 = k.at[:, :s - w].set(jax.random.normal(ks[0], (b, s - w, h, d)))
    v2 = v.at[:, :s - w].set(jax.random.normal(ks[1], (b, s - w, h, d)))
    out2 = attention_ref(q, k2, v2, causal=True, window=w)
    assert float(jnp.abs(out[:, -1] - out2[:, -1]).max()) < 1e-5
