"""TrainCluster on the FabricRuntime — the ISSUE 4 acceptance assertions:

  (a) checkpoint traffic scheduled on the SoC paths degrades step time
      less than host-path staging when the host direction is busy, and
      the ordering flips when the fabric is idle (the §6.1 crossover);
  (b) a simulated node failure triggers detect -> elastic resize ->
      checkpoint resume with the loss curve bit-identical to an
      uninterrupted run at the same steps;
  (c) ledger conservation holds across barrier/cancel under the new
      runtime primitives.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.fabric import Fabric, OUT, IN, Path
from repro.core.runtime import FabricRuntime
from repro.train.cluster import (ClusterTimeModel, TRAIN_FABRICS,
                                 TrainCluster, train_fabric)


# ----------------------------------------------------------------------
# new runtime primitives: barrier, cancel, kill, periodic
# ----------------------------------------------------------------------

def test_barrier_rendezvous_and_cycles():
    rt = FabricRuntime(Fabric.of(Path("p", 10.0)))
    log = []
    bar = rt.barrier(3, on_release=lambda gen: log.append(("release", gen)))

    def party(i, delay):
        yield delay
        yield bar.arrive()
        log.append((i, rt.clock.now))
        yield bar.arrive()                 # cyclic: second generation
        log.append((i, rt.clock.now))

    for i, d in enumerate((0.1, 0.5, 0.3)):
        rt.process(party(i, d))
    rt.clock.run()
    # everyone resumes at the last arrival time of each generation
    assert log[0] == ("release", 1)
    assert {e for e in log[1:4]} == {(0, 0.5), (1, 0.5), (2, 0.5)}
    assert log[4] == ("release", 2)
    assert all(t == 0.5 for _, t in log[5:])
    assert bar.generation == 2


def test_barrier_remove_party_releases_waiters():
    rt = FabricRuntime(Fabric.of(Path("p", 10.0)))
    bar = rt.barrier(3)
    woke = []

    def party(i):
        yield bar.arrive()
        woke.append(i)

    rt.process(party(0))
    rt.process(party(1))
    rt.clock.run()
    assert woke == []                      # 2 of 3 arrived: still waiting
    bar.remove_party()                     # the third party died
    rt.clock.run()
    assert sorted(woke) == [0, 1]


def test_cancel_transfer_conserves_ledger_and_rebalances():
    cap = 100.0
    rt = FabricRuntime(Fabric.of(Path("link", cap)))
    t1 = rt.transfer("link", 100.0)
    t2 = rt.transfer("link", 100.0)
    rt.clock.schedule(0.5, lambda: rt.cancel(t1))
    rt.clock.run()
    assert t1.canceled and t1.done and t1.remaining > 0
    # t1 progressed 25 (shared rate 50) before the cancel
    assert t1.remaining == pytest.approx(75.0)
    # t2: 0.5s at 50/s, then full rate for the rest
    assert t2.finished_at == pytest.approx(0.5 + 75.0 / cap)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_cancel_during_latency_phase_never_occupies():
    rt = FabricRuntime(Fabric.of(Path("lagged", 10.0, latency=1.0)))
    t = rt.transfer("lagged", 5.0)
    rt.clock.schedule(0.5, lambda: rt.cancel(t))
    rt.clock.run()
    assert t.canceled and t.remaining == 5.0
    assert rt.ledger.reserved("lagged", OUT) == pytest.approx(0.0, abs=1e-9)
    assert rt.active_transfers() == []


def test_process_kill_cancels_inflight_transfer():
    rt = FabricRuntime(Fabric.of(Path("p", 10.0)))
    seen = {}

    def worker():
        yield rt.transfer("p", 100.0, flow="w")
        seen["finished"] = True            # must never run

    proc = rt.process(worker())
    rt.clock.schedule(1.0, proc.kill)
    rt.clock.run()
    assert proc.done and proc.killed and "finished" not in seen
    assert rt.ledger.reserved("p", OUT) == pytest.approx(0.0, abs=1e-9)
    assert rt.active_transfers() == []


def test_periodic_process_fires_until_killed():
    rt = FabricRuntime(Fabric.of(Path("p", 10.0)))
    ticks = []
    proc = rt.every(0.25, lambda: ticks.append(rt.clock.now), start_delay=0.0)
    rt.clock.schedule(1.1, proc.kill)
    rt.clock.run()
    assert ticks == pytest.approx([0.0, 0.25, 0.5, 0.75, 1.0])


# ----------------------------------------------------------------------
# (a) the §6.1 crossover
# ----------------------------------------------------------------------

def _step_time(grad_bytes, ckpt_path, ckpt_bytes=8e9, steps=6):
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=grad_bytes,
                          ckpt_bytes=ckpt_bytes, ckpt_path=ckpt_path)
    cluster = TrainCluster(2, tm, ckpt_every=2)
    return cluster.run(steps)["sim_seconds"] / steps


def test_ckpt_staging_crossover_busy_vs_idle():
    busy, idle = 8e9, 1e6
    base_busy = _step_time(busy, "soc", ckpt_bytes=0.0)
    base_idle = _step_time(idle, "soc", ckpt_bytes=0.0)
    # host direction busy with gradient traffic: SoC staging degrades
    # the step less than host staging (LineFS keeps its win)
    soc_busy = _step_time(busy, "soc") - base_busy
    host_busy = _step_time(busy, "host") - base_busy
    assert soc_busy < host_busy, (soc_busy, host_busy)
    # idle fabric: the faster host path wins and the ordering flips
    # (LineFS loses its win when the host is free, §6.1)
    soc_idle = _step_time(idle, "soc") - base_idle
    host_idle = _step_time(idle, "host") - base_idle
    assert host_idle < soc_idle, (host_idle, soc_idle)


def test_ckpt_contention_emerges_from_shared_ledger():
    """Host-path staging shares the gradient direction budget; the
    degradation it causes exceeds the SoC path's by more than the
    concurrency discount alone could explain."""
    busy = 8e9
    base = _step_time(busy, "soc", ckpt_bytes=0.0)
    soc = _step_time(busy, "soc")
    host = _step_time(busy, "host")
    assert host > soc > base
    # host staging at least doubles the damage of soc staging
    assert (host - base) > 2 * (soc - base)


def test_external_host_load_slows_only_the_loaded_node():
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=2e9)
    cluster = TrainCluster(3, tm, host_load={"node1": 0.7})
    cluster.run(4)
    det = cluster.straggler
    assert det.occupancy["node1"] > 0.5
    assert det.occupancy["node0"] < 0.2
    assert "node1" in det.stragglers()
    # the loaded node's observed step time is the worst of the fleet
    assert det.ema["node1"] > det.ema["node0"]


def test_named_fabrics_and_time_model_from_config():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    cfg = get_config("internlm2-1.8b").reduced()
    shape = ShapeConfig("t", 128, 8, "train")
    for name, build in TRAIN_FABRICS.items():
        fab = build(2)
        assert "host:0" in fab and "soc:1" in fab and "net" in fab
    tm = ClusterTimeModel.from_config(cfg, shape, nodes=2)
    assert tm.compute_s > 0 and tm.grad_bytes > 0 and tm.ckpt_bytes > 0
    assert tm.tokens_per_step == 128 * 8
    with pytest.raises(ValueError):
        ClusterTimeModel(compute_s=1.0, grad_bytes=0.0, ckpt_path="nvme")


# ----------------------------------------------------------------------
# (b) fail -> detect -> resize -> resume, bit-identical losses
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def numeric_pieces():
    from repro.configs import RunConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    from repro.models.params import init_params
    from repro.train.train_step import make_train_step
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=3e-3, warmup_steps=2, total_steps=12)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    pipeline = TokenPipeline(cfg, shape, seed=0)
    return cfg, step_fn, pipeline


def _numeric_cluster(pieces, ckpt_dir, fail_at):
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    cfg, step_fn, pipeline = pieces
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e8, ckpt_bytes=1e8,
                          tokens_per_step=4 * 32)
    return TrainCluster(
        3, tm, step_fn=step_fn, params=params, opt_state=adamw_init(params),
        batch_at=pipeline.batch_at,
        ckpt=CheckpointManager(str(ckpt_dir), every=4, keep=3),
        heartbeat_every=0.2, heartbeat_timeout=1.0, fail_at=fail_at)


def test_failure_detect_resize_resume_bit_identical(tmp_path, numeric_pieces):
    ref = _numeric_cluster(numeric_pieces, tmp_path / "ref", None)
    ref.run(10)
    fl = _numeric_cluster(numeric_pieces, tmp_path / "fl", ("node2", 6))
    summary = fl.run(10)

    kinds = [e["event"] for e in summary["events"]]
    assert kinds == ["node_silent", "failure_detected", "elastic_resize"]
    silent = summary["events"][0]
    detect = summary["events"][1]
    resize = summary["events"][2]
    # detection is event-driven in simulated time: one timeout after the
    # node's *last heartbeat*, which lands within one heartbeat interval
    # before it went silent
    assert silent["t"] + 1.0 - 0.2 - 1e-6 <= detect["t"] \
        <= silent["t"] + 1.0 + 1e-6
    assert resize["nodes"] == 2
    assert resize["mesh"] == (2, 8, 1)     # best_mesh_for(16 devices)
    assert resize["resume_step"] == 5      # last ckpt at 4 -> resume at 5
    assert summary["nodes"] == 2

    # the loss curve is bit-identical to the uninterrupted run
    ref_losses = {h["step"]: h["loss"] for h in ref.history}
    fl_losses = {h["step"]: h["loss"] for h in fl.history}
    assert sorted(fl_losses) == sorted(ref_losses) == list(range(10))
    for k in ref_losses:
        assert fl_losses[k] == ref_losses[k], k

    # the failure run paid for the re-run steps in simulated time
    assert summary["sim_seconds"] > ref.runtime.clock.now


def test_simulated_tokens_per_s_accounts_for_lost_work(tmp_path,
                                                       numeric_pieces):
    ref = _numeric_cluster(numeric_pieces, tmp_path / "a", None)
    s_ref = ref.run(10)
    fl = _numeric_cluster(numeric_pieces, tmp_path / "b", ("node1", 6))
    s_fl = fl.run(10)
    assert s_fl["tokens_per_s"] < s_ref["tokens_per_s"]


# ----------------------------------------------------------------------
# (c) ledger conservation across barrier/cancel
# ----------------------------------------------------------------------

def _assert_clean_ledger(cluster, external_flows=()):
    led = cluster.runtime.ledger
    for name in cluster.fabric:
        for direction in (OUT, IN):
            reserved = led.reserved(name, direction)
            external = sum(
                (o if direction == OUT else i)
                for (flow, pname), (o, i) in led._by_flow.items()
                if pname == name and flow in external_flows)
            assert reserved == pytest.approx(external, abs=1e-6), \
                (name, direction, reserved, external)
    # and nothing but external flows still holds anything
    leftover = {flow for (flow, _), (o, i) in led._by_flow.items()
                if (o > 0 or i > 0) and flow not in external_flows}
    assert not leftover, leftover


def test_ledger_conserves_through_barrier_steps():
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=4e9, ckpt_bytes=4e9)
    cluster = TrainCluster(3, tm, ckpt_every=2)
    cluster.run(6)
    _assert_clean_ledger(cluster)


def test_ledger_conserves_through_failure_and_cancel(tmp_path):
    """A mid-run kill cancels in-flight transfers; everything those
    flows reserved must be back in the ledger, while the external
    host-load reservation survives untouched."""
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=4e9, ckpt_bytes=4e9)
    cluster = TrainCluster(
        3, tm, ckpt_every=2, host_load={"node0": 0.3},
        heartbeat_every=0.2, heartbeat_timeout=1.0, fail_at=("node2", 3))
    summary = cluster.run(6)
    assert any(e["event"] == "elastic_resize" for e in summary["events"])
    _assert_clean_ledger(cluster, external_flows={"hostload:node0"})
    hl = cluster.fabric["host:0"].capacity * 0.3
    assert cluster.runtime.ledger.reserved("host:0", OUT) == pytest.approx(hl)


def test_cluster_runs_are_chainable():
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=1e9)
    cluster = TrainCluster(2, tm)
    s1 = cluster.run(3)
    assert cluster.start_step == 3 and s1["steps"] == 3
    s2 = cluster.run(2)
    assert cluster.start_step == 5
    assert s2["steps"] == 2               # this call, not cumulative
    steps = [h["step"] for h in cluster.history]
    assert steps == list(range(5))
    _assert_clean_ledger(cluster)


def test_cluster_validates_host_load_and_node_names():
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=1e9)
    # a load at/above 1 - discount would stall the node's gradient flow
    # at rate 0 forever (the clock would never drain) -> refused upfront
    with pytest.raises(ValueError, match="stall"):
        TrainCluster(2, tm, host_load={"node0": 0.95})
    with pytest.raises(ValueError, match="unknown node"):
        TrainCluster(2, tm, host_load={"node7": 0.5})
    with pytest.raises(ValueError, match="unknown node"):
        TrainCluster(2, tm, fail_at=("node9", 3))
    with pytest.raises(ValueError, match="unknown node"):
        TrainCluster(2, tm, node_compute_scale={"nodeX": 2.0})
