"""Multi-pod hierarchical fabrics (train/pods.py): merged per-pod
fabrics over the shared DCN trunk, the compressed-vs-raw pod_sync
crossover, pod-local fault tolerance, and the launcher path."""
import jax
import pytest

from repro.core.fabric import Fabric, FabricError, OUT, merge_fabrics
from repro.train.cluster import ClusterTimeModel, TrainCluster
from repro.train.pods import (PodTopology, pod_cluster, pod_fabric,
                              trunk_path, TRUNK)


# ----------------------------------------------------------------------
# topology + fabric composition
# ----------------------------------------------------------------------

def test_pod_topology_maps_nodes_and_paths():
    topo = PodTopology(3, 4)
    assert topo.total_nodes == 12
    assert topo.pod_of(0) == 0 and topo.pod_of(11) == 2
    assert topo.local_of(9) == 1
    assert topo.node_path(9, "host") == "pod2/host:1"
    assert topo.node_path(5, "cpu:host") == "pod1/cpu:host:1"
    assert topo.net_path(7) == "pod1/net"
    assert topo.trunk == TRUNK


def test_pod_topology_validates():
    with pytest.raises(ValueError):
        PodTopology(0, 4)
    with pytest.raises(ValueError):
        PodTopology(2, 2, sync="bogus")
    with pytest.raises(ValueError):
        PodTopology(2, 2, compress_ratio=0.0)


def test_pod_fabric_namespaces_pods_and_shares_one_trunk():
    fab = pod_fabric(3, 2)
    for p in range(3):
        assert f"pod{p}/host:0" in fab
        assert f"pod{p}/soc:1" in fab
        assert f"pod{p}/net" in fab
    assert "host:0" not in fab          # nothing leaks un-namespaced
    assert TRUNK in fab                 # one shared trunk, not three
    assert len([n for n in fab if n == TRUNK]) == 1


def test_conflicting_trunk_capacities_are_a_merge_error():
    a = Fabric.of(trunk_path(25e9))
    b = Fabric.of(trunk_path(50e9))
    with pytest.raises(FabricError):
        merge_fabrics(a, b)
    # agreeing definitions fold silently into one budget
    merged = merge_fabrics(a, Fabric.of(trunk_path(25e9)))
    assert TRUNK in merged


def test_cluster_rejects_mismatched_topology():
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=0.0)
    with pytest.raises(ValueError):
        TrainCluster(3, tm, topology=PodTopology(2, 2))


# ----------------------------------------------------------------------
# the pod_sync tradeoff: emergent, flips with trunk bandwidth
# ----------------------------------------------------------------------

def _tokens(sync, trunk_bw):
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e9,
                          tokens_per_step=4096)
    c = pod_cluster(4, 2, tm, sync=sync, trunk_bw=trunk_bw)
    tokens = c.run(4)["tokens_per_s"]
    # conservation: every trunk reservation was returned
    assert c.runtime.ledger.reserved(TRUNK, OUT) == pytest.approx(0.0)
    return tokens


def test_compressed_sync_wins_on_thin_trunk_loses_on_fat():
    thin, fat = 25e9, 400e9
    assert _tokens("compressed", thin) > _tokens("auto", thin)
    assert _tokens("compressed", fat) < _tokens("auto", fat)


def test_single_pod_topology_matches_plain_cluster():
    """pods=1 is the degenerate case: no trunk traffic, same timeline
    as an un-namespaced TrainCluster."""
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e9,
                          tokens_per_step=4096)
    plain = TrainCluster(2, tm).run(4)
    podded = pod_cluster(1, 2, tm).run(4)
    assert podded["sim_seconds"] == pytest.approx(plain["sim_seconds"])
    assert podded["tokens_per_s"] == pytest.approx(plain["tokens_per_s"])


# ----------------------------------------------------------------------
# pod-local failure: detect -> resize -> resume, bit-identical losses
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def numeric_pieces():
    from repro.configs import RunConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    from repro.train.train_step import make_train_step
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=3e-3, warmup_steps=2, total_steps=12)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    pipeline = TokenPipeline(cfg, shape, seed=0)
    return cfg, step_fn, pipeline


def _numeric_pod_cluster(pieces, ckpt_dir, fail_at):
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    cfg, step_fn, pipeline = pieces
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e8, ckpt_bytes=1e8,
                          tokens_per_step=4 * 32)
    return pod_cluster(
        2, 2, tm, step_fn=step_fn, params=params,
        opt_state=adamw_init(params), batch_at=pipeline.batch_at,
        ckpt=CheckpointManager(str(ckpt_dir), every=4, keep=3),
        heartbeat_every=0.2, heartbeat_timeout=1.0, fail_at=fail_at)


def test_pod_leader_failure_detect_resize_resume_bit_identical(
        tmp_path, numeric_pieces):
    """Losing pod 1's *leader* (node2) mid-run: the watchdog fires, the
    fleet resizes to 3 nodes, node3 inherits pod-1 leadership for the
    trunk sync, and the loss curve stays bit-identical to the
    uninterrupted run."""
    ref = _numeric_pod_cluster(numeric_pieces, tmp_path / "ref", None)
    ref.run(10)
    fl = _numeric_pod_cluster(numeric_pieces, tmp_path / "fl",
                              ("node2", 6))
    summary = fl.run(10)

    kinds = [e["event"] for e in summary["events"]]
    assert kinds == ["node_silent", "failure_detected", "elastic_resize"]
    assert summary["events"][2]["nodes"] == 3
    assert summary["nodes"] == 3

    ref_losses = {h["step"]: h["loss"] for h in ref.history}
    fl_losses = {h["step"]: h["loss"] for h in fl.history}
    assert sorted(fl_losses) == sorted(ref_losses) == list(range(10))
    for k in ref_losses:
        assert fl_losses[k] == ref_losses[k], k

    # the failure run paid for re-run steps + still paid the trunk
    assert summary["sim_seconds"] > ref.runtime.clock.now
    assert fl.runtime.ledger.reserved(TRUNK, OUT) == pytest.approx(0.0)


# ----------------------------------------------------------------------
# launcher path: --simulate N --pods P
# ----------------------------------------------------------------------

def test_launch_train_simulate_pods_cli(capsys):
    from repro.launch.train import main
    cluster = main(["--arch", "internlm2-1.8b", "--reduced", "--steps", "3",
                    "--simulate", "8", "--pods", "4", "--ckpt-every", "0"])
    out = capsys.readouterr().out
    assert "pods=4x8 pod_sync=auto" in out
    assert "reserved after run = 0" in out
    assert cluster.topology.total_nodes == 32
    assert TRUNK in cluster.fabric
