"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention.ops import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant.ops import dequantize_int8, quantize_int8
from repro.kernels.quant.ref import dequantize_ref, quantize_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_sequential_ref

FA_CASES = [
    # B, S, Hq, Hkv, d, win, cap, qb, kb, dtype
    (2, 256, 4, 2, 64, None, None, 128, 128, jnp.float32),
    (1, 512, 8, 8, 128, 128, 50.0, 128, 256, jnp.float32),
    (2, 512, 4, 1, 64, None, 30.0, 256, 128, jnp.float32),
    (1, 256, 2, 2, 32, 100, None, 64, 64, jnp.float32),
    (1, 256, 4, 2, 64, None, None, 128, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_vs_ref(case):
    b, s, hq, hkv, d, win, cap, qb, kb, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dt)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dt)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dt)
    ref = attention_ref(q, k, v, causal=True, window=win, softcap=cap)
    out = flash_attention(q, k, v, causal=True, window=win, softcap=cap,
                          q_block=qb, kv_block=kb)
    tol = 2e-5 if dt == jnp.float32 else 2e-2
    assert float(jnp.abs(ref.astype(jnp.float32) - out.astype(jnp.float32)).max()) < tol


DEC_CASES = [
    (2, 512, 4, 2, 64, None, None, 300),
    (1, 256, 8, 8, 128, 128, 50.0, 256),
    (2, 512, 4, 1, 64, None, None, 1),
    (1, 1024, 16, 2, 64, None, 30.0, 777),
]


@pytest.mark.parametrize("case", DEC_CASES)
def test_decode_attention_vs_ref(case):
    b, s, hq, hkv, d, win, cap, clen = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    kc = jax.random.normal(ks[1], (b, s, hkv, d))
    vc = jax.random.normal(ks[2], (b, s, hkv, d))
    ref = decode_attention_ref(q, kc, vc, jnp.asarray(clen), window=win, softcap=cap)
    out = decode_attention_kernel(q, kc, vc, jnp.asarray(clen), window=win,
                                  softcap=cap, kv_block=128)
    assert float(jnp.abs(ref - out).max()) < 2e-5


SSD_CASES = [(2, 64, 4, 8, 16, 16, 2), (1, 128, 6, 16, 8, 32, 3),
             (2, 256, 8, 16, 32, 64, 8)]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_vs_sequential(case):
    b, s, h, p, n, L, ht = case
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    yr, hr = ssd_sequential_ref(x, dt, A, Bm, C)
    yk, hk = ssd_scan(x, dt, A, Bm, C, chunk=L, head_tile=ht)
    assert float(jnp.abs(yr.astype(jnp.float32) - yk).max()) < 5e-3
    assert float(jnp.abs(hr - hk).max()) < 5e-3


@pytest.mark.parametrize("n,block", [(1000, 128), (4096, 256), (17, 16)])
def test_quant_kernel_vs_ref(n, block):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 3
    q, s = quantize_int8(x, block=block)
    pad = (-n) % block
    blocks = jnp.pad(x, (0, pad)).reshape(-1, block)
    qr, sr = quantize_ref(blocks)
    assert (q == qr).all()
    # scales match to float32 ulp (reduction order differs across tiles)
    assert float(jnp.abs(s - sr).max() / jnp.abs(sr).max()) < 1e-6
    back = dequantize_int8(q, s, (n,))
    ref = dequantize_ref(qr, sr).reshape(-1)[:n]
    assert float(jnp.abs(back - ref).max()) < 1e-5
    # roundtrip error bounded by half a quantization step per block
    step = jnp.repeat(s[:, 0], block)[:n]
    assert bool((jnp.abs(back - x) <= step * 0.5 + 1e-6).all())
