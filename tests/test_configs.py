"""Config registry + parameter-count parity."""
import jax
import pytest

from repro.configs import SHAPES, all_configs, get_config, list_archs, shape_applicable
from repro.models.params import init_params, layer_period, param_count_tree

NAMEPLATE = {  # billions, from the assignment's public sources
    "glm4-9b": (9.4, 0.1), "gemma2-9b": (9.24, 0.12), "gemma-7b": (8.54, 0.1),
    "internlm2-1.8b": (1.89, 0.05), "granite-moe-1b-a400m": (1.33, 0.05),
    "mamba2-2.7b": (2.7, 0.08), "jamba-1.5-large-398b": (398, 4.0),
}


def test_registry_complete():
    assert len(list_archs()) == 10
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.name == arch
        assert cfg.source


@pytest.mark.parametrize("arch", list(NAMEPLATE))
def test_param_counts_match_nameplate(arch):
    want, tol = NAMEPLATE[arch]
    got = get_config(arch).param_count() / 1e9
    assert abs(got - want) < tol, (arch, got, want)


def test_moe_active_params():
    cfg = get_config("granite-moe-1b-a400m")
    assert 0.35 < cfg.active_param_count() / 1e9 < 0.5
    jam = get_config("jamba-1.5-large-398b")
    assert 85 < jam.active_param_count() / 1e9 < 100


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_config_init_matches_analytic(arch):
    small = get_config(arch).reduced()
    params, logical = init_params(small, jax.random.PRNGKey(0))
    assert param_count_tree(params) == small.param_count()
    # logical tree mirrors params tree
    pl = jax.tree.leaves(params)
    ll = jax.tree.leaves(
        logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(pl) == len(ll)
    for p, lg in zip(pl, ll):
        assert len(lg) == p.ndim, (lg, p.shape)


def test_layer_periods():
    assert layer_period(get_config("gemma2-9b")) == 2
    assert layer_period(get_config("jamba-1.5-large-398b")) == 8
    assert layer_period(get_config("mamba2-2.7b")) == 1
    assert layer_period(get_config("glm4-9b")) == 1


def test_shape_applicability():
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicable(cfg, shape)
            if sname == "long_500k":
                assert ok == (cfg.family in ("ssm", "hybrid")), arch
            else:
                assert ok, (arch, sname, reason)


def test_jamba_interleave():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [cfg.layer_kind(i) for i in range(8)]
    assert kinds.count("attn") == 1 and kinds.count("ssm") == 7
    moes = [cfg.is_moe_layer(i) for i in range(8)]
    assert sum(moes) == 4  # every other layer


def test_gemma2_local_global():
    cfg = get_config("gemma2-9b")
    assert cfg.is_local_layer(0) and not cfg.is_local_layer(1)
