"""HLO collective parsing + axis attribution + roofline wiring."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.charz import (attribute_axes, parse_collectives,
                              summarize_traffic)
from repro.core.roofline import build_report

MESH = [("pod", 2), ("data", 16), ("model", 16)]

HLO_SAMPLE = """
  %all-gather = f32[32,16]{0,1} all-gather(%copy), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %ar = bf16[128]{0} all-reduce(%x), channel_id=2, replica_groups=[32,16]<=[512], to_apply=%add
  %rs = s8[64]{0} reduce-scatter(%y), channel_id=3, replica_groups=[16,32]<=[2,16,16]T(1,2,0), dimensions={0}
  %cp = f32[16]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,256},{256,0}}
"""


def test_parse_all_kinds():
    ops = parse_collectives(HLO_SAMPLE, MESH)
    kinds = sorted(o.op for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    ag = next(o for o in ops if o.op == "all-gather")
    assert ag.result_bytes == 32 * 16 * 4
    assert ag.group_size == 4
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.result_bytes == 128 * 2
    assert ar.group_size == 16


def test_axis_attribution_single():
    # model: stride 1, size 16
    assert attribute_axes(list(range(16)), MESH) == ("model",)
    # data: stride 16, size 16
    assert attribute_axes(list(range(0, 256, 16)), MESH) == ("data",)
    # pod: stride 256, size 2
    assert attribute_axes([0, 256], MESH) == ("pod",)


def test_axis_attribution_fused():
    # (data, model): contiguous 256 devices
    assert attribute_axes(list(range(256)), MESH) == ("data", "model")
    # (pod, data): stride 16, 32 members
    grp = [p * 256 + d * 16 for p in range(2) for d in range(16)]
    assert attribute_axes(sorted(grp), MESH) == ("pod", "data")


def test_traffic_model():
    ops = parse_collectives(HLO_SAMPLE, MESH)
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.traffic_per_chip == pytest.approx(2 * 256 * 15 / 16)
    rs = next(o for o in ops if o.op == "reduce-scatter")
    assert rs.traffic_per_chip == pytest.approx(64 * (rs.group_size - 1))


def test_summarize_pod_dominates():
    s = summarize_traffic(HLO_SAMPLE, MESH)
    assert "dcn:pod" in s.per_path      # the collective-permute pair (0,16)?
    assert s.total > 0


def test_end_to_end_small_compile():
    """Real lowering: a sharded matmul emits an all-gather we can parse."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with jax.set_mesh(mesh):
        f = jax.jit(lambda a, b: (a @ b).sum())
        co = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32),
                     jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    rep = build_report(arch="x", shape="y", mesh_name="1", mesh_axes=[("model", 1)],
                       cost=co.cost_analysis(), hlo_text=co.as_text(),
                       model_flops=2 * 8 * 8 * 8, chips=1)
    assert rep.flops_per_chip > 0
    assert rep.compute_s > 0
