"""HLO collective parsing + axis attribution + roofline wiring."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.charz import (attribute_axes, parse_collectives,
                              summarize_traffic)
from repro.core.roofline import build_report

MESH = [("pod", 2), ("data", 16), ("model", 16)]

HLO_SAMPLE = """
  %all-gather = f32[32,16]{0,1} all-gather(%copy), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={1}
  %ar = bf16[128]{0} all-reduce(%x), channel_id=2, replica_groups=[32,16]<=[512], to_apply=%add
  %rs = s8[64]{0} reduce-scatter(%y), channel_id=3, replica_groups=[16,32]<=[2,16,16]T(1,2,0), dimensions={0}
  %cp = f32[16]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,256},{256,0}}
"""


def test_parse_all_kinds():
    ops = parse_collectives(HLO_SAMPLE, MESH)
    kinds = sorted(o.op for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    ag = next(o for o in ops if o.op == "all-gather")
    assert ag.result_bytes == 32 * 16 * 4
    assert ag.group_size == 4
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.result_bytes == 128 * 2
    assert ar.group_size == 16


def test_axis_attribution_single():
    # model: stride 1, size 16
    assert attribute_axes(list(range(16)), MESH) == ("model",)
    # data: stride 16, size 16
    assert attribute_axes(list(range(0, 256, 16)), MESH) == ("data",)
    # pod: stride 256, size 2
    assert attribute_axes([0, 256], MESH) == ("pod",)


def test_axis_attribution_fused():
    # (data, model): contiguous 256 devices
    assert attribute_axes(list(range(256)), MESH) == ("data", "model")
    # (pod, data): stride 16, 32 members
    grp = [p * 256 + d * 16 for p in range(2) for d in range(16)]
    assert attribute_axes(sorted(grp), MESH) == ("pod", "data")


def test_axis_attribution_trivial_and_full():
    # groups of <= 1 span nothing
    assert attribute_axes([], MESH) == ()
    assert attribute_axes([7], MESH) == ()
    # the whole mesh is the full fused run
    assert attribute_axes(list(range(512)), MESH) == ("pod", "data", "model")


def test_axis_attribution_smallest_stride_fallback():
    """A partial-axis group matches no exact axis and no contiguous run;
    it falls back to the smallest stride whose axis can contain the
    jumps — here 3 of data's 16 members."""
    assert attribute_axes([0, 16, 32], MESH) == ("data",)
    # partial model-axis group: stride 1 -> model
    assert attribute_axes([3, 4, 5, 6], MESH) == ("model",)
    # stride that fits no axis at all (= 2 x pod stride): every axis is
    # rejected and the fallback attributes to the whole axis list
    assert attribute_axes([0, 512], MESH) == ("pod", "data", "model")


def test_collective_permute_source_target_pairs():
    """Each source-target pair becomes a 2-group; attribution uses the
    first pair, traffic is the full payload, group_size is forced to 2."""
    # pod-crossing pairs (stride 256)
    hlo = ("%cp = f32[128]{0} collective-permute(%x), channel_id=9, "
           "source_target_pairs={{0,256},{1,257},{2,258}}")
    (op,) = parse_collectives(hlo, MESH)
    assert op.op == "collective-permute"
    assert op.axes == ("pod",)
    assert op.group_size == 2
    assert op.traffic_per_chip == 128 * 4            # full result, no (n-1)/n
    # neighbor shift along model (stride 1)
    hlo2 = ("%cp2 = bf16[64]{0} collective-permute(%y), channel_id=10, "
            "source_target_pairs={{0,1},{1,2},{2,3}}")
    (op2,) = parse_collectives(hlo2, MESH)
    assert op2.axes == ("model",)
    assert op2.traffic_per_chip == 64 * 2


def test_iota_groups_with_transpose_attribution():
    """[g,s]<=[dims]T(perm) iota groups: the transpose changes which
    axis is innermost, and attribution must follow the permuted layout."""
    # untransposed: [32,16]<=[512] -> groups are contiguous model rows
    hlo = ("%ag = f32[16]{0} all-gather(%x), channel_id=11, "
           "replica_groups=[32,16]<=[512], dimensions={0}")
    (op,) = parse_collectives(hlo, MESH)
    assert op.axes == ("model",)
    # transposed T(1,2,0): each group mixes model (stride 1) and pod
    # (stride 256) members -> not an axis, not a contiguous run; the
    # smallest-stride fallback lands on model
    hlo_t = ("%rs = s8[64]{0} reduce-scatter(%y), channel_id=12, "
             "replica_groups=[16,32]<=[2,16,16]T(1,2,0), dimensions={0}")
    (op_t,) = parse_collectives(hlo_t, MESH)
    assert op_t.group_size == 32
    assert op_t.axes == ("model",)
    # transposed T(0,2,1): groups hold one pod's data-axis members
    hlo_d = ("%ag2 = f32[8]{0} all-gather(%z), channel_id=13, "
             "replica_groups=[32,16]<=[2,16,16]T(0,2,1), dimensions={0}")
    (op_d,) = parse_collectives(hlo_d, MESH)
    assert op_d.axes == ("data",)
    # fused multi-axis iota: [2,256]<=[512] -> (data, model) runs
    hlo_f = ("%ar2 = f32[4]{0} all-reduce(%w), channel_id=14, "
             "replica_groups=[2,256]<=[512], to_apply=%add")
    (op_f,) = parse_collectives(hlo_f, MESH)
    assert op_f.axes == ("data", "model")
    assert op_f.group_size == 256


def test_traffic_model():
    ops = parse_collectives(HLO_SAMPLE, MESH)
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.traffic_per_chip == pytest.approx(2 * 256 * 15 / 16)
    rs = next(o for o in ops if o.op == "reduce-scatter")
    assert rs.traffic_per_chip == pytest.approx(64 * (rs.group_size - 1))


def test_summarize_pod_dominates():
    s = summarize_traffic(HLO_SAMPLE, MESH)
    assert "dcn:pod" in s.per_path      # the collective-permute pair (0,16)?
    assert s.total > 0


def test_end_to_end_small_compile():
    """Real lowering: a sharded matmul emits an all-gather we can parse."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    with jax.set_mesh(mesh):
        f = jax.jit(lambda a, b: (a @ b).sum())
        co = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32),
                     jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    rep = build_report(arch="x", shape="y", mesh_name="1", mesh_axes=[("model", 1)],
                       cost=co.cost_analysis(), hlo_text=co.as_text(),
                       model_flops=2 * 8 * 8 * 8, chips=1)
    assert rep.flops_per_chip > 0
    assert rep.compute_s > 0
