"""Fabric API: budget-ledger invariants + paper-number reproduction.

The ledger properties run on randomized fabrics/sequences (seeded, no
hypothesis dependency): budgets are conserved under any allocation
sequence, no path is over-committed, and release restores exactly.
The router section re-derives the §5.1/§5.2 calibration from the
ledger side; tests/test_planner.py asserts the same numbers through
the router/alternatives surface.
"""
import math
import random

import pytest

from repro.core.fabric import (Alternative, BYTES_PER_S, BudgetLedger, Fabric,
                               FabricError, InsufficientBudget,
                               MultipathRouter, OPS_PER_S, Path, Use,
                               linefs_fabric, linefs_replication_alternatives)
from repro.core.paths import enumerate_paths

N = 200e9 / 8   # paper testbed: 200 Gbps network
P = 256e9 / 8   # 256 Gbps internal PCIe


# ----------------------------------------------------------------------
# ledger properties
# ----------------------------------------------------------------------

def _random_fabric(rng: random.Random) -> Fabric:
    n = rng.randint(2, 5)
    paths = []
    for i in range(n):
        paths.append(Path(
            f"p{i}", capacity=rng.uniform(1.0, 1e3),
            units=rng.choice([BYTES_PER_S, OPS_PER_S]),
            bidirectional=rng.random() < 0.7,
            shared_group=rng.choice([None, "g1", "g2"])))
    disc = rng.choice([0.0, 0.125])
    return Fabric(paths, concurrency_discount=disc)


@pytest.mark.parametrize("seed", range(20))
def test_ledger_never_overcommits(seed):
    """Any sequence of (non-strict) reserves keeps every direction at or
    under its raw capacity; strict over-asks raise and change nothing."""
    rng = random.Random(seed)
    fabric = _random_fabric(rng)
    led = fabric.ledger()
    flows = ["f1", "f2", "f3"]
    for _ in range(200):
        name = rng.choice(list(fabric))
        flow = rng.choice(flows)
        out = rng.uniform(0, fabric[name].capacity * 0.6)
        in_ = rng.uniform(0, fabric[name].capacity * 0.6)
        before = led.checkpoint()
        ok = led.reserve(name, out=out, in_=in_, flow=flow, strict=False)
        if not ok:
            assert led.checkpoint() == before   # failed reserve is a no-op
        for p in fabric:
            for d in ("out", "in"):
                cap = fabric.direction_capacity(p, d)
                assert led.reserved(p, d) <= cap * (1 + 1e-9), (p, d)
                assert led.available(p, d) >= 0.0


@pytest.mark.parametrize("seed", range(20))
def test_ledger_release_restores_exactly(seed):
    """Releasing every flow returns the ledger to pristine state; the
    sum of per-flow holdings always equals the per-path reserved total."""
    rng = random.Random(seed)
    fabric = _random_fabric(rng)
    led = fabric.ledger()
    holdings = {}
    for k in range(100):
        name = rng.choice(list(fabric))
        flow = f"f{rng.randint(0, 3)}"
        out = rng.uniform(0, fabric[name].capacity * 0.4)
        in_ = rng.uniform(0, fabric[name].capacity * 0.4)
        if led.reserve(name, out=out, in_=in_, flow=flow, strict=False):
            o, i = holdings.get((flow, name), (0.0, 0.0))
            holdings[(flow, name)] = (o + out, i + in_)
        # invariant: totals match the per-flow view
        for p in fabric:
            tot_o = sum(o for (f, q), (o, i) in holdings.items() if q == p)
            tot_i = sum(i for (f, q), (o, i) in holdings.items() if q == p)
            assert led.reserved(p, "out") == pytest.approx(tot_o, abs=1e-6)
            assert led.reserved(p, "in") == pytest.approx(tot_i, abs=1e-6)
    for flow in {f for (f, _) in holdings}:
        led.release_flow(flow)
    for p in fabric:
        for d in ("out", "in"):
            assert led.reserved(p, d) == pytest.approx(0.0, abs=1e-6)
            assert led.available(p, d) == pytest.approx(
                fabric.direction_capacity(p, d), rel=1e-9, abs=1e-6)


def test_ledger_strict_overcommit_raises():
    fabric = Fabric.of(Path("p", 100.0))
    led = fabric.ledger()
    led.reserve("p", out=80.0)
    with pytest.raises(InsufficientBudget):
        led.reserve("p", out=30.0)
    assert led.reserved("p", "out") == pytest.approx(80.0)   # unchanged
    assert led.reserve("p", out=30.0, strict=False) is False
    led.reserve("p", out=20.0)                               # exact fill OK


def test_ledger_release_more_than_held_raises():
    fabric = Fabric.of(Path("p", 100.0))
    led = fabric.ledger()
    led.reserve("p", out=10.0, flow="a")
    with pytest.raises(InsufficientBudget):
        led.release("p", out=20.0, flow="a")
    with pytest.raises(InsufficientBudget):
        led.release("p", out=5.0, flow="b")   # b holds nothing


def test_ledger_checkpoint_restore_roundtrip():
    fabric = Fabric.of(Path("a", 10.0), Path("b", 20.0, bidirectional=False))
    led = fabric.ledger()
    led.reserve("a", out=3.0, in_=2.0, flow="x")
    token = led.checkpoint()
    led.reserve("a", out=4.0, flow="y")
    led.reserve("b", out=11.0, flow="y")
    led.restore(token)
    assert led.reserved("a", "out") == pytest.approx(3.0)
    assert led.reserved("a", "in") == pytest.approx(2.0)
    assert led.reserved("b", "out") == pytest.approx(0.0)
    assert led.holders("a") == {"x"}


def test_unidirectional_path_has_no_in_budget():
    fabric = Fabric.of(Path("one", 50.0, bidirectional=False))
    led = fabric.ledger()
    assert led.available("one", "in") == 0.0
    with pytest.raises(InsufficientBudget):
        led.reserve("one", in_=1.0)


def test_concurrency_discount_applied_once_in_ledger():
    """§4.1: a second distinct flow on the same group cuts the
    effective capacity once — not per call site, not per use."""
    fabric = Fabric.of(Path("p", 100.0), concurrency_discount=0.125)
    led = fabric.ledger()
    assert led.effective_capacity("p", "out") == pytest.approx(100.0)
    led.reserve("p", out=10.0, flow="a")
    # a alone: still undiscounted
    assert led.effective_capacity("p", "out") == pytest.approx(100.0)
    # b joining discounts the path (and would-be availability reflects it)
    assert led.effective_capacity("p", "out", joining="b") == pytest.approx(87.5)
    assert led.available("p", "out", joining="b") == pytest.approx(77.5)
    led.reserve("p", out=5.0, flow="b")
    assert led.effective_capacity("p", "out") == pytest.approx(87.5)


# ----------------------------------------------------------------------
# router: the §5.1 LineFS numbers through the first-class API
# ----------------------------------------------------------------------

def test_router_linefs_a1_peak_matches_paper():
    """Paper §5.1: without compression A1 peaks at 128 Gbps."""
    fabric = linefs_fabric(N, P)
    a1 = linefs_replication_alternatives(N, P, ratio=1.0)[0]
    assert abs(a1.solo_rate(fabric) * 8 / 1e9 - 128) < 1


def test_router_greedy_combine_exceeds_solo():
    """A2 (SoC-capped) + A3 fills the leftover network (Fig 15)."""
    fabric = linefs_fabric(N, P)
    alts = linefs_replication_alternatives(N, P, ratio=0.5, soc_rate=12e9)
    router = fabric.router()
    allocs, total = router.allocate([alts[1], alts[2]])
    assert total > alts[1].solo_rate(fabric)
    assert total > 0.9 * alts[2].solo_rate(fabric)
    assert allocs[0].bottleneck == "compute"
    assert allocs[1].bottleneck.startswith("net")


def test_router_bidirectional_multiplexing():
    """Fig 5: opposite-direction flows reach ~2x one-way; same-direction
    flows split one budget; double-crossing eats both directions."""
    fabric = linefs_fabric(N, P)
    router = fabric.router()
    read = Alternative("read", uses=[Use("net", out=1)])
    write = Alternative("write", uses=[Use("net", in_=1)])
    _, total = router.allocate([read, write])
    assert total == pytest.approx(2 * N, rel=1e-6)
    read2 = Alternative("read2", uses=[Use("net", out=1)])
    _, total_same = router.allocate([read, read2])
    assert total_same == pytest.approx(N, rel=1e-6)
    relay = Alternative("relay", uses=[Use("internal", out=1, in_=1)])
    other = Alternative("other", uses=[Use("internal", out=1)])
    _, solo = router.allocate([relay])
    allocs, both = router.allocate([relay, other])
    assert solo == pytest.approx(P, rel=1e-6)
    assert both == solo and allocs[1].rate == 0.0


def test_router_slack_rule():
    """B_slow <= P - N after the primary saturates the network."""
    fabric = linefs_fabric(N, P)
    primary = Alternative("primary", uses=[Use("net", out=1),
                                           Use("internal", out=1)])
    assert fabric.router().slack(primary, "internal") == \
        pytest.approx(P - N, rel=1e-6)


def test_allocate_aggregates_duplicate_uses():
    """Two Uses of one (path, direction) add up — the admissible rate
    halves instead of the strict reserve blowing up."""
    fabric = Fabric.of(Path("net", 100.0))
    dup = Alternative("dup", uses=[Use("net", out=1), Use("net", out=1)])
    allocs, total = fabric.router().allocate([dup])
    assert total == pytest.approx(50.0)
    assert allocs[0].bottleneck == "net:out"


def test_reserve_alternative_strict_failure_is_atomic():
    """A strict reserve that raises mid-alternative must leave the
    ledger untouched (all uses or none)."""
    fabric = Fabric.of(Path("a", 100.0), Path("b", 10.0))
    led = fabric.ledger()
    alt = Alternative("x", uses=[Use("a", out=1), Use("b", out=1)])
    with pytest.raises(InsufficientBudget):
        led.reserve_alternative(alt, 50.0)     # b only sustains 10
    assert led.reserved("a", "out") == 0.0
    assert led.reserved("b", "out") == 0.0


def test_plan_decode_placement_uses_given_costs():
    """The plan must be computed with the caller's calibration, not the
    defaults (use coefficients like mixed_nic_efficiency come from
    PathCosts, not from the fabric)."""
    from repro.serve.disagg import (PathCosts, kv_fabric,
                                    plan_decode_placement)
    costs = PathCosts(mixed_nic_efficiency=0.3)
    plan = plan_decode_placement(kv_fabric(costs), hit_mass=0.7, costs=costs)
    default = plan_decode_placement(kv_fabric(), hit_mass=0.7)
    assert plan.rate < default.rate            # harsher mixing penalty


def test_router_demand_cap_and_ledger_threading():
    """Routing against a pre-loaded ledger sees only the leftovers."""
    fabric = linefs_fabric(N, P)
    led = fabric.ledger()
    led.reserve("net", out=N / 2, flow="background")
    router = fabric.router()
    a3 = linefs_replication_alternatives(N, P, ratio=1.0)[2]
    _, total = router.allocate([a3], ledger=led)
    assert total == pytest.approx(N / 2, rel=1e-6)
    # demand below capacity stops early
    _, got = router.allocate([a3], demand=1e9)
    assert got == pytest.approx(1e9)


# ----------------------------------------------------------------------
# router: the §5.2 DrTM-KV numbers (ops/s units + blend)
# ----------------------------------------------------------------------

def test_kv_fabric_is_ops_units_and_validates():
    from repro.serve.disagg import kv_alternatives, kv_fabric
    fabric = kv_fabric()
    assert all(p.units == OPS_PER_S for p in fabric.values())
    for alt in kv_alternatives().values():
        fabric.validate(alt)    # declared units match
    bad = Alternative("bad", uses=[Use("host_read", out=1, units=BYTES_PER_S)])
    with pytest.raises(FabricError):
        fabric.validate(bad)
    unknown = Alternative("u", uses=[Use("nope", out=1)])
    with pytest.raises(FabricError):
        fabric.validate(unknown)


def test_blend_reproduces_combined_a4_a5():
    """§5.2 / Fig 18: the router blend matches the calibrated paper
    numbers and the DisaggKV entry point is the same computation."""
    from repro.serve.disagg import DisaggKV, KVStoreParams, MultipathRouter
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    total, allocs = kv.combined_a4_a5()
    assert abs(total / 1e6 - 68) < 4
    assert sum(a.rate for a in allocs) == pytest.approx(total)
    m = kv.cache_hit_mass()
    alts = kv.alternatives()
    direct, _ = MultipathRouter(kv.fabric()).blend(
        [(alts["A5"], m), (alts["A4"], 1 - m)])
    assert direct == pytest.approx(total)
    # discount applied once: disabling it must raise the blended rate
    from repro.serve.disagg import PathCosts
    kv2 = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000),
                   costs=PathCosts(concurrency_discount=0.0))
    total2, _ = kv2.combined_a4_a5()
    assert total2 > total


def test_plan_decode_placement_prefers_soc_cache():
    from repro.serve.disagg import plan_decode_placement, kv_fabric
    plan = plan_decode_placement(kv_fabric(), hit_mass=0.7)
    assert plan.location == "soc_cache"
    assert plan.rate > plan.baseline_rate
    # with a cold cache the host path wins
    cold = plan_decode_placement(kv_fabric(), hit_mass=0.0)
    assert cold.location == "host"
    assert cold.rate == pytest.approx(cold.baseline_rate)


# ----------------------------------------------------------------------
# TPU fabric construction
# ----------------------------------------------------------------------

def test_enumerate_paths_returns_fabric():
    fabric = enumerate_paths({"pod": 2, "data": 16, "model": 16})
    assert isinstance(fabric, Fabric)
    assert set(fabric) == {"dcn:pod", "ici:data", "ici:model", "pcie:host"}
    assert fabric["ici:data"].axis == "data"
    assert fabric["dcn:pod"].kind == "dcn"
    # mapping protocol: dict-style consumers keep working
    assert "pcie:host" in fabric and len(fabric) == 4
    assert fabric["pcie:host"].bw == fabric["pcie:host"].capacity


def test_fabric_rejects_duplicates_and_bad_units():
    with pytest.raises(FabricError):
        Fabric.of(Path("x", 1.0), Path("x", 2.0))
    with pytest.raises(FabricError):
        Path("y", 1.0, units="widgets/s")
    with pytest.raises(FabricError):
        Path("z", 0.0)
