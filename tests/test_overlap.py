"""Bucketed DDP overlap — per-layer-group gradient transfers issued
during backward (ISSUE 9):

  (a) ``ClusterTimeModel.bucket_plan`` splits the step cost into K
      slices whose plain sums are *exactly* the step totals;
  (b) the overlap win is emergent: K>=4 beats single-shot allreduce by
      >= 20% on the comm-bound headline config, and degrades to ~K=1
      cost when the network is idle-fast;
  (c) the numeric stream is bit-identical for every K, including
      through a mid-bucket failure + checkpoint resume;
  (d) the ledger conserves with K buckets in flight, across
      pause/resume and pod-leader trunk traffic;
  (e) the straggler loop closes into real data: rebalanced shares
      become per-node microbatch counts in the jitted step.
"""
import math

import jax
import pytest

from repro.core.fabric import OUT, IN
from repro.train.cluster import (BucketSlice, ClusterTimeModel,
                                 TrainCluster, train_fabric)

from tests.test_cluster import _assert_clean_ledger

NODES = 2
#: the headline comm-bound config: comm ~ compute on the v5e fabric
HEADLINE = dict(compute_s=0.6, grad_bytes=2e9)


def _cluster(buckets, steps=4, nodes=NODES, fabric_kw=None, tm_kw=None,
             **cluster_kw):
    tm = ClusterTimeModel(buckets=buckets, **{**HEADLINE, **(tm_kw or {})})
    fab = train_fabric(nodes, **(fabric_kw or {}))
    cluster = TrainCluster(nodes, tm, fabric=fab, **cluster_kw)
    summary = cluster.run(steps)
    return cluster, summary["sim_seconds"] / summary["steps"]


# ----------------------------------------------------------------------
# (a) the bucket plan
# ----------------------------------------------------------------------

def test_bucket_plan_sums_exactly_to_step_totals():
    tm = ClusterTimeModel(compute_s=0.7310391, grad_bytes=3.7e9 / 7)
    for k in (1, 2, 3, 5, 8, 16):
        plan = tm.bucket_plan(k)
        assert len(plan) == k
        assert sum(sl.compute_s for sl in plan) == tm.compute_s
        assert sum(sl.grad_bytes for sl in plan) == tm.grad_bytes
        assert all(sl.compute_s >= 0 and sl.grad_bytes >= 0 for sl in plan)


def test_bucket_plan_weighted_split_is_exact_and_ordered():
    tm = ClusterTimeModel(compute_s=1.0, grad_bytes=1e10)
    plan = tm.bucket_plan(3, weights=[4.0, 1.0, 1.0])
    assert sum(sl.compute_s for sl in plan) == tm.compute_s
    assert sum(sl.grad_bytes for sl in plan) == tm.grad_bytes
    # the heavy first layer group gets ~4/6 of the cost
    assert plan[0].grad_bytes == pytest.approx(4e10 / 6, rel=1e-9)
    assert plan[0].compute_s > plan[1].compute_s


def test_bucket_plan_defaults_to_time_model_buckets():
    tm = ClusterTimeModel(compute_s=0.4, grad_bytes=8e9, buckets=4)
    plan = tm.bucket_plan()
    assert len(plan) == 4
    for sl in plan:                        # uniform to within one ulp
        assert sl.compute_s == pytest.approx(0.1, rel=1e-12)
        assert sl.grad_bytes == pytest.approx(2e9, rel=1e-12)


def test_bucket_plan_validation():
    tm = ClusterTimeModel(compute_s=0.4, grad_bytes=8e9)
    with pytest.raises(ValueError, match="k >= 1"):
        tm.bucket_plan(0)
    with pytest.raises(ValueError, match="positive weights"):
        tm.bucket_plan(2, weights=[1.0])
    with pytest.raises(ValueError, match="positive weights"):
        tm.bucket_plan(2, weights=[1.0, -1.0])
    with pytest.raises(ValueError, match="buckets"):
        ClusterTimeModel(compute_s=0.4, grad_bytes=8e9, buckets=0)


def test_from_config_threads_buckets():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    cfg = get_config("internlm2-1.8b").reduced()
    tm = ClusterTimeModel.from_config(cfg, ShapeConfig("t", 128, 8, "train"),
                                      nodes=2, buckets=4)
    assert tm.buckets == 4 and len(tm.bucket_plan()) == 4


# ----------------------------------------------------------------------
# (b) the emergent overlap win
# ----------------------------------------------------------------------

def test_bucketed_overlap_beats_single_shot_by_20_percent():
    _, t1 = _cluster(1)
    _, t4 = _cluster(4)
    win = 1.0 - t4 / t1
    assert win >= 0.20, f"K=4 overlap win {win:.1%} < 20%"
    # more buckets hide more comm (up to the per-bucket latency tax)
    _, t2 = _cluster(2)
    assert t1 > t2 > t4


def test_idle_fast_network_degrades_to_single_shot_cost():
    fast = dict(host_bw=400e9, net_bw_per_node=400e9)
    _, t1 = _cluster(1, fabric_kw=fast)
    _, t4 = _cluster(4, fabric_kw=fast)
    # nothing to hide: bucketing must cost at most a few percent
    # (K extra path latencies), never help or hurt materially
    assert abs(t4 / t1 - 1.0) < 0.05, (t1, t4)


def test_bucket_timeline_records_overlap():
    steps, k = 3, 4
    cluster, _ = _cluster(k, steps=steps)
    tl = cluster.bucket_timeline
    assert len(tl) == steps * k
    per_step = {}
    for r in tl:
        assert r["t_issue"] is not None and r["t_done"] > r["t_issue"]
        per_step.setdefault(r["step"], []).append(r)
    for recs in per_step.values():
        recs.sort(key=lambda r: r["bucket"])
        assert [r["bucket"] for r in recs] == list(range(k))
        # the overlap itself: bucket 0 is already in flight before the
        # last bucket is issued (comm under later backward slices)
        assert recs[0]["t_issue"] < recs[-1]["t_issue"]
        assert recs[0]["t_done"] > recs[1]["t_issue"]


def test_single_shot_path_has_no_bucket_machinery():
    cluster, _ = _cluster(1)
    assert cluster.bucket_timeline == []
    assert cluster._bucket_barriers == []


# ----------------------------------------------------------------------
# (d) ledger conservation with K buckets in flight
# ----------------------------------------------------------------------

def test_ledger_conserves_with_inflight_buckets():
    cluster, _ = _cluster(4, steps=5, nodes=3,
                          tm_kw=dict(ckpt_bytes=4e9), ckpt_every=2)
    _assert_clean_ledger(cluster)


def test_bucketed_pause_resume_drains_at_chunk_boundary():
    """An admission pause in drain mode lands mid-bucket at the next
    chunk boundary; the run completes with the deferral visible in
    simulated time and the ledger conserved."""
    def run(paused):
        tm = ClusterTimeModel(buckets=4, chunk_bytes=2.5e8, **HEADLINE)
        cluster = TrainCluster(NODES, tm, fabric=train_fabric(NODES))
        rt = cluster.runtime
        if paused:
            rt.clock.schedule(0.9, lambda: cluster.pause_transfers(
                cancel=False))
            rt.clock.schedule(1.9, cluster.resume_transfers)
        cluster.begin(3)
        rt.clock.run(stop=lambda: cluster.done)
        return cluster, cluster.finish()

    base, s0 = run(paused=False)
    paused, s1 = run(paused=True)
    kinds = [e["event"] for e in s1["events"]]
    assert kinds == ["transfers_paused", "transfers_resumed"]
    assert s1["events"][0]["mode"] == "drain"
    assert s1["steps"] == s0["steps"] == 3
    # the pause deferred roughly the pause window, losing no work
    assert s1["sim_seconds"] > s0["sim_seconds"] + 0.5
    _assert_clean_ledger(base)
    _assert_clean_ledger(paused)


def test_bucketed_pause_cancel_reissues_and_conserves():
    tm = ClusterTimeModel(buckets=4, **HEADLINE)
    cluster = TrainCluster(NODES, tm, fabric=train_fabric(NODES))
    rt = cluster.runtime
    rt.clock.schedule(0.8, cluster.pause_transfers)       # cancel mode
    rt.clock.schedule(1.8, cluster.resume_transfers)
    cluster.begin(3)
    rt.clock.run(stop=lambda: cluster.done)
    summary = cluster.finish()
    assert summary["steps"] == 3
    _assert_clean_ledger(cluster)


def test_pod_leader_bucketed_trunk_conserves():
    """2 pods x 2 nodes, thin trunk, K=4: per-bucket leader rings share
    the trunk concurrently; afterwards every trunk reservation is
    conserved and the bucketed run still beats single-shot."""
    from repro.train.pods import TRUNK, pod_cluster

    def run(k):
        tm = ClusterTimeModel(compute_s=0.6, grad_bytes=5e8, buckets=k)
        c = pod_cluster(2, 2, tm, sync="compressed", trunk_bw=25e9)
        s = c.run(4)
        assert c.runtime.ledger.reserved(TRUNK, OUT) == pytest.approx(0.0)
        _assert_clean_ledger(c)
        return s["sim_seconds"] / s["steps"]

    t1, t4 = run(1), run(4)
    assert t4 < t1, (t1, t4)


# ----------------------------------------------------------------------
# (c) numeric stream: bit-identical for every K
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def numeric_pieces():
    from repro.configs import RunConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    from repro.train.train_step import make_train_step
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=3e-3, warmup_steps=2, total_steps=12)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"),
                      static_argnames=("node_shares",))
    pipeline = TokenPipeline(cfg, shape, seed=0)
    return cfg, step_fn, pipeline


def _numeric_cluster(pieces, buckets, *, ckpt_dir=None, fail_at=None,
                     **kw):
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    cfg, step_fn, pipeline = pieces
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e8,
                          ckpt_bytes=1e8 if ckpt_dir else 0.0,
                          tokens_per_step=4 * 32, buckets=buckets)
    return TrainCluster(
        2, tm, step_fn=step_fn, params=params, opt_state=adamw_init(params),
        batch_at=pipeline.batch_at,
        ckpt=CheckpointManager(str(ckpt_dir), every=4, keep=3)
        if ckpt_dir else None,
        ckpt_every=4 if ckpt_dir else 0,
        heartbeat_every=0.2, heartbeat_timeout=1.0, fail_at=fail_at, **kw)


def test_losses_bit_identical_across_bucket_counts(numeric_pieces):
    losses = {}
    for k in (1, 2, 4, 8):
        c = _numeric_cluster(numeric_pieces, k)
        c.run(6)
        losses[k] = [h["loss"] for h in c.history]
    assert all(len(v) == 6 for v in losses.values())
    for k in (2, 4, 8):
        assert losses[k] == losses[1], k   # bit-identical, not approx


def test_failure_mid_bucket_resumes_bit_identical(tmp_path, numeric_pieces):
    """A node silenced mid-run under K=4: detect -> resize -> restore,
    then the loss curve matches an uninterrupted K=1 run bit for bit —
    bucketing and failure handling never touch the numeric stream."""
    ref = _numeric_cluster(numeric_pieces, 1, ckpt_dir=tmp_path / "ref")
    ref.run(10)
    fl = _numeric_cluster(numeric_pieces, 4, ckpt_dir=tmp_path / "fl",
                          fail_at=("node1", 6))
    summary = fl.run(10)
    kinds = [e["event"] for e in summary["events"]]
    assert kinds == ["node_silent", "failure_detected", "elastic_resize"]
    assert summary["events"][2]["resume_step"] == 5
    assert summary["nodes"] == 1 and summary["buckets"] == 4
    # every bucket subprocess was torn down with its parent
    assert all(bp.done for n in fl.nodes for bp in n.subprocs)
    ref_losses = {h["step"]: h["loss"] for h in ref.history}
    fl_losses = {h["step"]: h["loss"] for h in fl.history}
    assert sorted(fl_losses) == sorted(ref_losses) == list(range(10))
    for k in ref_losses:
        assert fl_losses[k] == ref_losses[k], k
    _assert_clean_ledger(fl)


# ----------------------------------------------------------------------
# (e) straggler shares -> real per-node microbatch counts
# ----------------------------------------------------------------------

def test_microbatch_shares_equal_without_straggler():
    from repro.ft.straggler import StragglerDetector
    det = StragglerDetector()
    det.observe("node0", 1.0)
    det.observe("node1", 1.05)
    assert det.microbatch_shares(["node0", "node1"], 2) == (2, 2)


def test_microbatch_shares_skew_toward_fast_nodes():
    from repro.ft.straggler import StragglerDetector
    det = StragglerDetector()
    for _ in range(6):
        det.observe("node0", 1.0)
        det.observe("node1", 4.0)
    assert "node1" in det.stragglers()
    shares = det.microbatch_shares(["node0", "node1"], 2)
    assert sum(shares) == 4 and shares[0] > shares[1] >= 1
    # a dead node's stale EMA must not absorb shares
    det.observe("node2", 0.1)
    shares = det.microbatch_shares(["node0", "node1"], 2)
    assert sum(shares) == 4


def test_split_by_shares_partitions_the_batch():
    import numpy as np
    from repro.train.train_step import split_by_shares
    batch = {"tokens": np.arange(8 * 3).reshape(8, 3)}
    subs = split_by_shares(batch, (3, 1))
    assert subs[0]["tokens"].shape == (6, 3)
    assert subs[1]["tokens"].shape == (2, 3)
    assert (np.concatenate([s["tokens"] for s in subs])
            == batch["tokens"]).all()
    with pytest.raises(ValueError, match="does not split"):
        split_by_shares(batch, (3, 2))
    with pytest.raises(ValueError, match=">= 1"):
        split_by_shares(batch, (4, 0))


def test_equal_shares_bit_identical_skewed_same_mean(numeric_pieces):
    cfg, step_fn, pipeline = numeric_pieces
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = pipeline.batch_at(0)
    step = jax.numpy.asarray(0)
    _, _, base = step_fn(params, opt, batch, step)
    _, _, eq = step_fn(params, opt, batch, step, node_shares=(2, 2))
    assert float(eq["loss"]) == float(base["loss"])   # bit-identical
    _, _, sk = step_fn(params, opt, batch, step, node_shares=(3, 1))
    # same global mean, different association/shapes: close, not equal
    assert float(sk["loss"]) == pytest.approx(float(base["loss"]), rel=1e-4)


def test_cluster_routes_skewed_shares_into_step(numeric_pieces):
    c = _numeric_cluster(numeric_pieces, 4, skew_batches=True,
                         microbatches_per_node=2,
                         node_compute_scale={"node1": 6.0})
    c.run(6)
    shares = [tuple(h["microbatch_shares"]) for h in c.history]
    # the detector closes within the first step: EMAs exist by the
    # first barrier release, so the slow node's share shrinks
    assert any(s[0] > s[1] for s in shares), shares
    assert all(sum(s) == 4 for s in shares)
    assert all(math.isfinite(h["loss"]) for h in c.history)


def test_skew_batches_equal_fleet_is_bit_identical(numeric_pieces):
    plain = _numeric_cluster(numeric_pieces, 2)
    plain.run(5)
    skew = _numeric_cluster(numeric_pieces, 2, skew_batches=True,
                            microbatches_per_node=2)
    skew.run(5)
    assert all(tuple(h["microbatch_shares"]) == (2, 2)
               for h in skew.history)
    assert [h["loss"] for h in skew.history] \
        == [h["loss"] for h in plain.history]


# ----------------------------------------------------------------------
# CLI smoke: --buckets through the launcher
# ----------------------------------------------------------------------

def test_launcher_simulate_buckets_smoke(capsys):
    from repro.launch.train import main
    cluster = main(["--arch", "internlm2-1.8b", "--shape", "train_4k",
                    "--steps", "3", "--simulate", "2", "--buckets", "4",
                    "--ckpt-every", "0"])
    out = capsys.readouterr().out
    assert "overlap win" in out and "bucket 3" in out
    assert cluster.tm.buckets == 4
    assert len(cluster.bucket_timeline) == 3 * 4
