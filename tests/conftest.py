import os
import sys

# tests run on the default single CPU device (the dry-run's 512-device
# override is local to repro/launch/dryrun.py; multi-device checks run in
# a subprocess — see test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")
