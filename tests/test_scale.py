"""Million-user serving (scale/): arrivals, fleet, autoscaling, and the
K-tenant arbitration + chunked-preemption satellites."""
import jax
import numpy as np
import pytest

from repro.core.fabric import Fabric, IN, OUT, Path
from repro.core.runtime import FabricRuntime
from repro.scale import (ArrivalGenerator, AutoscaleConfig, Autoscaler,
                         Burst, LengthSpec, ReplicaPool, ServeFleet,
                         FleetTenantSpec, TraceSpec, burst_trace,
                         headline_fleet, ttft_attainment)
from repro.serve.engine import Request, ServeTimeModel, StagedServeEngine
from repro.serve.engine import _EngineCore
from repro.tenancy import (AdmittedTenant, FleetAdmissionController, LATENCY,
                           occupancy_ledger)


# ----------------------------------------------------------------------
# arrivals: determinism, rate tracking, heavy tails
# ----------------------------------------------------------------------

def test_arrival_generator_deterministic():
    """Same (spec, seed) -> byte-identical request sequence; a different
    seed -> a different one."""
    spec = burst_trace(base_rate=2.0, duration=60.0)
    a = ArrivalGenerator(spec, seed=3).requests()
    b = ArrivalGenerator(spec, seed=3).requests()
    assert len(a) == len(b) > 50
    for x, y in zip(a, b):
        assert x.rid == y.rid and x.arrival == y.arrival
        assert x.max_new_tokens == y.max_new_tokens
        assert np.array_equal(x.prompt, y.prompt)
    c = ArrivalGenerator(spec, seed=4).requests()
    assert [r.arrival for r in c] != [r.arrival for r in a]


def test_arrival_rate_tracks_burst():
    """Thinning reproduces the rate curve: the burst window sees ~10x
    the off-burst arrival density."""
    spec = burst_trace(base_rate=4.0, duration=120.0, burst_start=30.0,
                       burst_duration=45.0, burst_multiplier=10.0,
                       diurnal_amplitude=0.0)
    arrivals = [r.arrival for r in ArrivalGenerator(spec, seed=0)]
    in_burst = sum(1 for t in arrivals if 30.0 <= t < 75.0) / 45.0
    outside = sum(1 for t in arrivals if not 30.0 <= t < 75.0) / 75.0
    assert in_burst / outside == pytest.approx(10.0, rel=0.25)
    # total volume matches the integral of the rate curve
    expected = spec.mean_rate * spec.duration
    assert len(arrivals) == pytest.approx(expected, rel=0.15)


def test_heavy_tail_length_sampling():
    """Lognormal lengths: median near spec median, a genuinely heavy
    right tail, hard clamps respected."""
    ls = LengthSpec(median=24, sigma=0.6, low=8, high=96)
    rng = np.random.default_rng(0)
    xs = np.array([ls.sample(rng) for _ in range(4000)])
    assert np.median(xs) == pytest.approx(24, rel=0.15)
    assert np.percentile(xs, 99) > 2.0 * np.median(xs)
    assert xs.min() >= 8 and xs.max() <= 96


def test_trace_rate_and_peak():
    spec = TraceSpec("t", base_rate=2.0, duration=100.0,
                     diurnal_amplitude=0.5, diurnal_period=100.0,
                     bursts=(Burst(10.0, 20.0, 5.0),))
    assert spec.rate(15.0) == pytest.approx(
        2.0 * (1 + 0.5 * np.sin(2 * np.pi * 15.0 / 100.0)) * 5.0)
    assert spec.rate(50.0) == pytest.approx(2.0)   # sin(pi) = 0, no burst
    grid = np.linspace(0.0, 99.9, 1500)
    assert spec.peak_rate >= max(spec.rate(t) for t in grid) - 1e-9
    with pytest.raises(ValueError):
        TraceSpec("bad", base_rate=0.0, duration=10.0)
    with pytest.raises(ValueError):
        Burst(0.0, -1.0, 2.0)


# ----------------------------------------------------------------------
# decode replica pool mechanics
# ----------------------------------------------------------------------

def _sim_engine(rt, tm, **kw):
    return StagedServeEngine(None, None, compute="sim", runtime=rt,
                             time_model=tm, **kw)


def _reqs(n, spacing=0.2, tokens=4, plen=8):
    rng = np.random.default_rng(5)
    return [Request(rid=i, prompt=rng.integers(1, 1000, plen).astype(np.int32),
                    max_new_tokens=tokens, arrival=spacing * i)
            for i in range(n)]


def _pool_fabric():
    return Fabric.of(Path("pf", 100.0), Path("dec", 50.0),
                     Path("rep:0", 50.0), Path("rep:1", 50.0))


def test_pool_fallback_matches_direct_decode_timing():
    """With no extra replicas the pool is behaviorally the plain decode
    path: same TTFTs, same finish times, same tokens."""
    tm = ServeTimeModel("pf", "dec", 1.0, 2.0)
    done = {}
    for pool in (False, True):
        rt = FabricRuntime(_pool_fabric())
        eng = _sim_engine(rt, tm, decode_pool=pool)
        for r in _reqs(8):
            eng.submit(r)
        served = eng.run()
        done[pool] = sorted(
            (r.rid, r.ttft, r.finish_time, tuple(r.out_tokens))
            for r in served)
    assert done[False] == done[True]


def test_scale_events_keep_tokens_bit_identical():
    """Scaling out mid-run and retiring mid-flight (transfer cancel +
    remainder re-queue) never changes any request's token stream."""
    tm = ServeTimeModel("pf", "dec", 1.0, 2.0)
    base_rt = FabricRuntime(_pool_fabric())
    base = _sim_engine(base_rt, tm, decode_pool=True)
    for r in _reqs(12):
        base.submit(r)
    want = {r.rid: list(r.out_tokens) for r in base.run()}

    rt = FabricRuntime(_pool_fabric())
    eng = _sim_engine(rt, tm, decode_pool=True)
    for r in _reqs(12):
        eng.submit(r)
    rt.clock.at(0.3, lambda: eng.add_decode_replica("rep:0"))
    rt.clock.at(0.6, lambda: eng.add_decode_replica("rep:1"))
    rt.clock.at(1.0, eng.retire_decode_replica)
    rt.clock.at(1.6, eng.retire_decode_replica)
    served = eng.run()
    got = {r.rid: list(r.out_tokens) for r in served}
    assert got == want
    # and the stream is the pure (rid, i) hash — scheduling can only
    # reorder time, not bytes
    for rid, toks in got.items():
        assert toks == [_EngineCore._sim_token(rid, i)
                        for i in range(len(toks))]
    assert [e["event"] for e in eng.scale_events] == \
        ["scale_out", "scale_out", "scale_in", "scale_in"]
    for p in rt.fabric:
        for d in (OUT, IN):
            assert rt.ledger.reserved(p, d) == pytest.approx(0.0, abs=1e-9)


@pytest.fixture(scope="module")
def small_lm():
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_jax_engine_tokens_survive_scale_events(small_lm):
    """The real-model engine under the replica pool: greedy tokens are
    bit-identical with and without a scale-out/scale-in cycle."""
    cfg, params = small_lm
    tm = ServeTimeModel("pf", "dec", 0.5, 0.5)

    def run(scale):
        rt = FabricRuntime(_pool_fabric())
        eng = StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                                runtime=rt, time_model=tm, decode_pool=True)
        rng = np.random.default_rng(11)
        for i in range(4):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                max_new_tokens=4, arrival=0.1 * i))
        if scale:
            rt.clock.at(0.15, lambda: eng.add_decode_replica("rep:0"))
            rt.clock.at(0.5, eng.retire_decode_replica)
        return {r.rid: list(r.out_tokens) for r in eng.run()}

    assert run(scale=False) == run(scale=True)


# ----------------------------------------------------------------------
# autoscaler
# ----------------------------------------------------------------------

def test_replica_pool_inventory():
    pool = ReplicaPool(["a", "b"])
    assert pool.capacity == 2 and pool.free == 2
    assert pool.acquire() == "a" and pool.acquire() == "b"
    assert pool.acquire() is None
    pool.release("a")
    with pytest.raises(ValueError):
        pool.release("a")
    assert pool.acquire() == "a"


def test_autoscale_config_validation():
    with pytest.raises(ValueError):
        AutoscaleConfig(target_attainment=0.0)
    with pytest.raises(ValueError):
        AutoscaleConfig(window_s=0.0)
    with pytest.raises(ValueError):
        AutoscaleConfig(max_replicas=0)
    assert ttft_attainment([], 0.5) == 1.0
    assert ttft_attainment([0.1, 0.9], 0.5) == 0.5


def test_autoscaler_no_flapping_on_steady_load():
    """Hysteresis: a fleet comfortably inside capacity never scales."""
    spec = FleetTenantSpec(
        name="steady",
        trace=TraceSpec(name="flat", base_rate=2.0, duration=40.0,
                        diurnal_amplitude=0.1, diurnal_period=40.0),
        slo_ttft=0.5, weight=4.0, seed=2)
    fleet = ServeFleet([spec], host_bw=1400.0)
    rep = fleet.run(autoscale=True, max_sim_seconds=500.0)
    tr = rep.tenants["steady"]
    assert tr.scale_events == [] and tr.autoscaler_events == []
    assert tr.attainment == 1.0


def test_autoscaler_scales_out_then_back_in():
    """The burst triggers scale-out; the quiet tail after it triggers
    scale-in (cooldowns bound the churn)."""
    fleet = headline_fleet()
    rep = fleet.run(autoscale=True, max_sim_seconds=2000.0)
    ev = rep.tenants["premium"].scale_events
    outs = [e for e in ev if e["event"] == "scale_out"]
    ins = [e for e in ev if e["event"] == "scale_in"]
    assert len(outs) >= 1 and len(ins) >= 1
    assert len(ev) <= 20                      # bounded churn, no flapping
    assert rep.tenants["premium"].peak_replicas >= 2
    # every replica went back to the shared pool
    assert fleet.pool.free == fleet.pool.capacity


def test_headline_attainment_static_vs_autoscaled():
    """The PR headline: under the 10x diurnal burst the autoscaled
    fleet holds >= 95% TTFT attainment for the latency tenant where the
    static fleet drops below 70% — with bit-identical token streams."""
    runs = {}
    for mode in (False, True):
        fleet = headline_fleet()
        runs[mode] = (fleet, fleet.run(autoscale=mode,
                                       max_sim_seconds=2000.0))
    static, auto = runs[False][1], runs[True][1]
    assert static.attainment("premium") < 0.70
    assert auto.attainment("premium") >= 0.95
    for name in ("premium", "standard"):
        a = {r.rid: list(r.out_tokens) for r in runs[False][0].served[name]}
        b = {r.rid: list(r.out_tokens) for r in runs[True][0].served[name]}
        assert a == b and len(a) > 0
    # quiescent fleet: the shared ledger conserves on every path/dir
    for mode, (fleet, _) in runs.items():
        for p in fleet.runtime.fabric:
            for d in (OUT, IN):
                assert fleet.runtime.ledger.reserved(p, d) == \
                    pytest.approx(0.0, abs=1e-6), (mode, p, d)


# ----------------------------------------------------------------------
# K-tenant admission arbitration
# ----------------------------------------------------------------------

class _FakeEngine:
    def __init__(self):
        self.ttft_log = []
        self.prefill_backlog = 0


def test_fleet_admission_priority_order():
    """Violation at the top tenant defers lower tenants lowest-first
    (one per tick); recovery resumes them LIFO."""
    rt = FabricRuntime(Fabric.of(Path("p", 1.0)))
    top = _FakeEngine()
    log = []
    tenants = [
        AdmittedTenant(name="low", priority=0,
                       pause=lambda: log.append("pause:low"),
                       resume=lambda: log.append("resume:low")),
        AdmittedTenant(name="mid", priority=1,
                       pause=lambda: log.append("pause:mid"),
                       resume=lambda: log.append("resume:mid")),
        AdmittedTenant(name="top", priority=2, slo_ttft=0.1, engine=top),
    ]
    ctl = FleetAdmissionController(rt, tenants, check_every=0.01).start()
    top.prefill_backlog = 1
    top.ttft_log.append((0.0, 0.5))          # violated from the start
    rt.clock.at(0.05, lambda: setattr(top, "prefill_backlog", 0))  # recover
    rt.clock.run(until=0.2)
    ctl.stop()
    assert log == ["pause:low", "pause:mid", "resume:mid", "resume:low"]
    assert [e["event"] for e in ctl.events] == \
        ["throttle", "throttle", "resume", "resume"]
    assert all(e.get("offender", "top") == "top" for e in ctl.events)
    assert ctl.paused_tenants == []


def test_fleet_arbitration_defers_without_loss():
    """In a live fleet: the premium burst pauses the standard tenant's
    intake; every standard request is still served afterwards with
    formula-identical tokens (deferral, not loss)."""
    specs = [
        FleetTenantSpec(
            name="premium",
            trace=burst_trace(base_rate=2.0, duration=40.0,
                              burst_multiplier=10.0, burst_start=8.0,
                              burst_duration=16.0, diurnal_amplitude=0.25),
            slo_ttft=0.4, weight=8.0, priority=1, seed=7),
        FleetTenantSpec(
            name="standard",
            trace=TraceSpec(name="steady", base_rate=2.0, duration=40.0,
                            diurnal_amplitude=0.25, diurnal_period=40.0),
            slo_ttft=2.0, weight=1.0, priority=0, seed=11),
    ]
    fleet = ServeFleet(specs, host_bw=1400.0, arbitration=True)
    rep = fleet.run(autoscale=False, max_sim_seconds=2000.0)
    throttles = [e for e in rep.admission_events if e["event"] == "throttle"]
    assert throttles and all(e["victim"] == "standard" and
                             e["offender"] == "premium" for e in throttles)
    assert any(e["event"] == "resume" for e in rep.admission_events)
    expected = len(ArrivalGenerator(specs[1].trace, seed=11).requests())
    served = fleet.served["standard"]
    assert len(served) == expected > 0
    for r in served:
        assert list(r.out_tokens) == [
            _EngineCore._sim_token(r.rid, i)
            for i in range(len(r.out_tokens))]


# ----------------------------------------------------------------------
# tenant-aware placement (occupancy attribution -> planner)
# ----------------------------------------------------------------------

def test_placement_flips_on_other_tenants_occupancy():
    """plan_decode_placement(occupancy=..., tenant=...) treats *other*
    tenants' measured occupancy as external reservations and excludes
    the tenant's own traffic."""
    from repro.serve.disagg import kv_fabric, plan_decode_placement
    fabric = kv_fabric()
    fresh = plan_decode_placement(fabric)
    assert fresh.location == "soc_cache"
    crowded = {"soc_read": {"train": 0.97}}
    plan = plan_decode_placement(fabric, occupancy=crowded, tenant="serve")
    assert plan.location == "host" and plan.rate < fresh.rate
    # the same fraction attributed to the tenant itself is ignored
    own = {"soc_read": {"serve": 0.97}}
    plan2 = plan_decode_placement(fabric, occupancy=own, tenant="serve")
    assert plan2.location == "soc_cache"
    assert plan2.rate == pytest.approx(fresh.rate)


def test_occupancy_ledger_clamps_and_skips():
    fabric = Fabric.of(Path("a", 100.0), Path("b", 10.0))
    led = occupancy_ledger(
        fabric,
        {"a": {"t1": 0.6, "t2": 0.8}, "missing": {"t1": 1.0},
         "b": {"me": 0.5}},
        exclude=("me",))
    assert led.reserved("a", OUT) == pytest.approx(100.0)   # clamped to cap
    assert led.reserved("b", OUT) == pytest.approx(0.0)     # own traffic


# ----------------------------------------------------------------------
# runtime at O(1k) concurrent transfers
# ----------------------------------------------------------------------

def test_ledger_conserves_under_1k_concurrent_transfers():
    """1.2k concurrent transfers across shared paths: reservations never
    exceed any path's capacity while live, and every (path, direction)
    returns to zero at quiescence."""
    fab = Fabric.of(*[Path(f"p{i}", 100.0) for i in range(4)],
                    concurrency_discount=0.1)
    rt = FabricRuntime(fab)
    rng = np.random.default_rng(0)
    ts = [rt.transfer(f"p{int(rng.integers(4))}", float(rng.uniform(1, 30)),
                      flow=f"f{i % 7}", tenant=f"t{i % 3}")
          for i in range(1200)]

    def probe():
        for p in fab:
            assert rt.ledger.reserved(p, OUT) <= fab[p].capacity + 1e-6

    rt.clock.at(0.05, probe)
    ev0 = rt.clock.processed
    rt.clock.run()
    assert all(t.done and not t.canceled for t in ts)
    assert rt.clock.processed - ev0 >= len(ts)
    for p in fab:
        for d in (OUT, IN):
            assert rt.ledger.reserved(p, d) == pytest.approx(0.0, abs=1e-6)
