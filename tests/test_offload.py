"""The SoC compute tier (ISSUE 6) — acceptance assertions:

  (a) the host-vs-SoC compression-offload crossover *emerges* from
      scheduling: soc-compress beats host-compress when the host side
      is loaded and loses to it idle;
  (b) compressed checkpoint bytes are bit-identical across host/SoC
      placement (placement moves cycles, never bytes);
  (c) compute-ledger conservation holds on every resource across
      reserve/cancel/complete/rebalance, weighted or not, and all-equal
      weights reduce to the equal split (mirror of the transfer
      properties in test_tenancy.py);
  (d) QoS-weighted static plans (MultipathRouter.allocate(qos=)) agree
      with the converged runtime shares under tenancy.

Plus coverage for the satellites: compute-aware choose_staging /
ckpt_path="auto" with compress-then-stage options, the DrTM-KV filter
offload and its placement flip under load, device rooflines, and the
smartnic-idiom OffloadStats.
"""
import math
import os

import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, StagingOption,
                                   load_checkpoint, save_checkpoint)
from repro.core.fabric import (Alternative, DCA, Fabric, FabricError, IN,
                               MultipathRouter, OPS_PER_S, OUT, Path, Use,
                               compute_path, dca_path)
from repro.core.runtime import Compute, FabricRuntime
from repro.offload import (BF2_ARM, BF2_DCA, HOST_CPU, DeviceSpec, KVFilter,
                           HOST_FILTER, SOC_FILTER, OffloadProgram,
                           OffloadStats, SoCCompressor, host_compressor,
                           kv_filter_alternatives, plan_filter_placement)
from repro.serve.disagg import DisaggKV, KVStoreParams
from repro.tenancy.qos import (OFFLOAD, QoSPolicy, SERVE, TRAIN, THROUGHPUT,
                               Tenant)
from repro.train.cluster import (ClusterTimeModel, HOST_COMPRESS,
                                 SOC_COMPRESS, TrainCluster, train_fabric)


def _clean_ledger(runtime, external_flows=()):
    """Every reservation is back, on every path and direction, except
    the declared external flows (same invariant as test_tenancy)."""
    led = runtime.ledger
    for name in runtime.fabric:
        for direction in (OUT, IN):
            reserved = led.reserved(name, direction)
            external = sum((o if direction == OUT else i)
                           for (flow, pname), (o, i) in led._by_flow.items()
                           if pname == name and flow in external_flows)
            assert reserved == pytest.approx(external, abs=1e-6), \
                (name, direction, reserved)


# ----------------------------------------------------------------------
# the Compute primitive (tentpole core)
# ----------------------------------------------------------------------

def test_compute_primitive_validation_and_occupancy():
    fab = Fabric.of(Path("wire", 100.0), compute_path("dev", 50.0))
    rt = FabricRuntime(fab)
    with pytest.raises(FabricError, match="not a compute resource"):
        rt.compute("wire", 10.0)
    with pytest.raises(FabricError, match="unknown compute resource"):
        rt.compute("gone", 10.0)
    with pytest.raises(FabricError, match=f"no {IN} budget"):
        rt.transfer("dev", 10.0, direction=IN)   # compute paths have no IN
    c = rt.compute("dev", 100.0, tenant=OFFLOAD)
    seen = {}
    rt.clock.schedule(0.1, lambda: seen.update(
        occ=rt.occupancy("dev"), by=rt.occupancy("dev", by_tenant=True)))
    rt.clock.run()
    assert isinstance(c, Compute)
    assert seen["occ"] == pytest.approx(1.0)          # visible in occupancy
    assert seen["by"] == {OFFLOAD: pytest.approx(1.0)}
    assert c.done and c.ops_done == pytest.approx(100.0)
    assert c.finished_at == pytest.approx(2.0)        # 100 ops @ 50/s
    assert rt.ledger.reserved("dev", OUT) == pytest.approx(0.0, abs=1e-9)


def test_compute_fair_share_and_qos_weighting():
    """Two programs on one device split the roofline; with a QoS policy
    the split follows the tenant weights."""
    qos = QoSPolicy([Tenant("hi", weight=3.0), Tenant("lo", weight=1.0)])
    rt = FabricRuntime(Fabric.of(compute_path("dev", 100.0),
                                 concurrency_discount=0.1), qos=qos)
    hi = rt.compute("dev", 90.0, tenant="hi")
    lo = rt.compute("dev", 90.0, tenant="lo")
    seen = {}
    rt.clock.schedule(0.1, lambda: seen.update(hi=hi.rate, lo=lo.rate))
    rt.clock.run()
    eff = 100.0 * 0.9                                 # §4.1 discount emerges
    assert seen["hi"] == pytest.approx(eff * 0.75)
    assert seen["lo"] == pytest.approx(eff * 0.25)
    assert rt.ledger.reserved("dev", OUT) == pytest.approx(0.0, abs=1e-9)


def test_equal_weights_reduce_to_equal_split_on_compute():
    """All-equal weights are byte-for-byte the unweighted runtime on a
    compute resource (mirror of the transfer property)."""
    qos = QoSPolicy([Tenant(f"t{i}", weight=2.0) for i in range(3)])
    finals = {}
    for name, policy in (("plain", None), ("equal", qos)):
        rt = FabricRuntime(Fabric.of(compute_path("dev", 90.0),
                                     concurrency_discount=0.1), qos=policy)
        cs = [rt.compute("dev", 27.0 * (i + 1), tenant=f"t{i}")
              for i in range(3)]
        mid = {}
        rt.clock.schedule(1e-3, lambda cs=cs: mid.update(
            rates=[c.rate for c in cs]))
        rt.clock.run()
        if policy is not None:
            assert mid["rates"] == pytest.approx([90.0 * 0.9 / 3] * 3)
        finals[name] = [c.finished_at for c in cs]
    assert finals["plain"] == finals["equal"]


@pytest.mark.parametrize("weights,ops,disc,cancel_idx", [
    ((1.0, 1.0, 1.0), (30.0, 20.0, 10.0), 0.0, None),
    ((5.0, 1.0), (100.0, 100.0), 0.125, 0),
    ((2.0, 3.0, 7.0, 0.5), (10.0, 40.0, 25.0, 5.0), 0.2, 2),
    ((8.0,), (50.0,), 0.3, None),
])
def test_compute_ledger_conserves_sweep(weights, ops, disc, cancel_idx):
    """Deterministic slice of the conservation property: mid-flight
    compute rates never exceed the effective roofline, match the ledger,
    and the ledger drains — also across a mid-flight cancel."""
    qos = QoSPolicy([Tenant(f"t{i}", weight=w) for i, w in enumerate(weights)])
    rt = FabricRuntime(Fabric.of(compute_path("dev", 100.0),
                                 concurrency_discount=disc), qos=qos)
    cs = [rt.compute("dev", amt, tenant=f"t{i}") for i, amt in enumerate(ops)]
    probes = []
    rt.clock.schedule(1e-3, lambda: probes.append(
        (sum(c.rate for c in cs if not c.done),
         rt.ledger.reserved("dev", OUT))))
    if cancel_idx is not None:
        rt.clock.schedule(2e-3, lambda: rt.cancel(cs[cancel_idx]))
    rt.clock.run()
    eff = 100.0 * ((1 - disc) if len(cs) > 1 and disc > 0 else 1.0)
    rates, reserved = probes[0]
    assert rates <= eff + 1e-6 and reserved <= eff + 1e-6
    assert rates == pytest.approx(reserved)
    assert all(c.done for c in cs)
    if cancel_idx is not None:
        assert cs[cancel_idx].canceled
    assert rt.ledger.reserved("dev", OUT) == pytest.approx(0.0, abs=1e-9)
    assert rt.ledger.reserved("dev", IN) == pytest.approx(0.0, abs=1e-9)


def test_compute_reservations_conserve_property():
    """Property (hypothesis): random weights/ops/discount, with a random
    mid-flight cancel, never over-commit the device and always drain the
    ledger — reserve/cancel/complete/rebalance conserve on every
    resource."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.floats(0.5, 8.0), st.floats(1.0, 50.0)),
                    min_size=1, max_size=5),
           st.floats(0.0, 0.3), st.integers(0, 5))
    def inner(flows, disc, cancel_at):
        qos = QoSPolicy([Tenant(f"t{i}", weight=w)
                         for i, (w, _) in enumerate(flows)])
        rt = FabricRuntime(Fabric.of(compute_path("dev", 100.0),
                                     concurrency_discount=disc), qos=qos)
        cs = [rt.compute("dev", amt, tenant=f"t{i}")
              for i, (_, amt) in enumerate(flows)]
        seen = {}

        def probe():
            seen["rates"] = sum(c.rate for c in cs if not c.done)
            seen["reserved"] = rt.ledger.reserved("dev", OUT)

        rt.clock.schedule(1e-3, probe)
        if cancel_at < len(cs):
            rt.clock.schedule(2e-3, lambda: rt.cancel(cs[cancel_at]))
        rt.clock.run()
        eff = 100.0 * (1 - disc if len(flows) > 1 and disc > 0 else 1.0)
        assert seen["rates"] <= eff + 1e-6
        assert seen["rates"] == pytest.approx(seen["reserved"])
        assert all(c.done for c in cs)
        assert rt.ledger.reserved("dev", OUT) == pytest.approx(0.0, abs=1e-6)
        assert rt.ledger.reserved("dev", IN) == pytest.approx(0.0, abs=1e-6)

    inner()


# ----------------------------------------------------------------------
# QoS-weighted allocate == converged runtime shares (satellite)
# ----------------------------------------------------------------------

def test_weighted_allocate_matches_converged_runtime_shares():
    """The static plan and the live weighted max-min agree: same fabric,
    same tenants, same discount — same rates."""
    disc = 0.1
    qos = QoSPolicy([Tenant("a", weight=3.0), Tenant("b", weight=1.0),
                     Tenant("c", weight=1.0)])
    tenants = ("a", "b", "c")

    fab = Fabric.of(Path("link", 100.0), concurrency_discount=disc)
    alts = [Alternative(t, uses=[Use("link", out=1.0)], tenant=t)
            for t in tenants]
    allocs, total = MultipathRouter(fab).allocate(alts, qos=qos)
    plan = {a.alternative: a.rate for a in allocs}
    eff = 100.0 * (1 - disc)
    assert plan["a"] == pytest.approx(eff * 0.6)
    assert plan["b"] == plan["c"] == pytest.approx(eff * 0.2)
    assert total == pytest.approx(eff)

    rt = FabricRuntime(Fabric.of(Path("link", 100.0),
                                 concurrency_discount=disc), qos=qos)
    ts = {t: rt.transfer("link", 500.0, tenant=t) for t in tenants}
    seen = {}
    rt.clock.schedule(1e-3, lambda: seen.update(
        {k: t.rate for k, t in ts.items()}))
    rt.clock.run()
    for t in tenants:
        assert seen[t] == pytest.approx(plan[t]), t


def test_weighted_allocate_compute_cap_water_fills_like_runtime():
    """A compute-capped heavy alternative's surplus goes to the lighter
    ones — the same water-filling the runtime applies via max_rate."""
    qos = QoSPolicy([Tenant("hi", weight=3.0), Tenant("lo", weight=1.0)])
    fab = Fabric.of(Path("link", 100.0))
    alts = [Alternative("hi", uses=[Use("link", out=1.0)], tenant="hi",
                        compute_rate=10.0),
            Alternative("lo", uses=[Use("link", out=1.0)], tenant="lo")]
    allocs, total = MultipathRouter(fab).allocate(alts, qos=qos)
    plan = {a.alternative: a.rate for a in allocs}
    assert plan["hi"] == pytest.approx(10.0)
    assert plan["lo"] == pytest.approx(90.0)

    rt = FabricRuntime(Fabric.of(Path("link", 100.0)), qos=qos)
    hi = rt.transfer("link", 10.0, tenant="hi", max_rate=10.0)
    lo = rt.transfer("link", 500.0, tenant="lo")
    seen = {}
    rt.clock.schedule(1e-3, lambda: seen.update(hi=hi.rate, lo=lo.rate))
    rt.clock.run()
    assert seen["hi"] == pytest.approx(plan["hi"])
    assert seen["lo"] == pytest.approx(plan["lo"])


def test_weighted_allocate_respects_demand_and_existing_holders():
    """Demand caps the aggregate; live ledger holders shrink the budget
    and trigger the discount exactly as the runtime counts them."""
    qos = QoSPolicy([Tenant("a", weight=1.0), Tenant("b", weight=1.0)])
    fab = Fabric.of(Path("link", 100.0), concurrency_discount=0.1)
    led = fab.ledger()
    led.reserve("link", out=30.0, flow="external")
    alts = [Alternative(t, uses=[Use("link", out=1.0)], tenant=t)
            for t in ("a", "b")]
    allocs, total = MultipathRouter(fab).allocate(alts, ledger=led, qos=qos)
    # 3 flows on the path -> discounted 90, minus the external 30
    assert total == pytest.approx(60.0)
    assert [a.rate for a in allocs] == pytest.approx([30.0, 30.0])
    allocs2, total2 = MultipathRouter(fab).allocate(alts, demand=10.0,
                                                    qos=qos)
    assert total2 == pytest.approx(10.0)
    assert all(a.bottleneck == "demand" for a in allocs2)
    with pytest.raises(FabricError, match="unbounded"):
        MultipathRouter(fab).allocate(
            [Alternative("free", uses=[], tenant="a")], qos=qos)


# ----------------------------------------------------------------------
# device rooflines + DCA path type
# ----------------------------------------------------------------------

def test_device_roofline_and_path_kinds():
    d = DeviceSpec("x", cores=4, ops_per_core=1e9, mem_bw=2e9)
    assert d.peak_ops == pytest.approx(4e9)
    assert d.roofline(1.0) == pytest.approx(2e9)     # memory bound
    assert d.roofline(10.0) == pytest.approx(4e9)    # compute bound
    with pytest.raises(ValueError, match="intensity"):
        d.roofline(0.0)
    with pytest.raises(ValueError, match="envelope"):
        DeviceSpec("bad", cores=0, ops_per_core=1e9, mem_bw=1e9)
    dca = BF2_DCA.path("dca:0")
    assert dca.kind == DCA and dca.is_compute and not dca.bidirectional
    assert dca.units == OPS_PER_S
    arm = BF2_ARM.path("cpu:soc:0")
    assert arm.is_compute and arm.capacity < HOST_CPU.path("h").capacity
    assert not Path("wire", 1.0).is_compute
    assert dca_path("d", 5.0).kind == DCA
    # the wimpy-SoC premise, in numbers: ARM complex far below the host
    assert BF2_ARM.roofline(1.0) < 0.3 * HOST_CPU.roofline(1.0)


def test_offload_program_pipeline_and_stats():
    """transfer-in -> compute -> transfer-out runs sequentially on one
    runtime, leaves a clean ledger, and records the smartnic-idiom
    stats."""
    fab = Fabric.of(Path("wire", 100.0), compute_path("dev", 50.0))
    rt = FabricRuntime(fab)
    stats = OffloadStats()
    prog = OffloadProgram(rt, "filt", stats=stats)
    proc = prog.launch(compute="dev", ops=100.0, in_path="wire",
                       in_bytes=200.0, out_path="wire", out_bytes=50.0)
    rt.clock.run()
    assert proc.done
    # 200/100 in + 100/50 compute + 50/100 out, strictly sequential
    assert proc.result == pytest.approx(2.0 + 2.0 + 0.5)
    s = stats.get_performance_stats()
    assert s["programs_run"] == 1 and s["ops_executed"] == pytest.approx(100.0)
    _clean_ledger(rt)


# ----------------------------------------------------------------------
# checkpoint-compression offload: bit-identical bytes (tentpole)
# ----------------------------------------------------------------------

def _tree():
    rng = np.random.default_rng(0)
    return {"w": rng.standard_normal((64, 32)).astype(np.float32),
            "b": np.arange(17, dtype=np.int32)}


def test_soc_compression_bit_identical_bytes(tmp_path):
    """A checkpoint compressed 'on the SoC' (SoCCompressor) is byte-for-
    byte the host-compressed checkpoint — placement moves the cycles,
    the accounting, and nothing else."""
    stats = OffloadStats()
    st_host = save_checkpoint(str(tmp_path / "host"), _tree(), step=3,
                              compress=True,
                              compressor=host_compressor(stats))
    st_soc = save_checkpoint(str(tmp_path / "soc"), _tree(), step=3,
                             compress=True,
                             compressor=SoCCompressor(stats=stats))
    assert st_host["stored_bytes"] == st_soc["stored_bytes"]
    import msgpack
    man = {}
    for who in ("host", "soc"):
        with open(os.path.join(tmp_path, who, "manifest.msgpack"), "rb") as f:
            man[who] = msgpack.unpackb(f.read())
    assert man["host"]["sha256"] == man["soc"]["sha256"]
    assert man["host"]["codec"] == man["soc"]["codec"] != "none"
    data = "data.npz" + {"zstd": ".zst", "zlib": ".zz"}[man["soc"]["codec"]]
    with open(os.path.join(tmp_path, "host", data), "rb") as f1, \
            open(os.path.join(tmp_path, "soc", data), "rb") as f2:
        assert f1.read() == f2.read()                 # bit-identical
    # restore from the SoC-compressed copy reproduces the tree exactly
    restored, step = load_checkpoint(str(tmp_path / "soc"), _tree())
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), _tree()["w"])
    # only the SoC run is credited as offloaded
    s = stats.get_performance_stats()
    assert s["compression_operations_offloaded"] == 1
    assert s["cpu_cycles_saved"] > 0
    assert s["compression_bytes_in"] == 2 * man["soc"]["raw_bytes"]


# ----------------------------------------------------------------------
# the crossover emerges from scheduling (tentpole acceptance)
# ----------------------------------------------------------------------

def _ckpt_cluster(mode, host_load=None, nodes=2):
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e6, ckpt_bytes=8e9,
                          ckpt_path=mode, tokens_per_step=1000)
    c = TrainCluster(nodes, tm, ckpt_every=2, host_load=host_load)
    summary = c.run(2)
    return c, summary["sim_seconds"]


def test_compression_crossover_emerges_from_scheduling():
    """Idle host side: the fat host cores + fast wire win. Loaded host
    side: the DCA codec + SoC wire win. Nothing in the cluster hardcodes
    the flip — it comes out of the shared ledger."""
    _, idle_host = _ckpt_cluster(HOST_COMPRESS)
    _, idle_soc = _ckpt_cluster(SOC_COMPRESS)
    assert idle_host < 0.9 * idle_soc, (idle_host, idle_soc)
    load = {"node0": 0.7, "node1": 0.7}
    _, busy_host = _ckpt_cluster(HOST_COMPRESS, load)
    soc_cluster, busy_soc = _ckpt_cluster(SOC_COMPRESS, load)
    assert busy_soc < 0.9 * busy_host, (busy_soc, busy_host)
    # offload accounting in the smartnic idiom: one save per node ran
    # off-host, crediting the codec ops as host cycles saved
    s = soc_cluster.offload.get_performance_stats()
    assert s["compression_operations_offloaded"] == 2
    assert s["cpu_cycles_saved"] == pytest.approx(2 * 8e9)
    assert s["compression_ratio"] == pytest.approx(0.5)
    _clean_ledger(soc_cluster.runtime,
                  external_flows={"hostload:node0", "hostload:node1"})


def test_host_compress_runs_on_host_and_credits_nothing():
    c, _ = _ckpt_cluster(HOST_COMPRESS)
    s = c.offload.get_performance_stats()
    assert s["compression_operations_offloaded"] == 0
    assert s["cpu_cycles_saved"] == 0.0
    assert s["compression_bytes_in"] == 2 * 8e9    # both saves recorded
    _clean_ledger(c.runtime)


def test_compress_staging_is_pause_safe():
    """Admission-control pause mid-codec: the Compute is canceled (its
    reservation returns), the remaining ops are re-issued after resume,
    and the save still completes — deferral, never loss."""
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=0.0, ckpt_bytes=8e9,
                          ckpt_path=SOC_COMPRESS)
    c = TrainCluster(1, tm, ckpt_every=1)
    rt = c.runtime
    rt.clock.schedule(0.3, c.pause_transfers)      # mid-DCA-compute
    rt.clock.schedule(0.6, c.resume_transfers)
    summary = c.run(1)
    assert summary["steps"] == 1
    kinds = [e["event"] for e in c.events]
    assert "transfers_paused" in kinds and "transfers_resumed" in kinds
    # the 0.3s pause is visible in the timeline (work deferred, not lost)
    assert summary["sim_seconds"] >= 0.3 + 0.8     # pause + full codec time
    assert c.offload.counters["compression_operations_offloaded"] == 1
    _clean_ledger(rt)


def test_compress_mode_requires_compute_tier_fabric():
    fab = train_fabric(1, compute_tier=False)
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=0.0, ckpt_bytes=1e9,
                          ckpt_path=SOC_COMPRESS)
    with pytest.raises(FabricError, match="compute paths"):
        TrainCluster(1, tm, fabric=fab)
    with pytest.raises(ValueError, match="ckpt_ratio"):
        ClusterTimeModel(compute_s=0.01, grad_bytes=0.0, ckpt_ratio=0.0)


# ----------------------------------------------------------------------
# compute-aware staging choice (satellite)
# ----------------------------------------------------------------------

def test_choose_staging_considers_compress_then_stage():
    """StagingOption candidates are costed per raw byte over wire AND
    compute; compress-then-stage wins exactly when both wires are
    mostly spoken for but the accelerator is idle."""
    fab = train_fabric(1)
    led = fab.ledger()
    cands = [StagingOption("host", "host:0"),
             StagingOption("soc", "soc:0"),
             StagingOption("soc-compress", "soc:0", wire_scale=0.5,
                           compute="dca:0", ops_scale=1.0)]
    # no ledger: first candidate (static preference)
    assert CheckpointManager.choose_staging(cands) == "host"
    # idle fabric: the fat host wire wins
    assert CheckpointManager.choose_staging(cands, ledger=led) == "host"
    # both wires 80% spoken for, DCA idle: compress-then-stage wins
    led.reserve("host:0", out=0.8 * fab["host:0"].capacity, flow="load-h")
    led.reserve("soc:0", out=0.8 * fab["soc:0"].capacity, flow="load-s")
    assert CheckpointManager.choose_staging(cands, ledger=led) \
        == "soc-compress"
    # plain strings still behave exactly as before (max available)
    assert CheckpointManager.choose_staging(["host:0", "soc:0"],
                                            ledger=led) == "host:0"


def test_auto_staging_picks_soc_compress_under_dual_wire_load():
    """ckpt_path='auto' on a compute-tier fabric reaches for the DCA
    when the host wire is saturated and the SoC wire is loaded enough
    that halving the staged bytes pays for the codec — visible in the
    offload accounting."""
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=0.0, ckpt_bytes=4e9,
                          ckpt_path="auto")
    c = TrainCluster(1, tm, ckpt_every=1)
    led = c.runtime.ledger
    led.reserve("host:0", out=0.95 * c.fabric["host:0"].capacity, flow="xh")
    led.reserve("soc:0", out=0.6 * c.fabric["soc:0"].capacity, flow="xs")
    c.run(1)
    assert c.offload.counters["compression_operations_offloaded"] == 1
    _clean_ledger(c.runtime, external_flows={"xh", "xs"})


def test_auto_staging_still_matches_best_raw_choice_idle_and_loaded():
    """The compute-tier candidates must not regress the §6.1 auto
    behavior: in the idle and host-loaded regimes the raw host/soc
    choice is still the cheapest and auto still matches it."""
    def step_time(mode, load):
        tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e6, ckpt_bytes=8e9,
                              ckpt_path=mode)
        return TrainCluster(1, tm, ckpt_every=2,
                            host_load=load).run(4)["sim_seconds"]

    for load in (None, {"node0": 0.6}):
        auto = step_time("auto", load)
        best = min(step_time("soc", load), step_time("host", load))
        assert auto == pytest.approx(best, rel=1e-9), (load, auto, best)


# ----------------------------------------------------------------------
# DrTM-KV filter offload (tentpole workload 2)
# ----------------------------------------------------------------------

def _kv():
    return DisaggKV(KVStoreParams(n_keys=2000, soc_cache_keys=100), seed=1)


def test_kv_filter_results_bit_identical_across_placement():
    kv = _kv()
    keys = kv.zipf_keys(400, seed=3)
    predicate = lambda vals: vals[:, 0] < 64          # ~25% selectivity
    soc = kv.filtered_scan(keys, predicate, where=SOC_FILTER)
    host = kv.filtered_scan(keys, predicate, where=HOST_FILTER)
    np.testing.assert_array_equal(soc.keys, host.keys)
    np.testing.assert_array_equal(soc.values, host.values)
    assert soc.scanned == host.scanned == 400
    assert soc.matched == host.matched == len(soc.keys) > 0
    # every returned value really satisfies the predicate
    assert bool(np.all(predicate(soc.values)))


def test_kv_filter_placement_flips_under_host_load():
    """Idle, the host path's 100 Mop/s beats the SoC's wimpy cores;
    with a serve tenant holding the host path the SoC placement keeps
    its rate and wins — same decision shape as decode placement."""
    kv = _kv()
    fab = kv.fabric()
    idle = plan_filter_placement(fab, selectivity=0.1, costs=kv.c)
    assert idle.location == HOST_FILTER
    assert idle.host_rate > idle.soc_rate
    led = fab.ledger()
    led.reserve("host_read", out=0.9 * fab["host_read"].capacity,
                flow="serve")
    busy = plan_filter_placement(fab, selectivity=0.1, costs=kv.c,
                                 ledger=led)
    assert busy.location == SOC_FILTER
    assert busy.soc_rate > busy.host_rate
    # the modeled scan seconds agree with the flip
    keys = kv.zipf_keys(200, seed=5)
    predicate = lambda vals: vals[:, 0] < 32
    f = KVFilter(kv)
    assert f.scan(keys, predicate, where=SOC_FILTER, ledger=led).seconds \
        < f.scan(keys, predicate, where=HOST_FILTER, ledger=led).seconds


def test_kv_filter_stats_and_alternatives():
    kv = _kv()
    stats = OffloadStats()
    f = KVFilter(kv, stats=stats)
    keys = kv.zipf_keys(300, seed=9)
    scan = f.scan(keys, lambda v: v[:, 0] < 16, where=SOC_FILTER)
    s = stats.get_performance_stats()
    assert s["packets_total"] == 300
    assert s["packets_offloaded"] == 300 - scan.matched
    assert s["offload_hit_rate"] == pytest.approx(1 - scan.matched / 300)
    assert s["cpu_cycles_saved"] >= 300
    alts = kv_filter_alternatives(kv.c, selectivity=0.2)
    for alt in alts.values():
        kv.fabric().validate(alt)
    with pytest.raises(ValueError, match="selectivity"):
        kv_filter_alternatives(kv.c, selectivity=1.5)
    with pytest.raises(ValueError, match="where"):
        f.scan(keys, lambda v: v[:, 0] < 16, where="fpga")


# ----------------------------------------------------------------------
# tenancy integration
# ----------------------------------------------------------------------

def test_serve_train_offload_policy():
    pol = QoSPolicy.serve_train_offload()
    assert pol.weight(SERVE) == 16.0
    assert pol.weight(TRAIN) == 1.0
    assert pol.weight(OFFLOAD) == 2.0
    assert pol.tenant_class(OFFLOAD) == THROUGHPUT


def test_offload_program_shares_device_with_qos_weights():
    """An offload program and a train-tenant Compute on one device split
    the roofline by policy weight."""
    qos = QoSPolicy.serve_train_offload(offload_weight=3.0, train_weight=1.0)
    rt = FabricRuntime(Fabric.of(compute_path("dev", 100.0)), qos=qos)
    prog = OffloadProgram(rt, "codec")          # tenant=OFFLOAD by default
    prog.launch(compute="dev", ops=400.0)
    tr = rt.compute("dev", 400.0, tenant=TRAIN)
    seen = {}
    rt.clock.schedule(1e-3, lambda: seen.update(train=tr.rate))
    rt.clock.run()
    assert seen["train"] == pytest.approx(25.0)     # 1/(3+1) of the device
    _clean_ledger(rt)
