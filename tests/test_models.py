"""Per-arch smoke: reduced config forward/train/decode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config, list_archs
from repro.models import model as M
from repro.models.params import init_params


def _tokens(cfg, b, s, key):
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    return jax.random.randint(key, shape, 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    b, s = 2, 32
    tokens = _tokens(cfg, b, s, key)
    fe = (jax.random.normal(key, (b, cfg.frontend_tokens, cfg.d_model)) * 0.02
          if cfg.frontend else None)
    res = M.forward(cfg, params, tokens, fe, impl="ref", remat="none")
    st = res.hidden.shape[1]
    assert res.hidden.shape == (b, st, cfg.d_model)
    assert not bool(jnp.isnan(res.hidden).any())
    labels = tokens
    mask = jnp.ones((b, st))
    if cfg.frontend:
        pad = jnp.zeros((b, cfg.frontend_tokens) + labels.shape[2:], labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
        mask = mask.at[:, :cfg.frontend_tokens].set(0.0)
    loss = M.cross_entropy(cfg, params, res.hidden, labels, mask, chunk=16)
    assert np.isfinite(float(loss))
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    b, s, maxlen = 2, 24, 32
    tokens = _tokens(cfg, b, s, key)
    fe = (jnp.zeros((b, cfg.frontend_tokens, cfg.d_model)) if cfg.frontend else None)
    res = M.forward(cfg, params, tokens, fe, impl="ref", remat="none",
                    capacity_factor=None)
    full = M.logits_for(cfg, params, res.hidden[:, -1:])
    total = maxlen + (cfg.frontend_tokens if cfg.frontend else 0)
    _, cache, pos = M.prefill(cfg, params, tokens[:, :s - 1], total,
                              frontend_embeds=fe, impl="ref",
                              cache_dtype=jnp.float32)
    step, _ = M.decode_step(cfg, params, tokens[:, s - 1:s], cache, pos)
    rel = float(jnp.abs(full - step).max()) / (float(jnp.abs(full).max()) + 1e-9)
    # 4e-2: SSM recurrence accumulates ~3% drift on jax 0.4.x CPU math
    assert rel < 4e-2, rel


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "granite-moe-1b-a400m",
                                  "mamba2-2.7b", "jamba-1.5-large-398b",
                                  "gemma2-9b", "musicgen-large"])
def test_train_step_no_nans(arch):
    from repro.configs import RunConfig
    from repro.optim.adamw import adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    opt = adamw_init(params)
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    b, s = 2, 32
    ft = cfg.frontend_tokens if cfg.frontend else 0
    tokens = np.asarray(_tokens(cfg, b, s - ft, key))
    batch = {"tokens": tokens, "labels": tokens, "loss_mask": np.ones((b, s - ft), np.float32)}
    if cfg.frontend:
        batch["frontend_embeds"] = np.zeros((b, ft, cfg.d_model), np.float32)
        pad = np.zeros((b, ft) + tokens.shape[2:], tokens.dtype)
        batch["labels"] = np.concatenate([pad, tokens], axis=1)
        batch["loss_mask"] = np.concatenate(
            [np.zeros((b, ft), np.float32), batch["loss_mask"]], axis=1)
    # step 1, not 0: linear warmup gives lr(0) == 0 (no update at all)
    p2, o2, m = step_fn(params, opt, batch, jnp.asarray(1))
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.abs(a - b2).max())
                for a, b2 in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


def test_microbatch_equivalence():
    """k microbatches of B/k must give the same grads as one batch of B."""
    from repro.configs import RunConfig
    from repro.optim.adamw import adamw_init
    from repro.train.train_step import make_train_step

    cfg = get_config("internlm2-1.8b").reduced()
    key = jax.random.PRNGKey(0)
    params, _ = init_params(cfg, key)
    b, s = 4, 16
    tokens = np.asarray(_tokens(cfg, b, s, key))
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": np.ones((b, s), np.float32)}
    outs = {}
    for mb in (0, 2):
        run = RunConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10,
                        microbatch=mb)
        opt = adamw_init(params)
        step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
        p2, _, m = step_fn(params, opt, batch, jnp.asarray(0))
        outs[mb] = (p2, float(m["loss"]))
    assert abs(outs[0][1] - outs[2][1]) < 1e-4
    for a, b2 in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[2][0])):
        assert float(jnp.abs(a - b2).max()) < 1e-4
