"""Logical-axis resolution rules."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_to_spec, mesh_axis_size


@pytest.fixture(scope="module")
def mesh():
    # single real device: use a 1x1 mesh; rule resolution is
    # independent of device count except for divisibility checks.
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


class FakeMesh:
    """Duck-typed mesh with arbitrary axis sizes for rule tests."""
    def __init__(self, shape):
        self.shape = shape


def test_basic_resolution():
    m = FakeMesh({"data": 16, "model": 16})
    assert logical_to_spec(("fsdp", "heads", None), m) == P("data", "model", None)
    assert logical_to_spec(("vocab", "fsdp"), m) == P("model", "data")


def test_divisibility_degrades_to_replication():
    m = FakeMesh({"data": 16, "model": 16})
    # kv_heads = 2 is not divisible by model=16 -> replicate that dim
    spec = logical_to_spec(("fsdp", "kv_heads", None), m, dim_sizes=(4096, 2, 128))
    assert spec == P("data", None, None)
    # kv_heads = 16 shards fine
    spec = logical_to_spec(("fsdp", "kv_heads", None), m, dim_sizes=(4096, 16, 128))
    assert spec == P("data", "model", None)


def test_missing_axis_degrades():
    m = FakeMesh({"data": 8})           # no model axis (e.g. 1-pod test mesh)
    assert logical_to_spec(("fsdp", "heads", None), m) == P("data", None, None)


def test_multi_axis_batch():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(("batch", None), m, dim_sizes=(256, 128))
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): replicate
    spec = logical_to_spec(("batch", None), m, dim_sizes=(1, 128))
    assert spec == P(None, None)


def test_overrides():
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(("kv_seq",), m, dim_sizes=(524288,),
                           overrides={"kv_seq": "data"})
    assert spec == P("data")


def test_mesh_axis_size():
    m = FakeMesh({"pod": 2, "data": 16})
    assert mesh_axis_size(m, ("pod", "data")) == 32
    assert mesh_axis_size(m, "absent") == 1
    assert mesh_axis_size(m, None) == 1
