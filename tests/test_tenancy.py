"""Multi-tenant colocation — the ISSUE 5 acceptance assertions, all on
one shared runtime/ledger in simulated time:

  (a) unmanaged colocation inflates the serve tenant's p99 TTFT by >2x
      its solo baseline, while QoS-weighted + admission-controlled
      colocation holds it <= 1.2x solo with train tokens/s within 20%
      of solo;
  (b) the serve tenant's greedy tokens and the train tenant's loss
      curve are bit-identical to their solo runs (colocation moves
      *when*, never *what*);
  (c) per-path budget conservation holds under weighted sharing across
      admit/throttle/resume transitions.

Plus the satellite coverage: weighted fair-sharing invariants in
core/runtime.py (conservation, reduction to equal shares, rebalance on
cancel/complete), the fabric merge/namespace helpers, and the
ledger-aware checkpoint staging choice.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.fabric import (Fabric, FabricError, IN, OUT, Path,
                               merge_fabrics)
from repro.core.runtime import FabricRuntime
from repro.serve.engine import Request
from repro.tenancy import (AdmissionConfig, Colocation, QoSPolicy, SERVE,
                           TRAIN, Tenant, colocation_fabric,
                           colocation_time_model, percentile, solo_serve,
                           solo_train)
from repro.train.cluster import ClusterTimeModel, TrainCluster


# ----------------------------------------------------------------------
# weighted fair-sharing in the runtime (satellite)
# ----------------------------------------------------------------------

def _rt(cap=100.0, disc=0.0, qos=None):
    return FabricRuntime(Fabric.of(Path("link", cap),
                                   concurrency_discount=disc), qos=qos)


def test_weighted_shares_follow_tenant_weights():
    """Two tenants 3:1 on one path: rates split 3:1 of the discounted
    capacity, and everything reserved is released at the end."""
    cap, disc = 100.0, 0.1
    qos = QoSPolicy([Tenant("hi", weight=3.0), Tenant("lo", weight=1.0)])
    rt = _rt(cap, disc, qos)
    t1 = rt.transfer("link", 90.0, tenant="hi")
    t2 = rt.transfer("link", 90.0, tenant="lo")
    seen = {}
    rt.clock.schedule(0.1, lambda: seen.update(hi=t1.rate, lo=t2.rate))
    rt.clock.run()
    eff = cap * (1 - disc)
    assert seen["hi"] == pytest.approx(eff * 0.75)
    assert seen["lo"] == pytest.approx(eff * 0.25)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_weights_one_reduce_to_equal_shares():
    """All-ones policy is byte-for-byte the unweighted runtime."""
    qos = QoSPolicy([Tenant("a", weight=1.0), Tenant("b", weight=1.0)])
    finals = {}
    for name, policy in (("plain", None), ("ones", qos)):
        rt = _rt(100.0, 0.125, policy)
        ta = rt.transfer("link", 80.0, tenant="a")
        tb = rt.transfer("link", 50.0, tenant="b")
        rt.clock.run()
        finals[name] = (ta.finished_at, tb.finished_at)
    assert finals["plain"] == finals["ones"]


def test_weighted_rebalance_on_cancel_and_complete():
    """Cancel the heavy tenant mid-flight: the survivor takes the whole
    (undiscounted) path; ledger returns to zero."""
    qos = QoSPolicy([Tenant("hi", weight=4.0), Tenant("lo", weight=1.0)])
    rt = _rt(100.0, 0.0, qos)
    t_hi = rt.transfer("link", 100.0, tenant="hi")   # 80/s share
    t_lo = rt.transfer("link", 100.0, tenant="lo")   # 20/s share
    rt.clock.schedule(0.5, lambda: rt.cancel(t_hi))
    rt.clock.run()
    assert t_hi.canceled and t_hi.remaining == pytest.approx(60.0)
    # lo: 0.5s at 20/s, then solo at 100/s for the remaining 90
    assert t_lo.finished_at == pytest.approx(0.5 + 90.0 / 100.0)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_weighted_max_rate_surplus_water_fills():
    """A capped heavy flow's surplus goes to lighter flows (weighted
    max-min, not strict proportionality)."""
    qos = QoSPolicy([Tenant("hi", weight=9.0), Tenant("lo", weight=1.0)])
    rt = _rt(100.0, 0.0, qos)
    hi = rt.transfer("link", 10.0, tenant="hi", max_rate=10.0)
    lo = rt.transfer("link", 90.0, tenant="lo")
    box = {}
    rt.clock.schedule(0.1, lambda: box.update(hi=hi.rate, lo=lo.rate))
    rt.clock.run()
    assert box["hi"] == pytest.approx(10.0)
    assert box["lo"] == pytest.approx(90.0)       # 10 share + 80 surplus
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_weighted_shares_conserve_budget_property():
    """Property: random weights/amounts never over-commit a path
    mid-flight, and the ledger drains to zero after completion."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.floats(0.5, 8.0), st.floats(1.0, 50.0)),
                    min_size=1, max_size=5),
           st.floats(0.0, 0.3))
    def inner(flows, disc):
        qos = QoSPolicy([Tenant(f"t{i}", weight=w)
                         for i, (w, _) in enumerate(flows)])
        rt = _rt(100.0, disc, qos)
        ts = [rt.transfer("link", amt, tenant=f"t{i}")
              for i, (_, amt) in enumerate(flows)]
        cap_seen = {}

        def probe():
            cap_seen["rates"] = sum(t.rate for t in ts if not t.done)
            cap_seen["reserved"] = rt.ledger.reserved("link", OUT)

        rt.clock.schedule(1e-3, probe)
        rt.clock.run()
        eff = 100.0 * (1 - disc if len(flows) > 1 and disc > 0 else 1.0)
        assert cap_seen["rates"] <= eff + 1e-6
        assert cap_seen["reserved"] <= eff + 1e-6
        assert all(t.done for t in ts)
        assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-6)
        assert rt.ledger.reserved("link", IN) == pytest.approx(0.0, abs=1e-6)

    inner()


@pytest.mark.parametrize("weights,amounts,disc", [
    ((1.0, 1.0, 1.0), (30.0, 20.0, 10.0), 0.0),
    ((5.0, 1.0), (100.0, 100.0), 0.125),
    ((2.0, 3.0, 7.0, 0.5), (10.0, 40.0, 25.0, 5.0), 0.2),
    ((8.0,), (50.0,), 0.3),
])
def test_weighted_shares_conserve_budget_sweep(weights, amounts, disc):
    """Deterministic slice of the conservation property (the hypothesis
    version above broadens it when the wheel is present): mid-flight
    rates never exceed the effective capacity, and the ledger drains."""
    qos = QoSPolicy([Tenant(f"t{i}", weight=w) for i, w in enumerate(weights)])
    rt = _rt(100.0, disc, qos)
    ts = [rt.transfer("link", amt, tenant=f"t{i}")
          for i, amt in enumerate(amounts)]
    probes = []
    rt.clock.schedule(1e-3, lambda: probes.append(
        (sum(t.rate for t in ts if not t.done),
         rt.ledger.reserved("link", OUT))))
    rt.clock.run()
    eff = 100.0 * ((1 - disc) if len(ts) > 1 and disc > 0 else 1.0)
    rates, reserved = probes[0]
    assert rates <= eff + 1e-6 and reserved <= eff + 1e-6
    assert rates == pytest.approx(reserved)
    assert all(t.done and not t.canceled for t in ts)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_qos_policy_validation():
    with pytest.raises(ValueError, match="weight"):
        Tenant("x", weight=0.0)
    with pytest.raises(ValueError, match="class"):
        Tenant("x", tenant_class="batch")
    with pytest.raises(ValueError, match="duplicate"):
        QoSPolicy([Tenant("a"), Tenant("a")])
    pol = QoSPolicy.serve_train(8.0, 2.0)
    assert pol.weight(SERVE) == 8.0 and pol.weight(TRAIN) == 2.0
    assert pol.weight("stranger") == 1.0 and pol.weight(None) == 1.0
    assert pol.tenant_class(SERVE) == "latency"


# ----------------------------------------------------------------------
# fabric merge / namespacing (tentpole helper)
# ----------------------------------------------------------------------

def test_merge_fabrics_shares_identical_paths_and_rejects_conflicts():
    a = Fabric.of(Path("shared", 10.0), Path("a_only", 5.0),
                  concurrency_discount=0.1)
    b = Fabric.of(Path("shared", 10.0), Path("b_only", 7.0),
                  concurrency_discount=0.2)
    m = merge_fabrics(a, b)
    assert sorted(m) == ["a_only", "b_only", "shared"]
    assert m.concurrency_discount == 0.2          # max of inputs
    conflicting = Fabric.of(Path("shared", 99.0))
    with pytest.raises(FabricError, match="merge conflict"):
        merge_fabrics(a, conflicting)
    assert merge_fabrics(a, concurrency_discount=0.05).concurrency_discount \
        == 0.05


def test_namespaced_fabric_prefixes_paths_and_groups():
    f = Fabric.of(Path("p", 10.0, shared_group="g"), Path("q", 5.0),
                  concurrency_discount=0.1)
    n = f.namespaced("tenant0")
    assert sorted(n) == ["tenant0/p", "tenant0/q"]
    assert n["tenant0/p"].group == "tenant0/g"
    assert n["tenant0/q"].group == "tenant0/q"    # implicit group follows
    # two namespaced copies of one fabric merge cleanly
    m = merge_fabrics(f.namespaced("x"), f.namespaced("y"))
    assert len(m) == 4


# ----------------------------------------------------------------------
# ledger-aware checkpoint staging (satellite)
# ----------------------------------------------------------------------

def test_choose_staging_prefers_free_path_and_falls_back_static():
    from repro.ckpt.checkpoint import CheckpointManager
    from repro.train.cluster import train_fabric
    fab = train_fabric(1)
    ledger = fab.ledger()
    cands = ["host:0", "soc:0"]
    # no ledger: the static fallback wins
    assert CheckpointManager.choose_staging(cands, fallback="soc:0") == "soc:0"
    assert CheckpointManager.choose_staging(cands) == "host:0"
    # idle fabric: the fatter host path wins
    assert CheckpointManager.choose_staging(cands, ledger=ledger) == "host:0"
    # host direction mostly spoken for: the SoC path wins
    ledger.reserve("host:0", out=0.8 * fab["host:0"].capacity, flow="load")
    assert CheckpointManager.choose_staging(cands, ledger=ledger) == "soc:0"
    with pytest.raises(ValueError):
        CheckpointManager.choose_staging([])


def test_auto_staging_matches_best_static_choice():
    """ckpt_path='auto' reproduces the §6.1 crossover dynamically: the
    per-save choice reads *standing* occupancy from the live ledger
    (an external host load — the colocation case), so it equals the
    best static choice in both the loaded and the idle regime."""
    def step_time(ckpt_path, host_load):
        tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e6, ckpt_bytes=8e9,
                              ckpt_path=ckpt_path)
        c = TrainCluster(1, tm, ckpt_every=2, host_load=host_load)
        return c.run(4)["sim_seconds"]

    for load in (None, {"node0": 0.6}):
        auto = step_time("auto", load)
        best = min(step_time("soc", load), step_time("host", load))
        assert auto == pytest.approx(best, rel=1e-9), (load, auto, best)
    with pytest.raises(ValueError, match="ckpt_path"):
        ClusterTimeModel(compute_s=1.0, grad_bytes=0.0, ckpt_path="nvme")


# ----------------------------------------------------------------------
# the colocation study (tentpole acceptance)
# ----------------------------------------------------------------------

HOST_BW, DISC = 16.0, 0.1
TRAIN_STEPS, N_REQS = 4, 8


@pytest.fixture(scope="module")
def small_lm():
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _fabric():
    return colocation_fabric(2, host_bw=HOST_BW, soc_frac=0.7,
                             net_bw_per_node=100.0, decode_bw=64.0,
                             concurrency_discount=DISC)


def _serve_tm():
    return colocation_time_model(0, prefill_units_per_token=0.25,
                                 decode_units_per_slot=0.25)


def _cluster_tm():
    return ClusterTimeModel(compute_s=0.3, grad_bytes=16.0, ckpt_bytes=8.0,
                            ckpt_path="soc", tokens_per_step=1024)


def _make_engine(small_lm):
    from repro.serve.engine import StagedServeEngine
    cfg, params = small_lm

    def make(rt):
        return StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                                 runtime=rt, time_model=_serve_tm(),
                                 tenant=SERVE)
    return make


def _make_cluster(numeric=None):
    def make(rt):
        kw = {}
        if numeric is not None:
            kw = dict(step_fn=numeric["step_fn"], params=numeric["params"](),
                      opt_state=numeric["opt_state"](),
                      batch_at=numeric["batch_at"])
        return TrainCluster(2, _cluster_tm(), fabric=rt.fabric, runtime=rt,
                            ckpt_every=2, tenant=TRAIN, **kw)
    return make


def _requests(cfg, spacing=0.3):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=4, arrival=spacing * i)
            for i in range(N_REQS)]


def _clean_ledger(runtime, external_flows=()):
    led = runtime.ledger
    for name in runtime.fabric:
        for direction in (OUT, IN):
            reserved = led.reserved(name, direction)
            external = sum((o if direction == OUT else i)
                           for (flow, pname), (o, i) in led._by_flow.items()
                           if pname == name and flow in external_flows)
            assert reserved == pytest.approx(external, abs=1e-6), \
                (name, direction, reserved)
    leftover = {flow for (flow, _), (o, i) in led._by_flow.items()
                if (o > 0 or i > 0) and flow not in external_flows}
    assert not leftover, leftover


@pytest.fixture(scope="module")
def colocation_runs(small_lm):
    """One solo/unmanaged/managed sweep shared by the assertions below
    (each run is seconds of jax work; the sweep is the experiment)."""
    cfg, _ = small_lm
    make_engine = _make_engine(small_lm)
    make_cluster = _make_cluster()
    solo_s = solo_serve(_fabric(), make_engine, _requests(cfg))
    solo_t = solo_train(_fabric(), make_cluster, TRAIN_STEPS)

    unmanaged = Colocation(fabric=_fabric(), make_engine=make_engine,
                           make_cluster=make_cluster)
    un = unmanaged.run(_requests(cfg), TRAIN_STEPS)

    managed = Colocation(
        fabric=_fabric(), make_engine=make_engine, make_cluster=make_cluster,
        qos=QoSPolicy.serve_train(16.0, 1.0),
        admission=AdmissionConfig(slo_ttft=1.2 * solo_s["p99_ttft"]))
    mg = managed.run(_requests(cfg), TRAIN_STEPS)
    return dict(solo_serve=solo_s, solo_train=solo_t, unmanaged=un,
                managed=mg, managed_harness=managed,
                unmanaged_harness=unmanaged)


def test_unmanaged_colocation_blows_p99_managed_holds_slo(colocation_runs):
    """(a) the headline crossover."""
    r = colocation_runs
    solo_p99 = r["solo_serve"]["p99_ttft"]
    assert r["unmanaged"].serve["p99_ttft"] > 2.0 * solo_p99, \
        (r["unmanaged"].serve, solo_p99)
    assert r["managed"].serve["p99_ttft"] <= 1.2 * solo_p99, \
        (r["managed"].serve, solo_p99)
    # the train tenant keeps >= 80% of its solo throughput under QoS
    solo_tps = r["solo_train"]["tokens_per_s"]
    assert r["managed"].train["tokens_per_s"] >= 0.8 * solo_tps, \
        (r["managed"].train["tokens_per_s"], solo_tps)
    # all work completed in every configuration
    for key in ("unmanaged", "managed"):
        assert r[key].serve["requests"] == N_REQS
        assert r[key].train["steps"] == TRAIN_STEPS


def test_occupancy_attribution_sees_both_tenants(colocation_runs):
    """The report attributes host:0 occupancy to both tenants (they
    really did share the path), and the serve-private decode path only
    to the serve tenant."""
    occ = colocation_runs["managed"].occupancy
    assert SERVE in occ["host:0"] and TRAIN in occ["host:0"]
    assert occ["host:0"][TRAIN] > occ["host:0"][SERVE] > 0.0
    assert set(occ["serve:decode"]) == {SERVE}
    assert TRAIN in occ["net"] and SERVE not in occ["net"]


def test_colocated_serve_tokens_bit_identical_to_solo(small_lm):
    """(b) serve half: contention moves TTFT, never the sampled token —
    under both unmanaged and QoS-weighted sharing."""
    cfg, _ = small_lm
    make_engine = _make_engine(small_lm)
    solo_reqs = _requests(cfg)
    rt = FabricRuntime(_fabric())
    eng = make_engine(rt)
    for q in solo_reqs:
        eng.submit(q)
    eng.run()
    solo_tokens = {q.rid: q.out_tokens for q in solo_reqs}

    for qos in (None, QoSPolicy.serve_train(16.0, 1.0)):
        reqs = _requests(cfg)
        Colocation(fabric=_fabric(), make_engine=make_engine,
                   make_cluster=_make_cluster(), qos=qos,
                   ).run(reqs, TRAIN_STEPS)
        for q in reqs:
            assert q.done and q.out_tokens == solo_tokens[q.rid], q.rid


def test_colocated_train_losses_bit_identical_to_solo(small_lm):
    """(b) train half: the numeric loss stream under colocation —
    including admission-control cancel + re-issue deferrals — matches
    the solo cluster bit for bit."""
    from repro.configs import RunConfig, get_config
    from repro.configs.base import ShapeConfig
    from repro.data.pipeline import TokenPipeline
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    from repro.train.train_step import make_train_step
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=3e-3, warmup_steps=2, total_steps=12)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    pipeline = TokenPipeline(cfg, shape, seed=0)
    numeric = dict(
        step_fn=step_fn, batch_at=pipeline.batch_at,
        params=lambda: init_params(cfg, jax.random.PRNGKey(0))[0],
        opt_state=lambda: adamw_init(
            init_params(cfg, jax.random.PRNGKey(0))[0]))
    make_cluster = _make_cluster(numeric)

    solo_cluster = make_cluster(FabricRuntime(_fabric()))
    solo_cluster.tenant = TRAIN
    solo_cluster.run(TRAIN_STEPS)
    solo_losses = {h["step"]: h["loss"] for h in solo_cluster.history}

    make_engine = _make_engine(small_lm)
    solo_s = solo_serve(_fabric(), make_engine, _requests(cfg))
    harness = Colocation(
        fabric=_fabric(), make_engine=make_engine, make_cluster=make_cluster,
        admission=AdmissionConfig(slo_ttft=1.2 * solo_s["p99_ttft"],
                                  occupancy_limit=0.4,
                                  watch_paths=("host:0",)))
    report = harness.run(_requests(cfg), TRAIN_STEPS)
    assert report.throttles > 0          # deferrals really happened
    colo_losses = {h["step"]: h["loss"] for h in harness.cluster.history}
    assert sorted(colo_losses) == sorted(solo_losses) \
        == list(range(TRAIN_STEPS))
    for k in solo_losses:
        assert colo_losses[k] == solo_losses[k], k


def test_admission_controller_throttles_and_conserves(small_lm):
    """(c) equal weights + an occupancy-triggered controller: at least
    one pause/resume cycle happens, every deferred transfer is
    re-issued (all steps complete), the serve tail beats unmanaged, and
    the ledger conserves across every admit/throttle/resume
    transition."""
    cfg, _ = small_lm
    make_engine = _make_engine(small_lm)
    solo_s = solo_serve(_fabric(), make_engine, _requests(cfg))
    harness = Colocation(
        fabric=_fabric(), make_engine=make_engine,
        make_cluster=_make_cluster(),
        admission=AdmissionConfig(slo_ttft=1.2 * solo_s["p99_ttft"],
                                  occupancy_limit=0.4,
                                  watch_paths=("host:0",)))
    report = harness.run(_requests(cfg), TRAIN_STEPS)
    assert report.throttles > 0
    kinds = [e["event"] for e in report.events]
    assert "throttle" in kinds and "resume" in kinds
    assert "transfers_paused" in kinds and "transfers_resumed" in kinds
    assert report.train["steps"] == TRAIN_STEPS      # deferral, not loss
    assert report.serve["p99_ttft"] <= 1.3 * solo_s["p99_ttft"]
    _clean_ledger(harness.runtime)


def test_managed_colocation_leaves_clean_ledger(colocation_runs):
    """(c) weighted sharing: after the managed run every reservation is
    back in the ledger, on every path and direction."""
    _clean_ledger(colocation_runs["managed_harness"].runtime)
    _clean_ledger(colocation_runs["unmanaged_harness"].runtime)
