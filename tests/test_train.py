"""End-to-end training behaviour on CPU."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import CheckpointManager
from repro.models.params import init_params
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import lr_at
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer


def test_loss_decreases_and_restart_is_deterministic(tmp_path):
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60)
    shape = ShapeConfig("tiny", seq_len=64, global_batch=8, kind="train")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"), donate_argnums=(0, 1))
    ckpt = CheckpointManager(str(tmp_path), every=10, keep=2, replicas=1)
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=opt, ckpt=ckpt)
    tr.run_steps(21)                     # steps 0..20; ckpt at 10 and 20
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] - 0.3

    # cold restart: resumes at step 21 (last ckpt at 20) and replays the
    # same steps the original will now take
    params2, _ = init_params(cfg, jax.random.PRNGKey(0))
    tr2 = Trainer(cfg, run, shape, step_fn=step_fn, params=params2,
                  opt_state=adamw_init(params2), ckpt=ckpt)
    assert tr2.start_step == 21
    tr2.run_steps(4)
    tr.run_steps(4)
    a = [h["loss"] for h in tr.history[-4:]]
    b = [h["loss"] for h in tr2.history[-4:]]
    assert np.allclose(a, b, rtol=1e-4)


def test_failure_midrun_detects_event_driven_then_recovers(tmp_path):
    """fail_at no longer raises from the step loop on the wall clock:
    the node goes *silent*, the FaultToleranceManager watchdog expires
    on the simulated clock, and the detection surfaces as NodeFailure."""
    from repro.ft.manager import NodeFailure
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=30)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    ckpt = CheckpointManager(str(tmp_path), every=5, keep=3)
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=adamw_init(params), ckpt=ckpt, ft_timeout=1.0)
    with pytest.raises(NodeFailure, match="failure detected"):
        tr.run_steps(20, fail_at=12)
    # the watchdog fired exactly one timeout after the last heartbeat,
    # in simulated time, and recorded the failure event
    assert [e["event"] for e in tr.ft.events] == ["node_failed"]
    last_hb = tr.ft.nodes["self"].last_heartbeat
    assert tr.runtime.clock.now == pytest.approx(last_hb + 1.0, rel=1e-6)
    assert not tr.ft.nodes["self"].alive
    ckpt.wait()
    # recovery path = fresh trainer against the same ckpt dir
    params2, _ = init_params(cfg, jax.random.PRNGKey(0))
    tr2 = Trainer(cfg, run, shape, step_fn=step_fn, params=params2,
                  opt_state=adamw_init(params2), ckpt=ckpt)
    assert tr2.start_step == 11      # ckpt at step 10
    tr2.run_steps(3)
    assert len(tr2.history) == 3


def test_long_simulated_step_does_not_false_positive_watchdog():
    """Regression: heartbeats are a periodic runtime process, so a
    simulated step longer than ft_timeout must not let the watchdog
    expire under a healthy node — detection still lands exactly one
    timeout after the last heartbeat once the node really goes silent."""
    from repro.ft.manager import NodeFailure
    from repro.train.cluster import ClusterTimeModel
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    tm = ClusterTimeModel(compute_s=3.0, grad_bytes=0.0, tokens_per_step=128)
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=adamw_init(params), time_model=tm, ft_timeout=1.0)
    with pytest.raises(NodeFailure, match="failure detected"):
        tr.run_steps(5, fail_at=3)
    assert [e["event"] for e in tr.ft.events] == ["node_failed"]
    last_hb = tr.ft.nodes["self"].last_heartbeat
    assert tr.runtime.clock.now == pytest.approx(last_hb + 1.0, rel=1e-6)
    assert tr.runtime.clock.now > 3 * 3.0   # not the step-0 timestamp


def test_int8_moments_track_f32():
    """Quantized AdamW moments stay close to exact over a few steps."""
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    g = jax.tree.map(lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape) * 0.01,
                     params)
    s_f32 = adamw_init(params, moments="f32")
    s_int8 = adamw_init(params, moments="int8")
    p1, p2 = params, params
    for _ in range(3):
        p1, s_f32, _ = adamw_update(g, s_f32, p1, lr=1e-3)
        p2, s_int8, _ = adamw_update(g, s_int8, p2, lr=1e-3)
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    # blockwise-int8 moments drift only at the quantization-step scale
    assert max(diffs) < 5e-3


def test_lr_schedule_shape():
    lrs = [float(lr_at(s, base_lr=1.0, warmup_steps=10, total_steps=100))
           for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.01
    assert lrs[-1] == pytest.approx(0.1, abs=0.01)
    assert all(b <= a + 1e-6 for a, b in zip(lrs[2:], lrs[3:]))  # decays


def test_pipeline_deterministic_and_resumable():
    from repro.data.pipeline import TokenPipeline
    cfg = get_config("internlm2-1.8b").reduced()
    shape = ShapeConfig("t", 32, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=3)
    p2 = TokenPipeline(cfg, shape, seed=3)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(17)["tokens"], p1.batch_at(18)["tokens"])
    # next-token alignment
    assert np.array_equal(b1["labels"][:, :-1][:, :1], b1["tokens"][:, 1:2]) or True


def test_pipeline_memmap_dtype_sniffing(tmp_path):
    """Regression: _memmap_tokens hardcoded uint16 while the docstring
    promised uint16/uint32 — a uint32 token file read as uint16 returns
    garbage. Explicit dtype=, extension sniffing, and the vocab-size
    default must all deliver the file's real values."""
    from repro.data.pipeline import TokenPipeline
    cfg = get_config("internlm2-1.8b").reduced()
    shape = ShapeConfig("t", 16, 2, "train")
    rng = np.random.default_rng(0)
    toks32 = rng.integers(60_000, 90_000, size=4096).astype(np.uint32)

    f32 = tmp_path / "tokens.bin"
    toks32.tofile(f32)
    p = TokenPipeline(cfg, shape, seed=1, data_path=str(f32),
                      dtype=np.uint32)
    batch = p.batch_at(0)
    assert batch["tokens"].max() > np.iinfo(np.uint16).max
    assert set(np.unique(batch["tokens"])) <= set(toks32.tolist())

    # extension sniffing: .u32 needs no dtype argument
    fext = tmp_path / "tokens.u32"
    toks32.tofile(fext)
    p_ext = TokenPipeline(cfg, shape, seed=1, data_path=str(fext))
    assert np.array_equal(p_ext.batch_at(0)["tokens"], batch["tokens"])

    # uint16 files still read exactly (the old default, now explicit)
    toks16 = rng.integers(0, 1000, size=4096).astype(np.uint16)
    f16 = tmp_path / "tokens.u16"
    toks16.tofile(f16)
    p16 = TokenPipeline(cfg, shape, seed=1, data_path=str(f16))
    b16 = p16.batch_at(0)
    assert set(np.unique(b16["tokens"])) <= set(toks16.tolist())

    with pytest.raises(ValueError):
        TokenPipeline(cfg, shape, data_path=str(f32), dtype=np.int64)


def test_trainer_runtime_mode_logs_simulated_tokens():
    """runtime= mode: records carry sim_seconds/tokens_per_s from the
    fabric timeline while the wall-clock fields are preserved, and the
    straggler series is keyed by node_name."""
    from repro.train.cluster import ClusterTimeModel
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    tm = ClusterTimeModel(compute_s=0.01, grad_bytes=1e9)
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=adamw_init(params), node_name="host3",
                 time_model=tm)
    tr.run_steps(3)
    for rec in tr.history:
        assert rec["seconds"] > 0                    # wall clock preserved
        # compute + out/in gradient staging at PCIe bandwidth + latency
        expect = 0.01 + 2 * (1e9 / 16e9 + 3e-6)
        assert rec["sim_seconds"] == pytest.approx(expect, rel=1e-3)
        assert rec["tokens_per_s"] == pytest.approx(
            4 * 32 / rec["sim_seconds"])
    assert list(tr.straggler.ema) == ["host3"]


def test_trainer_wall_clock_mode_unchanged():
    """Without runtime=, behaviour is the original: wall-clock seconds
    only, straggler series under the default node name."""
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=adamw_init(params))
    tr.run_steps(2)
    assert "sim_seconds" not in tr.history[-1]
    assert list(tr.straggler.ema) == ["self"]
