"""Fast event core: the incremental per-(path,direction) rebalancer
must be observationally identical to the settle-everything oracle.

Property: replaying one randomized schedule of
issue / cancel / cancel-and-reissue ops — across paths that share an
interference group, with mixed QoS weights and max_rate caps, and with
deliberately colliding op instants — under ``rebalance="global"`` and
``rebalance="incremental"`` produces *bit-identical* (time, rate,
remaining) traces, and the shared ledger conserves per
(path, direction) in both modes.

The seeded-RNG replays below always run; when hypothesis is installed
(importorskip pattern, as in test_property.py) the same harness is
additionally driven by generated schedules with shrinking.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.fabric import Fabric, IN, OUT, Path
from repro.core.runtime import FabricRuntime
from repro.tenancy.qos import QoSPolicy, Tenant

if HAVE_HYPOTHESIS:
    settings.register_profile("simcore", max_examples=30, deadline=None)
    settings.load_profile("simcore")

PATHS = ("h0", "s0", "net")
DIRS = (OUT, IN)
PROBES = (1.0, 3.0, 7.0)


def _fabric() -> Fabric:
    # h0 and s0 share one interference group (the PCIe socket shape
    # from train_fabric); net stands alone.
    return Fabric.of(Path("h0", 100.0, shared_group="pcie0"),
                     Path("s0", 40.0, shared_group="pcie0"),
                     Path("net", 200.0),
                     concurrency_discount=0.3)


def _runtime(mode: str) -> FabricRuntime:
    qos = QoSPolicy([Tenant("serve", weight=3.0),
                     Tenant("train", weight=1.0)])
    return FabricRuntime(_fabric(), qos=qos, rebalance=mode)


def _settled_remaining(t) -> float:
    """What ``t.remaining`` would read if settled right now — the
    anchor-based lazy settle leaves ``remaining`` stale while the rate
    is unchanged, in *both* modes, so probes must settle explicitly."""
    dt = t.runtime.clock.now - t._last_update
    if t.done or t.rate <= 0 or dt <= 0:
        return t.remaining
    return max(0.0, t.remaining - t.rate * dt)


def _run_schedule(specs, cancels, mode):
    """Replay one op schedule; return the full observable trace."""
    rt = _runtime(mode)
    trace = []
    ts = []

    def issue(path, direction, amount, flow, tenant, max_rate):
        t = rt.transfer(path, amount, direction=direction, flow=flow,
                        tenant=tenant, max_rate=max_rate)
        t.add_callback(lambda t: trace.append(
            ("done", t.path, t.direction, t.flow, rt.clock.now,
             t.canceled, t.remaining)))
        ts.append(t)

    def do_cancel(pick, reissue):
        if not ts:
            return
        t = ts[pick % len(ts)]
        if t.done:
            trace.append(("cancel-noop", pick % len(ts), rt.clock.now))
            return
        rt.cancel(t)
        trace.append(("cancel", t.path, t.direction, t.flow,
                      rt.clock.now, t.remaining))
        if reissue and t.remaining > 0:
            issue(t.path, t.direction, t.remaining, t.flow + "+r",
                  t.tenant, t.max_rate)

    def probe():
        snap = tuple((t.done, t.rate, _settled_remaining(t)) for t in ts)
        held = tuple(rt.ledger.reserved(p, d) for p in PATHS for d in DIRS)
        trace.append(("probe", rt.clock.now, snap, held))

    for (at, p, d, amount, fl, tenant, max_rate) in specs:
        rt.clock.at(at, issue, PATHS[p], DIRS[d], amount, f"f{fl}",
                    tenant, max_rate)
    for (at, pick, reissue) in cancels:
        rt.clock.at(at, do_cancel, pick, reissue)
    for at in PROBES:
        rt.clock.at(at, probe)
    rt.clock.run()

    assert all(t.done for t in ts)
    for p in PATHS:
        for d in DIRS:
            # conservation: every reservation was returned
            assert rt.ledger.reserved(p, d) == pytest.approx(0.0, abs=1e-6)
    trace.append(("end", rt.clock.now, rt.clock.processed))
    return trace


# op instants quantized to 1/8 s so schedules collide on purpose —
# same-instant coalescing and tie ordering are part of the contract
_TENANTS = ("serve", "train", None)
_MAX_RATES = (math.inf, 5.0, 17.0)


def _random_schedule(seed, n_transfers=20, n_cancels=6):
    rng = np.random.default_rng(seed)
    specs = [(int(rng.integers(0, 65)) * 0.125,
              int(rng.integers(0, len(PATHS))),
              int(rng.integers(0, len(DIRS))),
              float(rng.uniform(0.5, 40.0)),
              int(rng.integers(0, 5)),
              _TENANTS[int(rng.integers(0, len(_TENANTS)))],
              _MAX_RATES[int(rng.integers(0, len(_MAX_RATES)))])
             for _ in range(n_transfers)]
    cancels = [(int(rng.integers(0, 65)) * 0.125,
                int(rng.integers(0, 31)),
                bool(rng.integers(0, 2)))
               for _ in range(n_cancels)]
    return specs, cancels


@pytest.mark.parametrize("seed", range(12))
def test_incremental_matches_global_seeded(seed):
    """Seeded replays of the randomized schedule — always runs, no
    hypothesis needed."""
    specs, cancels = _random_schedule(seed)
    inc = _run_schedule(specs, cancels, "incremental")
    glo = _run_schedule(specs, cancels, "global")
    assert inc == glo


@pytest.mark.parametrize("flows", ["same", "distinct"])
def test_discount_flip_consistent_across_modes(flows):
    """Force the multi-flow discount on and off repeatedly: every
    transfer on the same flow (never discounted) vs distinct flows
    (discounted once >= 2 concurrent) — both replays must agree across
    modes (the flag flip forces a full-group rebalance)."""
    specs, _ = _random_schedule(99, n_transfers=16, n_cancels=0)
    mutated = [(at, p, d, amount, 0 if flows == "same" else i, tenant, mr)
               for i, (at, p, d, amount, _, tenant, mr)
               in enumerate(specs)]
    inc = _run_schedule(mutated, [], "incremental")
    glo = _run_schedule(mutated, [], "global")
    assert inc == glo


if HAVE_HYPOTHESIS:
    _instant = st.integers(0, 64).map(lambda k: k * 0.125)
    _transfer = st.tuples(
        _instant,
        st.integers(0, len(PATHS) - 1),
        st.integers(0, len(DIRS) - 1),
        st.floats(0.5, 40.0, allow_nan=False, allow_infinity=False),
        st.integers(0, 4),
        st.sampled_from(_TENANTS),
        st.sampled_from(_MAX_RATES),
    )
    _cancel = st.tuples(_instant, st.integers(0, 30), st.booleans())

    @given(st.lists(_transfer, min_size=1, max_size=25),
           st.lists(_cancel, max_size=8))
    def test_incremental_matches_global_bit_identical(specs, cancels):
        inc = _run_schedule(specs, cancels, "incremental")
        glo = _run_schedule(specs, cancels, "global")
        assert inc == glo
