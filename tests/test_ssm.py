"""SSD chunked == sequential recurrence; conv step == conv."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.ssm import (causal_conv, causal_conv_step, ssd_chunked,
                              ssd_decode_step, ssd_ref)

CASES = [(2, 64, 4, 8, 16, 16), (1, 100, 3, 16, 8, 32), (2, 256, 8, 16, 32, 64)]


def _inputs(b, s, h, p, n, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, Bm, C


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_sequential(case):
    b, s, h, p, n, L = case
    x, dt, A, Bm, C = _inputs(b, s, h, p, n)
    yr, hr = ssd_ref(x, dt, A, Bm, C)
    yc, hc = ssd_chunked(x, dt, A, Bm, C, chunk=L)
    assert float(jnp.abs(yr - yc).max()) < 2e-3
    assert float(jnp.abs(hr - hc).max()) < 2e-3


def test_state_passing_prefill_decode():
    """Chunked state h after S tokens must continue the recurrence."""
    b, s, h, p, n = 1, 32, 2, 8, 4
    x, dt, A, Bm, C = _inputs(b, s + 1, h, p, n, key=7)
    y_all, _ = ssd_ref(x, dt, A, Bm, C)
    _, hmid = ssd_chunked(x[:, :s], dt[:, :s], A, Bm[:, :s], C[:, :s], chunk=8)
    y_t, _ = ssd_decode_step(x[:, s], dt[:, s], A, Bm[:, s], C[:, s], hmid)
    assert float(jnp.abs(y_all[:, s] - y_t).max()) < 2e-3


def test_conv_step_equals_conv():
    b, s, ch, k = 2, 16, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (b, s, ch))
    w = jax.random.normal(ks[1], (k, ch))
    y, st = causal_conv(x, w)
    st2 = jnp.zeros((b, k - 1, ch))
    outs = []
    for t in range(s):
        yt, st2 = causal_conv_step(x[:, t], w, st2)
        outs.append(yt)
    assert float(jnp.abs(y - jnp.stack(outs, 1)).max()) < 1e-5
    assert float(jnp.abs(st - st2).max()) < 1e-6


def test_padding_robustness():
    """Non-chunk-multiple sequence lengths pad internally."""
    x, dt, A, Bm, C = _inputs(1, 37, 2, 4, 8)
    yr, hr = ssd_ref(x, dt, A, Bm, C)
    yc, hc = ssd_chunked(x, dt, A, Bm, C, chunk=16)
    assert yc.shape == yr.shape
    assert float(jnp.abs(yr - yc).max()) < 2e-3
