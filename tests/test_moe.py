"""MoE dispatch: capacity, drops, hot-expert replication (Advice #1)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.moe import (moe_ffn, moe_ffn_dense_ref,
                              replicate_hot_experts)


@pytest.fixture(scope="module")
def setup():
    k0 = jax.random.PRNGKey(2)
    B, S, D, E, K, F = 2, 128, 32, 8, 2, 64
    ks = jax.random.split(k0, 4)
    params = {"router": jax.random.normal(ks[1], (D, E)) * 0.02,
              "w_in": jax.random.normal(ks[2], (E, D, 2, F)) * 0.05,
              "w_out": jax.random.normal(ks[3], (E, F, D)) * 0.05}
    x_uniform = jax.random.normal(ks[0], (B, S, D)) * 0.5
    x_skewed = (jax.random.normal(ks[0], (B, S, D)) * 0.1
                + params["router"][:, 0][None, None, :] * 1.5)
    return params, x_uniform, x_skewed, E, K


def test_lossless_matches_dense(setup):
    params, x, _, E, K = setup
    y, m = moe_ffn(x, params, num_experts=E, top_k=K,
                   activation=jax.nn.silu, capacity_factor=None)
    yref = moe_ffn_dense_ref(x, params, num_experts=E, top_k=K,
                             activation=jax.nn.silu)
    assert float(jnp.abs(y.astype(jnp.float32) - yref.astype(jnp.float32)).max()) < 5e-2
    assert float(m.dropped_frac) == 0.0


def test_tight_capacity_drops(setup):
    params, _, x_skew, E, K = setup
    _, m = moe_ffn(x_skew, params, num_experts=E, top_k=K,
                   activation=jax.nn.silu, capacity_factor=0.8)
    assert 0.0 < float(m.dropped_frac) < 1.0


def test_hot_expert_replication_reduces_drops(setup):
    """Advice #1: replicating the hottest experts' queues tames skew."""
    params, _, x_skew, E, K = setup
    _, m0 = moe_ffn(x_skew, params, num_experts=E, top_k=K,
                    activation=jax.nn.silu, capacity_factor=0.8)
    _, m3 = moe_ffn(x_skew, params, num_experts=E, top_k=K,
                    activation=jax.nn.silu, capacity_factor=0.8,
                    hot_expert_replicas=3)
    assert float(m3.dropped_frac) < float(m0.dropped_frac)


def test_replication_is_output_lossless(setup):
    """With lossless capacity, replicas must not change the math."""
    params, _, x_skew, E, K = setup
    y0, _ = moe_ffn(x_skew, params, num_experts=E, top_k=K,
                    activation=jax.nn.silu, capacity_factor=None)
    y3, _ = moe_ffn(x_skew, params, num_experts=E, top_k=K,
                    activation=jax.nn.silu, capacity_factor=None,
                    hot_expert_replicas=3)
    assert float(jnp.abs(y0.astype(jnp.float32) - y3.astype(jnp.float32)).max()) < 5e-3


def test_replicate_hot_experts_mapping():
    idx = jnp.asarray([[0, 1], [0, 2], [0, 3], [0, 1]])
    virt, parents = replicate_hot_experts(idx, None, num_experts=4,
                                          replicas=2, num_hot=1)
    # expert 0 is hottest; its replica is virtual expert 4 -> parent 0
    assert parents.shape[0] == 5 and int(parents[4]) == 0
    col0 = virt[:, 0]
    assert set(int(v) for v in col0) == {0, 4}     # round-robin split
    # non-hot assignments untouched
    assert (virt[:, 1] == idx[:, 1]).all()
