"""Event-driven fabric runtime: emergent concurrency discount, LineFS
pipelining, and the staged serving pipeline vs the synchronous engine.

These are the ISSUE 3 acceptance assertions:
  (a) two overlapping transfers on one path each see the discounted
      fair-share rate, and the ledger conserves (returns to zero);
  (b) pipelined replication beats sequential replication by >= 20%
      simulated latency at the paper's testbed bandwidths;
  (c) the staged ServeEngine's p99 time-to-first-token under a bursty
      arrival trace is lower than the synchronous engine's, with
      identical output tokens.
"""
import math

import jax
import numpy as np
import pytest

from repro.core.fabric import (Fabric, InsufficientBudget, OUT, Path,
                               linefs_fabric)
from repro.core.runtime import FabricRuntime, Process, Signal, SimClock
from repro.ckpt.replication import simulate_replication


# ----------------------------------------------------------------------
# clock / process plumbing
# ----------------------------------------------------------------------

def test_clock_orders_events_deterministically():
    clock = SimClock()
    log = []
    clock.schedule(2.0, lambda: log.append("c"))
    clock.schedule(1.0, lambda: log.append("a"))
    clock.schedule(1.0, lambda: log.append("b"))   # tie: schedule order
    clock.run()
    assert log == ["a", "b", "c"]
    assert clock.now == 2.0


def test_clock_run_until_and_stop():
    clock = SimClock()
    hits = []
    for t in (1.0, 2.0, 3.0):
        clock.schedule(t, lambda t=t: hits.append(t))
    clock.run(until=2.5)
    assert hits == [1.0, 2.0] and clock.now == 2.5
    clock.run()
    assert hits == [1.0, 2.0, 3.0]


def test_process_yield_protocol():
    fabric = Fabric.of(Path("p", 10.0))
    rt = FabricRuntime(fabric)
    sig = rt.signal()
    log = []

    def child():
        yield 0.5
        log.append(("child", rt.clock.now))
        return 42

    def parent():
        got = yield rt.process(child(), name="child")
        log.append(("joined", got, rt.clock.now))
        yield rt.transfer("p", 10.0)          # 1s at full rate
        log.append(("transferred", rt.clock.now))
        sig.fire()

    def waiter():
        yield sig
        log.append(("woken", rt.clock.now))

    rt.process(parent(), name="parent")
    rt.process(waiter(), name="waiter")
    rt.clock.run()
    assert log == [("child", 0.5), ("joined", 42, 0.5),
                   ("transferred", 1.5), ("woken", 1.5)]


# ----------------------------------------------------------------------
# (a) emergent §4.1 discount + ledger conservation
# ----------------------------------------------------------------------

def test_overlapping_transfers_see_discounted_rate_and_conserve():
    cap, disc = 100.0, 0.125
    fabric = Fabric.of(Path("link", cap), concurrency_discount=disc)
    rt = FabricRuntime(fabric)
    t1 = rt.transfer("link", 100.0)
    t2 = rt.transfer("link", 100.0)
    seen = {}
    rt.clock.schedule(0.1, lambda: seen.update(
        r1=t1.rate, r2=t2.rate, reserved=rt.ledger.reserved("link", OUT)))
    rt.clock.run()
    shared = cap * (1 - disc) / 2                      # 43.75
    assert seen["r1"] == pytest.approx(shared)
    assert seen["r2"] == pytest.approx(shared)
    # mid-flight the ledger accounts exactly for both flows
    assert seen["reserved"] == pytest.approx(cap * (1 - disc))
    # both finish together at the shared rate
    assert t1.finished_at == pytest.approx(100.0 / shared)
    assert t2.finished_at == pytest.approx(100.0 / shared)
    # conservation: everything reserved was released
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)
    assert rt.ledger.reserved("link", "in") == pytest.approx(0.0, abs=1e-9)


def test_staggered_transfer_rebalances_midflight():
    """A solo transfer runs at full rate; when a second joins, both drop
    to the discounted share; when the first leaves, the survivor speeds
    back up to the full undiscounted rate."""
    cap, disc = 100.0, 0.125
    fabric = Fabric.of(Path("link", cap), concurrency_discount=disc)
    rt = FabricRuntime(fabric)
    t1 = rt.transfer("link", 100.0)
    box = {}
    rt.clock.schedule(0.25, lambda: box.update(solo=t1.rate))
    rt.clock.schedule(0.5, lambda: box.update(t2=rt.transfer("link", 100.0)))
    rt.clock.run()
    assert box["solo"] == pytest.approx(cap)
    shared = cap * (1 - disc) / 2
    # t1 had 50 left at t=0.5, drains at the shared rate
    assert t1.finished_at == pytest.approx(0.5 + 50.0 / shared)
    # t2: shared until t1 leaves, then full rate for the remainder
    done_shared = (t1.finished_at - 0.5) * shared
    assert box["t2"].finished_at == pytest.approx(
        t1.finished_at + (100.0 - done_shared) / cap)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(0.0, abs=1e-9)


def test_clock_run_until_advances_past_empty_heap():
    """run(until=X) lands on X even when no events are pending — the
    sync engine relies on this to jump to a future arrival."""
    clock = SimClock()
    assert clock.run(until=1.5) == 1.5
    assert clock.now == 1.5


def test_rebalance_unstalls_transfer_after_external_release():
    """A transfer stalled behind an external reservation resumes when
    the holder releases and the runtime is rebalanced."""
    fabric = Fabric.of(Path("link", 100.0))
    rt = FabricRuntime(fabric)
    rt.ledger.reserve("link", out=100.0, flow="primary")
    t = rt.transfer("link", 50.0)
    rt.clock.run()
    assert not t.done and t.rate == 0.0          # stalled, not failed
    rt.ledger.release("link", out=100.0, flow="primary")
    rt.rebalance("link")
    rt.clock.run()
    assert t.done and t.rate == pytest.approx(100.0)


def test_max_rate_surplus_water_fills_to_uncapped_flows():
    """Max-min fairness: a rate-capped flow's unused share goes to the
    uncapped flows, keeping the path fully utilized."""
    fabric = Fabric.of(Path("p", 100.0))
    rt = FabricRuntime(fabric)
    slow = rt.transfer("p", 10.0, max_rate=10.0)
    fast = rt.transfer("p", 90.0)
    box = {}
    rt.clock.schedule(0.1, lambda: box.update(slow=slow.rate, fast=fast.rate))
    rt.clock.run()
    assert box["slow"] == pytest.approx(10.0)
    assert box["fast"] == pytest.approx(90.0)     # 50 share + 40 surplus
    assert slow.finished_at == pytest.approx(1.0)
    assert fast.finished_at == pytest.approx(1.0)
    assert rt.ledger.reserved("p", OUT) == pytest.approx(0.0, abs=1e-9)


def test_transfers_respect_external_reservations():
    """A primary functionality's pre-reserved rate is off-limits, and it
    counts as a holder for the discount."""
    cap, disc = 100.0, 0.10
    fabric = Fabric.of(Path("link", cap), concurrency_discount=disc)
    rt = FabricRuntime(fabric)
    rt.ledger.reserve("link", out=30.0, flow="primary")
    t = rt.transfer("link", 60.0)
    rt.clock.run()
    # 2 holders -> discounted cap 90; minus the primary's 30 -> rate 60
    assert t.rate == pytest.approx(60.0)
    assert t.finished_at == pytest.approx(1.0)
    assert rt.ledger.reserved("link", OUT) == pytest.approx(30.0)


def test_shared_group_transfers_interfere_across_paths():
    """Two paths in one shared_group: concurrent flows discount each
    other but do not share each other's budget (paper §4.1)."""
    fabric = Fabric.of(
        Path("a", 100.0, shared_group="pcie"),
        Path("b", 50.0, shared_group="pcie"),
        concurrency_discount=0.2)
    rt = FabricRuntime(fabric)
    ta = rt.transfer("a", 80.0)
    tb = rt.transfer("b", 40.0)
    rt.clock.run()
    assert ta.finished_at == pytest.approx(1.0)   # 80 / (100*0.8)
    assert tb.finished_at == pytest.approx(1.0)   # 40 / (50*0.8)


# ----------------------------------------------------------------------
# (b) pipelined replication
# ----------------------------------------------------------------------

def test_pipelined_replication_beats_sequential_by_20pct():
    """LineFS §5.1: staging chunk i+1 while chunk i is on the wire.
    Paper testbed: 200 Gbps network, 256 Gbps internal, ratio 0.5."""
    kw = dict(chunks=8, net_bw=200e9 / 8, staging_bw=256e9 / 8, ratio=0.5)
    seq = simulate_replication(1e9, pipelined=False, **kw)
    pipe = simulate_replication(1e9, pipelined=True, **kw)
    win = 1.0 - pipe.seconds / seq.seconds
    assert win >= 0.20, f"pipelining won only {win:.1%}"
    assert win <= 0.5                      # bounded by a 2-stage pipeline
    assert len(pipe.chunk_finish_s) == 8
    assert pipe.percentile(99) == pytest.approx(pipe.seconds)
    # chunk completions are strictly ordered
    assert all(a < b for a, b in zip(pipe.chunk_finish_s,
                                     pipe.chunk_finish_s[1:]))


def test_sequential_replication_matches_closed_form():
    N, P = 200e9 / 8, 256e9 / 8
    seq = simulate_replication(1e9, ratio=0.5, chunks=4, pipelined=False,
                               net_bw=N, staging_bw=P)
    dma = 0.7 * P
    expect = 1e9 / dma + 0.5e9 / N + 4 * (3e-7 + 1e-6)   # + per-chunk latency
    assert seq.seconds == pytest.approx(expect, rel=1e-6)


# ----------------------------------------------------------------------
# charz replay
# ----------------------------------------------------------------------

def test_charz_replay_overlaps_independent_groups():
    from repro.core.charz import TrafficSummary, replay
    fabric = Fabric.of(
        Path("ici:model", 100.0, shared_group="ici"),
        Path("ici:data", 100.0, shared_group="ici"),
        Path("dcn:pod", 10.0, shared_group="dcn"),
        concurrency_discount=0.1)
    s = TrafficSummary(per_path={"ici:model": 90.0, "ici:data": 90.0,
                                 "dcn:pod": 5.0, "ici:?": 1e9},
                       per_op={}, op_counts={})
    t = replay(s, fabric)
    # the two ici flows discount each other (until dcn? no: separate
    # groups don't interact) -> each runs at 90 for 1s; dcn overlaps.
    assert t == pytest.approx(1.0)
    # empty summary replays in zero time
    empty = TrafficSummary(per_path={}, per_op={}, op_counts={})
    assert replay(empty, fabric) == 0.0


def test_charz_replay_on_shared_clock_stops_at_own_completion():
    """Embedding a replay in a larger timeline must not drain the host
    timeline's later events or include them in the elapsed time."""
    from repro.core.charz import TrafficSummary, replay
    fabric = Fabric.of(Path("p", 10.0))
    clock = SimClock(start=2.0)
    foreign = []
    clock.schedule(999.0, lambda: foreign.append("ran"))
    s = TrafficSummary(per_path={"p": 10.0}, per_op={}, op_counts={})
    assert replay(s, fabric, clock=clock) == pytest.approx(1.0)
    assert clock.now == pytest.approx(3.0)
    assert foreign == []                 # the t=1001 event is still pending
    clock.run()
    assert foreign == ["ran"]


# ----------------------------------------------------------------------
# (c) staged vs synchronous serving engine
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    from repro.configs import get_config
    from repro.models.params import init_params
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve_fabric():
    return Fabric.of(Path("prefill", 16.0), Path("decode", 10.0))


def _requests(cfg, n=8, plen=8, max_new=4):
    from repro.serve.engine import Request
    rng = np.random.default_rng(7)
    return [  # bursty: everyone arrives at t=0
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                max_new_tokens=max_new, arrival=0.0)
        for i in range(n)]


def _p99(ttfts):
    arr = sorted(ttfts)
    return arr[min(len(arr) - 1, int(math.ceil(0.99 * len(arr))) - 1)]


def test_staged_engine_beats_sync_p99_ttft_with_identical_tokens(small_lm):
    from repro.serve.engine import (ServeEngine, ServeTimeModel,
                                    StagedServeEngine)
    cfg, params = small_lm
    tm = ServeTimeModel(prefill_path="prefill", decode_path="decode",
                        prefill_units_per_token=1.0, decode_units_per_slot=1.0)

    sync = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                       runtime=FabricRuntime(_serve_fabric()), time_model=tm)
    sync_reqs = _requests(cfg)
    for r in sync_reqs:
        sync.submit(r)
    sync.run()

    staged = StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                               fabric=_serve_fabric(), time_model=tm)
    staged_reqs = _requests(cfg)
    for r in staged_reqs:
        staged.submit(r)
    done = staged.run()

    assert all(r.done for r in sync_reqs)
    assert all(r.done for r in staged_reqs)
    assert sorted(r.rid for r in done) == [r.rid for r in sync_reqs]
    # identical output tokens: overlap changes *when*, never *what*
    for a, b in zip(sync_reqs, staged_reqs):
        assert a.out_tokens == b.out_tokens, a.rid
    sync_p99 = _p99([r.ttft for r in sync_reqs])
    staged_p99 = _p99([r.ttft for r in staged_reqs])
    assert staged_p99 < sync_p99, (staged_p99, sync_p99)
    # the staged engine finishes the whole trace no later than sync
    assert max(r.finish_time for r in staged_reqs) <= \
        max(r.finish_time for r in sync_reqs) + 1e-9


def test_sync_engine_serves_future_arrivals(small_lm):
    """Regression: run(until=...) on an empty heap must advance the
    clock, or the sync engine spins forever on a future arrival."""
    from repro.serve.engine import Request, ServeEngine, ServeTimeModel
    cfg, params = small_lm
    tm = ServeTimeModel(prefill_path="prefill", decode_path="decode")
    eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                      runtime=FabricRuntime(_serve_fabric()), time_model=tm)
    rng = np.random.default_rng(17)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3, arrival=0.5 + i) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_steps=100)
    assert [r.rid for r in done] == [0, 1]
    for r in reqs:
        assert r.done and r.first_token_time >= r.arrival


def test_staged_engine_staggered_arrivals(small_lm):
    """Requests arriving mid-flight join the pipeline; TTFT is measured
    from each request's own arrival."""
    from repro.serve.engine import ServeTimeModel, StagedServeEngine
    cfg, params = small_lm
    tm = ServeTimeModel(prefill_path="prefill", decode_path="decode")
    eng = StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                            fabric=_serve_fabric(), time_model=tm)
    rng = np.random.default_rng(3)
    from repro.serve.engine import Request
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3, arrival=0.7 * i) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.first_token_time is not None and r.ttft >= 0.0
        assert r.first_token_time >= r.arrival + 8 / 16.0 - 1e-9


def test_staged_engine_placement_reacts_to_live_ledger(small_lm):
    """AdmitStage re-plans the §5.2 placement per admitted request from
    live ledger occupancy: with the SoC read path mostly spoken for, the
    plan flips from soc_cache to host."""
    from repro.serve.disagg import kv_fabric, plan_decode_placement
    cfg, params = small_lm
    fabric = kv_fabric()
    ledger = fabric.ledger()
    fresh = plan_decode_placement(fabric, ledger=ledger)
    assert fresh.location == "soc_cache"
    # a tenant eats nearly all of the SoC-side read budget
    ledger.reserve("soc_read", out=0.95 * fabric["soc_read"].capacity,
                   flow="tenant")
    live = plan_decode_placement(fabric, ledger=ledger)
    assert live.location == "host"
    assert live.rate < fresh.rate


def test_staged_engine_counts_placements(small_lm):
    from repro.serve.disagg import kv_fabric, kv_serve_time_model
    from repro.serve.engine import Request, StagedServeEngine
    cfg, params = small_lm
    eng = StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                            fabric=kv_fabric(),
                            time_model=kv_serve_time_model(),
                            plan_placement=True)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert sum(eng.placements.values()) == 4
    assert all(r.placement in ("soc_cache", "host") for r in reqs)


# ----------------------------------------------------------------------
# prefill bucketing (satellite)
# ----------------------------------------------------------------------

def test_prefill_bucketing_counts_compilations(small_lm):
    from repro.serve.engine import Request, ServeEngine
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref")
    rng = np.random.default_rng(11)
    # lengths 5, 7, 8 -> one 8-bucket; 13 -> one 16-bucket
    for i, plen in enumerate((5, 7, 8, 13)):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=2))
    eng.run()
    assert eng.stats["prefill_compilations"] == 2
    assert eng.stats["prefill_tokens"] == 5 + 7 + 8 + 13
    assert eng.stats["prefill_padded_tokens"] == 3 + 1 + 0 + 3


def test_prefill_bucketing_matches_exact(small_lm):
    """Padded prefill must be bit-identical to exact-length prefill."""
    from repro.serve.engine import Request, ServeEngine
    cfg, params = small_lm
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 11, 13)]
    outs = {}
    for bucketed in (True, False):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                          bucket_prefill=bucketed)
        reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[bucketed] = [r.out_tokens for r in reqs]
    assert outs[True] == outs[False]


# ----------------------------------------------------------------------
# fast event core: heap compaction + indexed bucket accessors
# ----------------------------------------------------------------------

def test_clock_compacts_tombstones_and_keeps_order():
    clock = SimClock()
    hits = []
    keep = [clock.schedule(100.0 + i, lambda i=i: hits.append(i))
            for i in range(4)]
    dead = [clock.schedule(float(i), lambda: hits.append("dead"))
            for i in range(4 * SimClock.COMPACT_MIN)]
    for ev in dead:
        clock.cancel(ev)
    # compaction fired (tombstones dominated the heap) and dropped them
    assert clock.compactions >= 1
    assert clock.pending == len(keep)
    clock.cancel(dead[0])          # double-cancel: no tombstone recount
    assert clock.pending == len(keep)
    clock.run()
    assert hits == [0, 1, 2, 3]    # survivors fire in time order
    assert clock.pending == 0


def test_clock_small_cancel_counts_never_compact():
    clock = SimClock()
    ev = clock.schedule(1.0, lambda: None)
    clock.schedule(2.0, lambda: None)
    clock.cancel(ev)
    assert clock.compactions == 0 and clock.pending == 1


def test_active_transfers_and_occupancy_are_per_path():
    fabric = Fabric.of(Path("a", 10.0), Path("b", 10.0))
    rt = FabricRuntime(fabric)
    ta = rt.transfer("a", 5.0, flow="fa")
    tb1 = rt.transfer("b", 5.0, flow="fb")
    tb2 = rt.transfer("b", 100.0, flow="fb2")
    rt.clock.run(until=0.1)
    assert rt.active_transfers("a") == [ta]
    assert set(rt.active_transfers("b")) == {tb1, tb2}
    assert set(rt.active_transfers()) == {ta, tb1, tb2}
    assert rt.occupancy("a", OUT) > 0 and rt.occupancy("a", "in") == 0.0
    rt.clock.run(until=5.0)        # a and b's short transfer complete
    assert ta.done and tb1.done and not tb2.done
    assert rt.active_transfers("a") == []
    assert rt.active_transfers("b") == [tb2]
    assert rt.occupancy("a", OUT) == 0.0
    rt.clock.run()
    assert rt.active_transfers() == []


def test_runtime_rejects_unknown_rebalance_mode():
    with pytest.raises(ValueError):
        FabricRuntime(Fabric.of(Path("p", 1.0)), rebalance="bogus")


def test_global_mode_matches_incremental_end_to_end():
    """One mixed workload (shared group, tenant weights via max_rate
    caps, cancels) must end at the same simulated instant with the
    same per-transfer finish times in both rebalance modes."""
    def run(mode):
        fabric = Fabric.of(Path("h", 100.0, shared_group="g"),
                           Path("s", 40.0, shared_group="g"),
                           concurrency_discount=0.2)
        rt = FabricRuntime(fabric, rebalance=mode)
        ts = [rt.transfer("h" if i % 2 else "s", 10.0 + i,
                          flow=f"f{i % 3}", max_rate=25.0 if i % 4 else 1e9)
              for i in range(12)]
        rt.clock.at(0.5, lambda: rt.cancel(ts[3]))
        rt.clock.run()
        return [(t.finished_at, t.canceled, t.remaining) for t in ts], \
            rt.clock.now, rt.clock.processed

    assert run("incremental") == run("global")
