"""Sharding-spec construction for every (arch x shape x mesh) cell —
no compilation, so the whole 40-cell matrix is validated in seconds.

Guards the invariants the dry-run relies on:
- every param leaf gets a PartitionSpec whose sharded dims divide;
- batch specs shard the batch dim over (pod, data) when divisible;
- decode caches pick the right strategy (head-sharded vs seq-sharded vs
  context-parallel) per arch/shape;
- abstract params match the real init's structure.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.inputs import batch_specs, decode_specs
from repro.models.params import abstract_params
from repro.models.model import init_cache_logical
from repro.parallel.sharding import CONTEXT_PARALLEL_OVERRIDES, logical_to_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESHES = {
    "16x16": FakeMesh({"data": 16, "model": 16}),
    "2x16x16": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}

IS_LG = lambda x: isinstance(x, tuple) and all(
    isinstance(e, (str, type(None))) for e in x)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_param_specs_divide(arch, mesh_name):
    cfg = get_config(arch)
    mesh = MESHES[mesh_name]
    shapes, logical = abstract_params(cfg)
    sl = jax.tree.leaves(shapes)
    ll = jax.tree.leaves(logical, is_leaf=IS_LG)
    assert len(sl) == len(ll)
    for spec_shape, lg in zip(sl, ll):
        spec = logical_to_spec(lg, mesh, dim_sizes=spec_shape.shape)
        for dim, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert spec_shape.shape[dim] % n == 0, (arch, lg, spec_shape.shape, spec)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", list(SHAPES))
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_batch_and_cache_specs(arch, shape_name, mesh_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("cell skipped by design")
    mesh = MESHES[mesh_name]

    bs = batch_specs(cfg, shape)
    assert bs["tokens"].dtype == jnp.int32
    spec = logical_to_spec(("batch",) + (None,) * (len(bs["tokens"].shape) - 1),
                           mesh, dim_sizes=bs["tokens"].shape)
    total = shape.global_batch
    if shape_name != "long_500k":
        # batch must actually shard over (pod, data)
        axes = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
        n = 1
        for a in axes:
            if a:
                n *= mesh.shape[a]
        assert total % max(n, 1) == 0

    if shape.kind == "decode":
        tok, cache, pos = decode_specs(cfg, shape)
        logical = init_cache_logical(cfg)
        cl = jax.tree.leaves(cache)
        ll = jax.tree.leaves(logical, is_leaf=IS_LG)
        assert len(cl) == len(ll)
        overrides = CONTEXT_PARALLEL_OVERRIDES if shape_name == "long_500k" else None
        for spec_shape, lg in zip(cl, ll):
            sp = logical_to_spec(lg, mesh, dim_sizes=spec_shape.shape,
                                 overrides=overrides)
            for dim, part in enumerate(sp):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                assert spec_shape.shape[dim] % n == 0, (arch, lg, spec_shape.shape)


@pytest.mark.parametrize("arch", list_archs())
def test_abstract_params_match_real_init_structure(arch):
    cfg = get_config(arch).reduced()
    from repro.models.params import init_params
    shapes, _ = abstract_params(cfg)
    real, _ = init_params(cfg, jax.random.PRNGKey(0))
    assert jax.tree_util.tree_structure(shapes) == jax.tree_util.tree_structure(real)
    for a, b in zip(jax.tree.leaves(shapes), jax.tree.leaves(real)):
        assert a.dtype == b.dtype


def test_full_configs_memory_budget():
    """fp32 master + moments (int8 for >100B) must fit 16 GiB/chip on the
    single-pod mesh — the runnability gate the dry-run verifies."""
    HBM = 16 * 2**30
    for arch in list_archs():
        cfg = get_config(arch)
        n = cfg.param_count()
        big = n > 100e9
        opt_bytes = n * (4 + (2 if big else 8))     # master + m,v
        weights_bf16 = n * 2
        per_chip = (opt_bytes + weights_bf16) / 256
        assert per_chip < 0.8 * HBM, (arch, per_chip / 2**30)
