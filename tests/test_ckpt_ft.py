"""Checkpointing, replication, failure detection, elastic re-mesh."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.ckpt.replication import plan_replication
from repro.ft.manager import FaultToleranceManager
from repro.ft.elastic import best_mesh_for
from repro.ft.straggler import StragglerDetector


def _tree(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return {"a": jax.random.normal(ks[0], (16, 8)),
            "nested": {"b": jax.random.normal(ks[1], (4,)),
                       "c": jnp.asarray(3, jnp.int32)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    stats = save_checkpoint(str(tmp_path / "ck"), t, step=7)
    assert stats["ratio"] <= 1.0
    back, step = load_checkpoint(str(tmp_path / "ck"), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert jnp.array_equal(a, b)


def test_corruption_detected(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path / "ck"), t, step=1)
    # flip a byte in the payload (extension depends on available codec)
    fn = next((tmp_path / "ck").glob("data.npz*"))
    raw = bytearray(fn.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    fn.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path / "ck"), t)


def test_chain_replica_fallback(tmp_path):
    """Primary destroyed -> restore from replica (LineFS chain)."""
    t = _tree()
    mgr = CheckpointManager(str(tmp_path / "primary"), every=1, replicas=2)
    mgr.save(10, t, blocking=True)
    # destroy the primary copy AND replica 0
    shutil.rmtree(mgr._step_dir(10))
    shutil.rmtree(mgr._step_dir(10, mgr.replica_dirs[0]))
    back, step = mgr.restore(t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert jnp.array_equal(a, b)


def test_retention_gc(tmp_path):
    t = _tree()
    mgr = CheckpointManager(str(tmp_path / "p"), every=1, keep=2)
    for s in (1, 2, 3):
        mgr.save(s, t, blocking=True)
    steps = mgr._complete_steps(mgr.dir)
    assert steps == [2, 3]


def test_failure_detection_and_recovery(tmp_path):
    clock = {"t": 0.0}
    mgr = CheckpointManager(str(tmp_path / "p"), every=1)
    ft = FaultToleranceManager(mgr, timeout=5.0, clock=lambda: clock["t"])
    for n in ("host0", "host1", "host2"):
        ft.register(n, devices=8)
    t = _tree()
    mgr.save(42, t, blocking=True)
    clock["t"] = 3.0
    ft.heartbeat("host0"); ft.heartbeat("host1")     # host2 goes silent
    clock["t"] = 7.0
    failed = ft.check()
    assert failed == ["host2"]
    assert ft.alive_devices() == 16
    back, resume = ft.recover(t)
    assert resume == 43


def test_elastic_mesh_choice():
    assert best_mesh_for(512, model=16) == ((2, 16, 16), ("pod", "data", "model"))
    assert best_mesh_for(256, model=16, prefer_pods=1) == ((16, 16), ("data", "model"))
    # one host of 8 lost from 256: 248 = 31 * 8
    shape, names = best_mesh_for(248, model=16)
    assert np.prod(shape) <= 248 and shape[-1] <= 16


def test_elastic_mesh_edge_cases():
    # single device: everything degrades to a 1x1 data/model mesh
    assert best_mesh_for(1, model=16) == ((1, 1), ("data", "model"))
    # prime device count: TP shrinks to 1, all devices go to data
    assert best_mesh_for(7, model=4) == ((7, 1), ("data", "model"))
    # device count not divisible by the TP degree: TP halves until it fits
    shape, names = best_mesh_for(12, model=8)
    assert shape == (3, 4) and names == ("data", "model")
    # never over-commits: the mesh always fits the surviving devices
    for devices in (1, 2, 3, 5, 6, 9, 11, 24, 100):
        shape, _ = best_mesh_for(devices, model=16)
        assert 1 <= np.prod(shape) <= devices


def test_reshard_round_trip_preserves_values():
    import jax.numpy as jnp
    from repro.ft.elastic import make_mesh, reshard
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
            "b": jnp.ones((4,), jnp.float32)}
    logical = {"w": ("fsdp", "mlp"), "b": ("embed",)}
    shape, names = best_mesh_for(len(jax.devices()), model=1)
    mesh = make_mesh(shape, names)
    out = reshard(tree, logical, mesh)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert jnp.array_equal(a, b)


def test_ft_event_driven_timeout_on_runtime():
    """Runtime mode: a silent node's watchdog fires the failure Signal
    in simulated time, with no polling; a heartbeating node survives."""
    from repro.core.fabric import Fabric, Path
    from repro.core.runtime import FabricRuntime
    rt = FabricRuntime(Fabric.of(Path("p", 1.0)))
    ft = FaultToleranceManager(None, timeout=1.0, runtime=rt)
    ft.register("steady", devices=4)
    ft.register("silent", devices=4)
    fired = []
    ft.failed.wait(lambda name: fired.append((name, rt.clock.now)))
    hb = rt.every(0.4, lambda: ft.heartbeat("steady"), start_delay=0.0)
    rt.clock.run(until=3.0)
    assert [n for n, _ in fired] == ["silent"]
    assert fired[0][1] == pytest.approx(1.0)
    assert ft.nodes["steady"].alive and not ft.nodes["silent"].alive
    assert ft.alive_devices() == 4
    hb.kill()
    ft.disarm()


def test_ft_simultaneous_timeouts_queue_every_failure():
    """Two watchdogs expiring at the same instant: Signal.fire drops a
    value when no waiter is registered, so the queue must carry both."""
    from repro.core.fabric import Fabric, Path
    from repro.core.runtime import FabricRuntime
    rt = FabricRuntime(Fabric.of(Path("p", 1.0)))
    ft = FaultToleranceManager(None, timeout=1.0, runtime=rt)
    ft.register("a", devices=2)
    ft.register("b", devices=2)            # same instant, same expiry
    rt.clock.run(until=2.0)
    assert sorted(ft.pending_failures) == ["a", "b"]
    assert not ft.nodes["a"].alive and not ft.nodes["b"].alive
    assert ft.alive_devices() == 0


def test_straggler_detection_and_rebalance():
    det = StragglerDetector(threshold=1.5)
    for _ in range(5):
        det.observe("n0", 1.0); det.observe("n1", 1.1)
        det.observe("n2", 1.0); det.observe("slow", 2.5)
    assert det.stragglers() == ["slow"]
    shares = det.rebalanced_shares(32)
    assert sum(shares.values()) == 32
    assert shares["slow"] < shares["n0"]


def test_replication_plan_uses_compression_when_ratio_good():
    good = plan_replication(ratio=0.3)
    bad = plan_replication(ratio=0.95, soc_rate=2e9)
    assert good.total_rate > 0
    assert good.ranked[0] in ("A2", "A3")
    # with poor ratio and a weak compressor, direct A3 must rank first
    assert bad.ranked[0] == "A3"
