"""§4.2 routing: reproduce the paper's own analytics (Fabric API)."""
import math

import pytest

from repro.core.fabric import (Alternative, MultipathRouter, Use,
                               linefs_fabric,
                               linefs_replication_alternatives)
from repro.core.compression import compression_wins, offload_path_bandwidth

N = 200e9 / 8   # paper testbed: 200 Gbps network
P = 256e9 / 8   # 256 Gbps internal PCIe


def test_linefs_a1_peak_matches_paper():
    """Paper §5.1: without compression A1 peaks at 128 Gbps."""
    fabric = linefs_fabric(N, P)
    a1 = linefs_replication_alternatives(N, P, ratio=1.0)[0]
    assert abs(a1.solo_rate(fabric) * 8 / 1e9 - 128) < 1


def test_linefs_compression_threshold():
    """Paper §5.1: A1 beats direct send iff ratio < P/N - 1 = 28%."""
    fabric = linefs_fabric(N, P)
    for ratio, wins in [(0.2, True), (0.27, True), (0.29, False), (0.5, False)]:
        alts = linefs_replication_alternatives(N, P, ratio)
        a1, a3 = alts[0], alts[2]
        assert (a1.solo_rate(fabric) > a3.solo_rate(fabric)) == wins, ratio
        assert compression_wins(N, P, ratio) == wins


def test_offload_bandwidth_formula():
    assert abs(offload_path_bandwidth(P, 1.0) - P / 2) < 1
    assert abs(offload_path_bandwidth(P, 0.0) - P) < 1


def test_greedy_combine_exceeds_solo():
    """A2 (SoC-capped) + A3 fills the leftover network (Fig 15)."""
    fabric = linefs_fabric(N, P)
    alts = linefs_replication_alternatives(N, P, ratio=0.5, soc_rate=12e9)
    router = fabric.router()
    allocs, total = router.allocate([alts[1], alts[2]])
    assert total > alts[1].solo_rate(fabric)
    assert total > 0.9 * alts[2].solo_rate(fabric)
    assert allocs[0].bottleneck == "compute"          # SoC caps A2
    assert allocs[1].bottleneck.startswith("net")     # A3 fills network


def test_bidirectional_multiplexing():
    """Fig 5: opposite-direction flows on one link reach ~2x one-way."""
    fabric = linefs_fabric(N, P)
    read = Alternative("read", uses=[Use("net", out=1)])
    write = Alternative("write", uses=[Use("net", in_=1)])
    router = fabric.router()
    _, total = router.allocate([read, write])
    assert abs(total - 2 * N) / (2 * N) < 1e-6
    # same-direction flows share one budget
    read2 = Alternative("read2", uses=[Use("net", out=1)])
    _, total_same = router.allocate([read, read2])
    assert abs(total_same - N) / N < 1e-6


def test_double_crossing_consumes_both_directions():
    """Paper path-③: crossing a link twice exhausts the bidirectional
    budget — adding an opposite flow gains nothing."""
    fabric = linefs_fabric(N, P)
    relay = Alternative("relay", uses=[Use("internal", out=1, in_=1)])
    other = Alternative("other", uses=[Use("internal", out=1)])
    router = fabric.router()
    _, solo = router.allocate([relay])
    allocs, total = router.allocate([relay, other])
    assert abs(solo - P) / P < 1e-6          # capped at uni-directional P
    assert total == solo                      # nothing left for `other`
    assert allocs[1].rate == 0.0


def test_slack_rule():
    """B_slow <= P - N: after the primary saturates the network, the
    internal link retains P - N for offload traffic."""
    fabric = linefs_fabric(N, P)
    primary = Alternative("primary", uses=[Use("net", out=1),
                                           Use("internal", out=1)])
    slack = fabric.router().slack(primary, "internal")
    assert abs(slack - (P - N)) / P < 1e-6


def test_solo_rate_against_live_ledger():
    """solo_rate(ledger=...) sees remaining budgets + the discount from
    live holders, not the pristine fabric."""
    fabric = linefs_fabric(N, P)
    alt = Alternative("a3", uses=[Use("net", out=1)])
    assert alt.solo_rate(fabric) == pytest.approx(N)
    ledger = fabric.ledger()
    ledger.reserve("net", out=0.5 * N, flow="primary")
    live = alt.solo_rate(fabric, ledger=ledger)
    # half the budget is spoken for; joining makes 2 holders — the
    # fabric has no discount configured here so it is exactly the rest
    assert live == pytest.approx(0.5 * N)


def test_drtm_kv_calibration():
    """§5.2 / Fig 17-18 reproduction within a few percent."""
    from repro.serve.disagg import DisaggKV, KVStoreParams
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    paths, alts = kv.fabric(), kv.alternatives()
    assert abs(alts["A1"].solo_rate(paths) / 1e6 - 50) < 3
    assert abs(alts["A4"].solo_rate(paths) / 1e6 - 58.3) < 3
    assert abs(alts["A5"].solo_rate(paths) / 1e6 - 70) < 3
    total, _ = kv.combined_a4_a5()
    assert abs(total / 1e6 - 68) < 4
    # orderings from Fig 17(a)
    lat = {k: a.criteria["latency_us"] for k, a in alts.items()}
    assert lat["A5"] < lat["A4"] < lat["A1"] < lat["A2"]
