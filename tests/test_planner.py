"""§4.2 planner: reproduce the paper's own analytics."""
import math

import pytest

from repro.core.planner import (Alternative, PathPlanner, PathUse,
                                linefs_alternatives, linefs_paths)
from repro.core.compression import compression_wins, offload_path_bandwidth

N = 200e9 / 8   # paper testbed: 200 Gbps network
P = 256e9 / 8   # 256 Gbps internal PCIe


def test_linefs_a1_peak_matches_paper():
    """Paper §5.1: without compression A1 peaks at 128 Gbps."""
    paths = linefs_paths(N, P)
    a1 = linefs_alternatives(N, P, ratio=1.0)[0]
    assert abs(a1.solo_rate(paths) * 8 / 1e9 - 128) < 1


def test_linefs_compression_threshold():
    """Paper §5.1: A1 beats direct send iff ratio < P/N - 1 = 28%."""
    paths = linefs_paths(N, P)
    for ratio, wins in [(0.2, True), (0.27, True), (0.29, False), (0.5, False)]:
        alts = linefs_alternatives(N, P, ratio)
        a1, a3 = alts[0], alts[2]
        assert (a1.solo_rate(paths) > a3.solo_rate(paths)) == wins, ratio
        assert compression_wins(N, P, ratio) == wins


def test_offload_bandwidth_formula():
    assert abs(offload_path_bandwidth(P, 1.0) - P / 2) < 1
    assert abs(offload_path_bandwidth(P, 0.0) - P) < 1


def test_greedy_combine_exceeds_solo():
    """A2 (SoC-capped) + A3 fills the leftover network (Fig 15)."""
    paths = linefs_paths(N, P)
    alts = linefs_alternatives(N, P, ratio=0.5, soc_rate=12e9)
    pl = PathPlanner(paths)
    allocs, total = pl.combine_greedy([alts[1], alts[2]])
    assert total > alts[1].solo_rate(paths)
    assert total > 0.9 * alts[2].solo_rate(paths)
    assert allocs[0].bottleneck == "compute"          # SoC caps A2
    assert allocs[1].bottleneck.startswith("net")     # A3 fills network


def test_bidirectional_multiplexing():
    """Fig 5: opposite-direction flows on one link reach ~2x one-way."""
    paths = linefs_paths(N, P)
    read = Alternative("read", uses=[PathUse("net", out_bytes=1)])
    write = Alternative("write", uses=[PathUse("net", in_bytes=1)])
    pl = PathPlanner(paths)
    _, total = pl.combine_greedy([read, write])
    assert abs(total - 2 * N) / (2 * N) < 1e-6
    # same-direction flows share one budget
    read2 = Alternative("read2", uses=[PathUse("net", out_bytes=1)])
    _, total_same = pl.combine_greedy([read, read2])
    assert abs(total_same - N) / N < 1e-6


def test_double_crossing_consumes_both_directions():
    """Paper path-③: crossing a link twice exhausts the bidirectional
    budget — adding an opposite flow gains nothing."""
    paths = linefs_paths(N, P)
    relay = Alternative("relay", uses=[PathUse("internal", out_bytes=1, in_bytes=1)])
    other = Alternative("other", uses=[PathUse("internal", out_bytes=1)])
    pl = PathPlanner(paths)
    _, solo = pl.combine_greedy([relay])
    allocs, total = pl.combine_greedy([relay, other])
    assert abs(solo - P) / P < 1e-6          # capped at uni-directional P
    assert total == solo                      # nothing left for `other`
    assert allocs[1].rate == 0.0


def test_slack_rule():
    """B_slow <= P - N: after the primary saturates the network, the
    internal link retains P - N for offload traffic."""
    paths = linefs_paths(N, P)
    primary = Alternative("primary", uses=[PathUse("net", out_bytes=1),
                                           PathUse("internal", out_bytes=1)])
    pl = PathPlanner(paths)
    slack = pl.slack(primary, "internal")
    assert abs(slack - (P - N)) / P < 1e-6


def test_drtm_kv_calibration():
    """§5.2 / Fig 17-18 reproduction within a few percent."""
    from repro.serve.disagg import DisaggKV, KVStoreParams
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    paths, alts = kv.paths(), kv.alternatives()
    assert abs(alts["A1"].solo_rate(paths) / 1e6 - 50) < 3
    assert abs(alts["A4"].solo_rate(paths) / 1e6 - 58.3) < 3
    assert abs(alts["A5"].solo_rate(paths) / 1e6 - 70) < 3
    total, _ = kv.combined_a4_a5()
    assert abs(total / 1e6 - 68) < 4
    # orderings from Fig 17(a)
    lat = {k: a.criteria["latency_us"] for k, a in alts.items()}
    assert lat["A5"] < lat["A4"] < lat["A1"] < lat["A2"]
