"""AdamW with sharded (ZeRO) state and optional int8 moments.

State sharding is inherited from the parameter sharding (fsdp x model):
because master params, m and v carry the same logical axes as the
weights, jit out_shardings partition them identically — ZeRO-3 without
bespoke machinery.

``moments="int8"`` stores m/v blockwise-int8 (paper theme: compress what
crosses/occupies a scarce resource — here HBM capacity). This is what
lets jamba-398B's optimizer fit the 16 GiB/chip budget (DESIGN.md §4);
the quantizer is the kernels/quant hot spot.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import (Quantized, dequantize_int8_blockwise,
                                    quantize_int8_blockwise)

PyTree = Any
_QBLOCK = 256


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree                 # f32 arrays or Quantized pairs
    v: PyTree


def _is_quant(x):
    return isinstance(x, Quantized)


def _maybe_quant(x: jax.Array, mode: str):
    if mode == "int8":
        return quantize_int8_blockwise(x, _QBLOCK)
    return x


def _maybe_dequant(x, shape):
    if _is_quant(x):
        return dequantize_int8_blockwise(x, shape)
    return x


def adamw_init(params: PyTree, *, moments: str = "f32") -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _maybe_quant(z, moments)
    m = jax.tree.map(zero_like, params)
    v = jax.tree.map(zero_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0,
                 moments: str = "f32") -> Tuple[PyTree, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)) if grad_clip > 0 else 1.0

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_p, new_m, new_v = [], [], []
    for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale
        mf = _maybe_dequant(m, g.shape)
        vf = _maybe_dequant(v, g.shape)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:                       # decay matrices only
            update = update + weight_decay * pf
        pf = pf - lr * update
        new_p.append(pf.astype(p.dtype))
        new_m.append(_maybe_quant(mf, "int8") if _is_quant(m) else mf)
        new_v.append(_maybe_quant(vf, "int8") if _is_quant(v) else vf)

    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = AdamWState(step=step,
                        m=jax.tree.unflatten(treedef, new_m),
                        v=jax.tree.unflatten(treedef, new_v))
    return params2, state2, {"grad_norm": gnorm}
