from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import lr_at
