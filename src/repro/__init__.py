"""repro: off-path SmartNIC characterization, rebuilt for TPU meshes."""
from repro import _jax_compat  # noqa: F401  (patches old jax in place)
