"""Tenant classes and QoS weights for fabric sharing (paper §6).

The paper's multi-tenant observation is that host- and SoC-side paths
degrade very differently once a co-runner loads one direction; the
conclusion this module encodes is that path sharing must be *policied*,
not emergent. Two tenant classes cover the serving+training colocation
study:

``LATENCY``      a tenant whose SLO is a tail-latency bound (time to
                 first token for the serve engine). It gets a large
                 fair-share weight so its short transfers see most of a
                 path's capacity even mid-gradient-burst.
``THROUGHPUT``   a tenant whose metric is aggregate progress (train
                 tokens/s). Weight 1: it soaks up whatever the latency
                 tenants leave idle, which on a mostly-idle path is
                 almost everything.

``QoSPolicy`` is the object a ``FabricRuntime`` consults per transfer
(duck-typed: the runtime only calls ``weight(tenant)``); the weighted
max-min split in ``FabricRuntime._rebalance`` does the rest. Weights
are *ratios*, not reservations — an absent tenant costs nothing, and
the §4.1 concurrency discount still emerges from flow count exactly as
in the unweighted runtime.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional

LATENCY = "latency"
THROUGHPUT = "throughput"
_CLASSES = (LATENCY, THROUGHPUT)

#: canonical tenant tags used by the colocation harness; OFFLOAD tags
#: the SoC compute tier's programs (offload/) when they share a fabric
SERVE, TRAIN = "serve", "train"
OFFLOAD = "offload"


@dataclass(frozen=True)
class Tenant:
    """One workload sharing the fabric: a name (the tag on its
    transfers), a class, its fair-share weight, and an admission
    ``priority`` — higher-priority latency tenants are protected first
    when K tenants contend (tenancy/admission.FleetAdmissionController);
    weights shape *rates*, priorities order *deferral*."""
    name: str
    tenant_class: str = THROUGHPUT
    weight: float = 1.0
    priority: int = 0

    def __post_init__(self):
        if self.tenant_class not in _CLASSES:
            raise ValueError(f"tenant {self.name}: unknown class "
                             f"{self.tenant_class!r} (have {_CLASSES})")
        if not self.weight > 0:
            raise ValueError(f"tenant {self.name}: weight must be > 0, "
                             f"got {self.weight}")


class QoSPolicy:
    """Tenant registry + weight lookup for the runtime's weighted
    fair-share. Unregistered tenants (and untagged transfers) weigh
    ``default_weight`` — colocating an unpolicied flow degrades
    gracefully to equal sharing instead of starving anyone."""

    def __init__(self, tenants: Iterable[Tenant] = (), *,
                 default_weight: float = 1.0):
        if not default_weight > 0:
            raise ValueError("default_weight must be > 0")
        self.default_weight = float(default_weight)
        self._tenants: Dict[str, Tenant] = {}
        for t in tenants:
            self.add(t)

    def add(self, tenant: Tenant) -> "QoSPolicy":
        if tenant.name in self._tenants:
            raise ValueError(f"duplicate tenant {tenant.name!r}")
        self._tenants[tenant.name] = tenant
        return self

    # -- the runtime's contract ----------------------------------------
    def weight(self, tenant: Optional[str]) -> float:
        t = self._tenants.get(tenant) if tenant is not None else None
        return t.weight if t is not None else self.default_weight

    # -- introspection --------------------------------------------------
    def tenant_class(self, tenant: Optional[str]) -> str:
        t = self._tenants.get(tenant) if tenant is not None else None
        return t.tenant_class if t is not None else THROUGHPUT

    def __getitem__(self, name: str) -> Tenant:
        return self._tenants[name]

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def __iter__(self):
        return iter(self._tenants.values())

    def __repr__(self) -> str:
        parts = ", ".join(f"{t.name}({t.tenant_class})x{t.weight:g}"
                          for t in self)
        return f"QoSPolicy({parts}; default={self.default_weight:g})"

    @classmethod
    def serve_train(cls, serve_weight: float = 16.0,
                    train_weight: float = 1.0) -> "QoSPolicy":
        """The colocation study's policy: a latency-class serve tenant
        promised ``serve_weight/(serve_weight+train_weight)`` of any
        path it contends on, over a throughput-class train tenant."""
        return cls([Tenant(SERVE, LATENCY, serve_weight),
                    Tenant(TRAIN, THROUGHPUT, train_weight)])

    @classmethod
    def serve_train_offload(cls, serve_weight: float = 16.0,
                            train_weight: float = 1.0,
                            offload_weight: float = 2.0) -> "QoSPolicy":
        """``serve_train`` plus the offload tier as a third
        throughput-class tenant: SoC programs (checkpoint compression,
        KV filtering) get a modest weight so they drain promptly on the
        devices they own without starving train staging on the wires
        they share."""
        return cls.serve_train(serve_weight, train_weight).add(
            Tenant(OFFLOAD, THROUGHPUT, offload_weight))

    @classmethod
    def fleet(cls, tenants: Iterable[Tenant]) -> "QoSPolicy":
        """A serving-fleet policy from explicit per-tenant specs (the
        scale/ ServeFleet builds one from its FleetTenantSpecs): weights
        shape each tenant's fair share on the paths it contends on,
        priorities feed the K-tenant admission arbitration."""
        return cls(tenants)
