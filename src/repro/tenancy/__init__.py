"""Multi-tenant colocation: QoS-weighted fabric sharing, the serve+train
interference harness, and SLO-driven admission control (paper §6)."""
from repro.tenancy.admission import (AdmissionConfig, AdmissionController,
                                     AdmittedTenant,
                                     FleetAdmissionController, percentile)
from repro.tenancy.colocation import (Colocation, InterferenceReport,
                                      colocation_fabric,
                                      colocation_time_model,
                                      occupancy_ledger, serve_metrics,
                                      solo_serve, solo_train)
from repro.tenancy.qos import (LATENCY, SERVE, THROUGHPUT, TRAIN, QoSPolicy,
                               Tenant)

__all__ = [
    "AdmissionConfig", "AdmissionController", "AdmittedTenant", "Colocation",
    "FleetAdmissionController", "InterferenceReport", "LATENCY", "QoSPolicy",
    "SERVE", "THROUGHPUT", "TRAIN", "Tenant", "colocation_fabric",
    "colocation_time_model", "occupancy_ledger", "percentile",
    "serve_metrics", "solo_serve", "solo_train",
]
