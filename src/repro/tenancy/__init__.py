"""Multi-tenant colocation: QoS-weighted fabric sharing, the serve+train
interference harness, and SLO-driven admission control (paper §6)."""
from repro.tenancy.admission import (AdmissionConfig, AdmissionController,
                                     percentile)
from repro.tenancy.colocation import (Colocation, InterferenceReport,
                                      colocation_fabric,
                                      colocation_time_model, serve_metrics,
                                      solo_serve, solo_train)
from repro.tenancy.qos import (LATENCY, SERVE, THROUGHPUT, TRAIN, QoSPolicy,
                               Tenant)

__all__ = [
    "AdmissionConfig", "AdmissionController", "Colocation",
    "InterferenceReport", "LATENCY", "QoSPolicy", "SERVE", "THROUGHPUT",
    "TRAIN", "Tenant", "colocation_fabric", "colocation_time_model",
    "percentile", "serve_metrics", "solo_serve", "solo_train",
]
