"""SLO-driven admission control for serve+train colocation.

QoS weights (tenancy/qos.py) bound the *rate* a train flow can take
from a contended path, but they cannot stop the train tenant from
keeping a path busy for seconds at a time — and the paper's §6 lesson
is that a loaded direction moves tail latency, not just throughput.
The ``AdmissionController`` closes that loop: a periodic runtime
process samples the serve tenant's SLO attainment (completed TTFTs
plus the train tenant's *live ledger occupancy* of the serve paths)
and, on a violation, *defers* the train tenant's fabric traffic —
``TrainCluster.pause_transfers`` cancels the in-flight allreduce and
checkpoint transfers (their reservations return to the ledger
instantly) and the node processes park until ``resume_transfers``
re-issues the canceled remainders. Deferral, not preemption of state:
no gradient bytes are lost, the train step simply finishes later.

Resume happens when the serve tenant's tail recovers: every completion
since the pause is back inside the SLO, or the serve tenant has no
latency-critical (prefill) work left in flight.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.fabric import OUT
from repro.tenancy.qos import TRAIN


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (same convention as
    ``ReplicationTiming.percentile``; note ``np.percentile`` — used by
    the serve launcher — interpolates instead)."""
    if not samples:
        raise ValueError("percentile of no samples")
    xs = sorted(samples)
    return xs[min(len(xs) - 1, max(0, int(math.ceil(q / 100.0 * len(xs))) - 1))]


@dataclass(frozen=True)
class AdmissionConfig:
    """Policy knobs for the controller.

    ``slo_ttft``        the serve tenant's TTFT bound, seconds (e.g.
                        1.2x its solo p99).
    ``check_every``     sampling period of the controller process.
    ``window_s``        how far back completed TTFTs count toward the
                        violation check.
    ``resume_margin``   completions since the pause must be within
                        ``resume_margin * slo_ttft`` to resume early.
    ``occupancy_limit`` optional pre-emptive trigger: pause when the
                        train tenant holds more than this fraction of a
                        watched path's outbound capacity *while* the
                        serve tenant has prefill work pending — acting
                        on ledger occupancy before a tail sample is
                        even complete. ``watch_paths`` names the
                        serve-critical paths (typically the prefill
                        path); empty = TTFT-driven only.
    ``drain_chunks``    pause via ``pause_transfers(cancel=False)``:
                        in-flight transfers drain instead of being
                        canceled, and the pause takes effect at the
                        next chunk boundary — meaningful when the
                        cluster time model chunks its transfers
                        (ClusterTimeModel.chunk_bytes), where a chunk
                        is small enough that draining beats the
                        cancel/re-issue churn.
    """
    slo_ttft: float
    check_every: float = 0.01
    window_s: float = 1.0
    resume_margin: float = 1.0
    occupancy_limit: Optional[float] = None
    watch_paths: Tuple[str, ...] = ()
    drain_chunks: bool = False


class AdmissionController:
    """Watches the serve tenant, throttles the train tenant (see module
    docstring). ``engine`` needs ``ttft_log``/``prefill_backlog``;
    ``cluster`` needs ``pause_transfers``/``resume_transfers``."""

    def __init__(self, runtime, engine, cluster, config: AdmissionConfig):
        self.runtime = runtime
        self.engine = engine
        self.cluster = cluster
        self.cfg = config
        self.events: List[dict] = []
        self.throttles = 0
        self.paused = False
        self._paused_at = 0.0
        self._resumed_at = -math.inf   # violation-window floor (no thrash)
        self._proc = None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "AdmissionController":
        if self._proc is None or self._proc.done:
            self._proc = self.runtime.every(self.cfg.check_every, self._tick,
                                            name="admission", start_delay=0.0)
        return self

    def stop(self) -> None:
        """Kill the watcher; never leave the train tenant paused."""
        if self._proc is not None:
            self._proc.kill()
            self._proc = None
        if self.paused:
            self._do_resume("controller_stopped")

    # -- the control loop ------------------------------------------------
    def _train_occupancy(self) -> float:
        """Worst-case train-tenant share of the watched paths' outbound
        capacity, straight from the live ledger reservations."""
        worst = 0.0
        for path in self.cfg.watch_paths:
            held = self.runtime.occupancy(path, OUT, by_tenant=True)
            worst = max(worst, held.get(TRAIN, 0.0))
        return worst

    def _tick(self) -> None:
        now = self.runtime.clock.now
        if not self.paused:
            if self.engine.prefill_backlog == 0:
                return        # nothing latency-critical to protect
            # samples older than the last resume were already acted on —
            # counting them again would thrash pause/resume for a full
            # window after every recovery
            floor = max(now - self.cfg.window_s, self._resumed_at)
            recent = [ttft for t, ttft in self.engine.ttft_log
                      if t > floor]
            violated = bool(recent) and percentile(recent, 99) > self.cfg.slo_ttft
            crowded = (self.cfg.occupancy_limit is not None
                       and self.engine.prefill_backlog > 0
                       and self._train_occupancy() > self.cfg.occupancy_limit)
            if violated or crowded:
                self.paused = True
                self._paused_at = now
                self.throttles += 1
                if self.cfg.drain_chunks:
                    self.cluster.pause_transfers(cancel=False)
                else:
                    self.cluster.pause_transfers()
                self.events.append({
                    "t": now, "event": "throttle",
                    "reason": "slo_violation" if violated else "occupancy",
                    "p99": percentile(recent, 99) if recent else None})
            return
        since = [ttft for t, ttft in self.engine.ttft_log
                 if t >= self._paused_at]
        recovered = bool(since) and \
            percentile(since, 99) <= self.cfg.resume_margin * self.cfg.slo_ttft
        if recovered or self.engine.prefill_backlog == 0:
            self._do_resume("recovered" if recovered else "serve_idle")

    def _do_resume(self, reason: str) -> None:
        self.paused = False
        self._resumed_at = self.runtime.clock.now
        self.cluster.resume_transfers()
        self.events.append({"t": self.runtime.clock.now, "event": "resume",
                            "reason": reason})


# ----------------------------------------------------------------------
# K-tenant arbitration (the serving fleet)
# ----------------------------------------------------------------------

@dataclass
class AdmittedTenant:
    """One tenant under fleet arbitration.

    ``priority`` orders protection: a violated higher-priority latency
    tenant causes lower-priority tenants to be deferred, lowest first.
    ``slo_ttft``+``engine`` make the tenant a *watched* (violation
    source) tenant — the engine needs ``ttft_log``/``prefill_backlog``;
    ``pause``/``resume`` make it a *deferrable* (victim) tenant — e.g.
    ``StagedServeEngine.pause_intake``/``resume_intake`` for a serve
    tenant or ``TrainCluster.pause_transfers``/``resume_transfers`` for
    a colocated train tenant. A tenant may be both.
    """
    name: str
    priority: int = 0
    slo_ttft: Optional[float] = None
    engine: object = None
    pause: Optional[Callable[[], None]] = None
    resume: Optional[Callable[[], None]] = None


class FleetAdmissionController:
    """K-tenant generalization of ``AdmissionController``: when two (or
    more) latency-class tenants contend, SLO violations at a
    higher-priority tenant defer lower-priority tenants one at a time,
    lowest priority first — a LIFO stack of victims, resumed in reverse
    order once every watched tenant above them has recovered (tail back
    inside ``resume_margin * slo`` since the pause, or no
    latency-critical work pending). Deferral, never loss: a paused serve
    tenant stops *dispatching* prefills, its queued requests are served
    later with identical tokens."""

    def __init__(self, runtime, tenants: Sequence[AdmittedTenant], *,
                 check_every: float = 0.01, window_s: float = 1.0,
                 resume_margin: float = 1.0):
        if not tenants:
            raise ValueError("fleet admission needs at least one tenant")
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.runtime = runtime
        # stable order: priority desc, declaration order breaks ties
        self.tenants = sorted(tenants, key=lambda t: -t.priority)
        self.check_every = check_every
        self.window_s = window_s
        self.resume_margin = resume_margin
        self.events: List[dict] = []
        self.throttles = 0
        self._victims: List[AdmittedTenant] = []   # LIFO pause stack
        self._paused_at: Dict[str, float] = {}
        self._resumed_at = -math.inf
        self._proc = None

    @property
    def paused_tenants(self) -> List[str]:
        return [t.name for t in self._victims]

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "FleetAdmissionController":
        if self._proc is None or self._proc.done:
            self._proc = self.runtime.every(self.check_every, self._tick,
                                            name="fleet-admission",
                                            start_delay=0.0)
        return self

    def stop(self) -> None:
        """Kill the watcher; resume every deferred tenant (LIFO)."""
        if self._proc is not None:
            self._proc.kill()
            self._proc = None
        while self._victims:
            self._do_resume("controller_stopped")

    # -- the control loop ------------------------------------------------
    def _watched_above(self, victim: AdmittedTenant) -> List[AdmittedTenant]:
        return [t for t in self.tenants
                if t.priority > victim.priority
                and t.slo_ttft is not None and t.engine is not None]

    def _violated(self, t: AdmittedTenant, now: float) -> bool:
        if t.slo_ttft is None or t.engine is None:
            return False
        if t.engine.prefill_backlog == 0:
            return False      # nothing latency-critical to protect
        floor = max(now - self.window_s, self._resumed_at)
        recent = [ttft for ts, ttft in t.engine.ttft_log if ts > floor]
        return bool(recent) and percentile(recent, 99) > t.slo_ttft

    def _recovered(self, watched: AdmittedTenant, paused_at: float) -> bool:
        if watched.engine.prefill_backlog == 0:
            return True
        since = [ttft for ts, ttft in watched.engine.ttft_log
                 if ts >= paused_at]
        return bool(since) and percentile(since, 99) <= \
            self.resume_margin * watched.slo_ttft

    def _tick(self) -> None:
        now = self.runtime.clock.now
        # resume first (LIFO): the most recent victim comes back once
        # every watched tenant above it has recovered since its pause
        if self._victims:
            top = self._victims[-1]
            watched = self._watched_above(top)
            if all(self._recovered(w, self._paused_at[top.name])
                   for w in watched):
                self._do_resume("recovered")
                return
        offender = next((t for t in self.tenants if self._violated(t, now)),
                        None)
        if offender is None:
            return
        # defer the lowest-priority still-running tenant below the
        # offender — one per tick, escalating up the priority ladder
        # while the violation persists
        candidates = [t for t in self.tenants
                      if t.priority < offender.priority
                      and t.pause is not None and t not in self._victims]
        if not candidates:
            return
        victim = candidates[-1]        # tenants sorted desc -> last is lowest
        victim.pause()
        self._victims.append(victim)
        self._paused_at[victim.name] = now
        self.throttles += 1
        self.events.append({"t": now, "event": "throttle",
                            "offender": offender.name,
                            "victim": victim.name})

    def _do_resume(self, reason: str) -> None:
        victim = self._victims.pop()
        self._resumed_at = self.runtime.clock.now
        if victim.resume is not None:
            victim.resume()
        self.events.append({"t": self.runtime.clock.now, "event": "resume",
                            "victim": victim.name, "reason": reason})
