"""Serve + train colocation on one FabricRuntime/BudgetLedger (§6).

The paper's multi-tenant angle, made executable: a latency-class
``StagedServeEngine`` and a throughput-class ``TrainCluster`` run as
*tenants* of a single merged fabric, drawing on the same budget ledger,
so every interference effect — the §4.1 concurrency discount, direction
budgets, weighted fair shares, admission-control deferral — emerges
from scheduling on one shared timeline instead of being asserted.

Topology (``colocation_fabric``): the train cluster's ``host:i`` /
``soc:i`` / ``net`` paths merged (``merge_fabrics``) with a
serve-private ``serve:decode`` path. The serve tenant's prefill
KV-cache shipment rides ``host:<serve_node>`` — the *same* path, same
direction, same budget as that node's gradient staging, which is
exactly the co-runner-loads-one-direction experiment of §6; decode
cache reads stay on the private path so steady-state decode is not the
confounder.

``Colocation.run`` launches both tenants, optionally under a
``QoSPolicy`` and an ``AdmissionController``, and produces an
``InterferenceReport``: per-tenant p50/p99 TTFT and tokens/s, plus a
per-(path, tenant) occupancy attribution sampled from the live ledger
reservations. Determinism note: overlap moves *when* tokens and losses
happen on the clock, never *what* they are — the serve tenant's greedy
tokens and the train tenant's loss curve are bit-identical to solo
runs of the same tenants (asserted in tests/test_tenancy.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import hw
from repro.core.fabric import Fabric, OUT, Path, merge_fabrics
from repro.core.runtime import FabricRuntime
from repro.obs.metrics import OccupancyTimeSeries
from repro.serve.engine import Request, ServeTimeModel, StagedServeEngine
from repro.tenancy.admission import (AdmissionConfig, AdmissionController,
                                     percentile)
from repro.tenancy.qos import QoSPolicy, SERVE, TRAIN
from repro.train.cluster import TrainCluster, train_fabric


def colocation_fabric(nodes: int = 2, *, host_bw: float = hw.PCIE_BW,
                      soc_frac: float = 0.7,
                      net_bw_per_node: float = hw.DCN_BW_PER_CHIP,
                      decode_bw: Optional[float] = None,
                      concurrency_discount: float = 0.1) -> Fabric:
    """The merged multi-tenant fabric: train paths + a serve-private
    decode path (prefill deliberately has no private path — it shares
    ``host:<serve_node>`` with gradient staging)."""
    serve_private = Fabric.of(
        Path("serve:decode", decode_bw if decode_bw is not None else host_bw,
             latency=hw.PCIE_LAT, kind="pcie"))
    return merge_fabrics(
        train_fabric(nodes, host_bw=host_bw, soc_frac=soc_frac,
                     net_bw_per_node=net_bw_per_node,
                     concurrency_discount=concurrency_discount),
        serve_private)


def colocation_time_model(serve_node: int = 0, *,
                          prefill_units_per_token: float = 1.0,
                          decode_units_per_slot: float = 1.0,
                          ) -> ServeTimeModel:
    """The serve tenant's cost mapping onto the merged fabric."""
    return ServeTimeModel(
        prefill_path=f"host:{serve_node}", decode_path="serve:decode",
        prefill_units_per_token=prefill_units_per_token,
        decode_units_per_slot=decode_units_per_slot)


# ----------------------------------------------------------------------
# the report
# ----------------------------------------------------------------------

@dataclasses.dataclass
class InterferenceReport:
    """What colocation did to each tenant, on one shared ledger.

    ``serve``      p50/p99 TTFT (s), tokens/s, request/token counts.
    ``train``      the cluster summary (steps, sim_seconds, tokens/s,
                   loss when the numeric stream ran).
    ``occupancy``  path -> tenant -> average fraction of the path's
                   outbound capacity held by that tenant's transfers
                   (sampled from live ledger reservations).
    ``events``     admission-controller + cluster events, time-ordered.
    ``throttles``  admission pause count (0 without a controller).
    """
    sim_seconds: float
    serve: Dict[str, float]
    train: Dict[str, object]
    occupancy: Dict[str, Dict[str, float]]
    events: List[dict]
    throttles: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def placement_ledger(self, fabric: Fabric, *,
                         tenant: Optional[str] = None):
        """A fresh ledger over ``fabric`` seeded with the *other*
        tenants' measured occupancy — the input that makes
        ``plan_decode_placement`` tenant-aware: the planner sees the
        co-runners' held capacity as external reservations without
        mistaking ``tenant``'s own traffic for contention."""
        exclude = (tenant,) if tenant is not None else ()
        return occupancy_ledger(fabric, self.occupancy, exclude=exclude)


def occupancy_ledger(fabric: Fabric, occupancy: Dict[str, Dict[str, float]],
                     *, exclude: Sequence[str] = ()):
    """Seed ``fabric.ledger()`` with per-path outbound reservations from
    a measured occupancy attribution (``path -> tenant -> fraction``,
    the ``InterferenceReport.occupancy`` shape), skipping the tenants in
    ``exclude``. Fractions are clamped to the path's capacity and
    reserved non-strict (a sampled attribution can momentarily exceed
    1.0 across tenants on a discounted path)."""
    ledger = fabric.ledger()
    for path, per_tenant in occupancy.items():
        if path not in fabric:
            continue
        frac = sum(f for t, f in per_tenant.items() if t not in exclude)
        if frac <= 0:
            continue
        cap = fabric[path].capacity
        ledger.reserve(path, out=min(frac, 1.0) * cap,
                       flow="occupancy", strict=False)
    return ledger


def serve_metrics(requests: Sequence[Request], elapsed: float) -> Dict[str, float]:
    """p50/p99 TTFT + decode throughput for a served request set."""
    ttfts = [r.ttft for r in requests if r.ttft is not None]
    tokens = sum(len(r.out_tokens) for r in requests)
    return {
        "requests": float(len(requests)),
        "tokens": float(tokens),
        "p50_ttft": percentile(ttfts, 50) if ttfts else float("nan"),
        "p99_ttft": percentile(ttfts, 99) if ttfts else float("nan"),
        "tokens_per_s": tokens / elapsed if elapsed > 0 else 0.0,
    }


class _OccupancySampler(OccupancyTimeSeries):
    """Periodic attribution of ledger-held *outbound* rate to tenants —
    since PR 10 a thin alias over ``obs.metrics.OccupancyTimeSeries``
    (OUT-only, same charge rule, same ``busy``/``finish()`` surface).
    (IN traffic draws on the opposite direction budget — mixing the two
    against one capacity would double-count a bidirectional path.)"""

    def __init__(self, runtime: FabricRuntime, every: float):
        super().__init__(runtime, every, directions=(OUT,))


# ----------------------------------------------------------------------
# the harness
# ----------------------------------------------------------------------

class Colocation:
    """Runs two tenants on one runtime and reports interference.

    ``make_engine`` / ``make_cluster`` receive the shared runtime and
    must build their tenant *on it* (``StagedServeEngine(runtime=rt)``,
    ``TrainCluster(runtime=rt, fabric=rt.fabric)``); the harness tags
    untagged tenants with the canonical ``serve``/``train`` names so
    the QoS policy and occupancy attribution line up. ``qos=None``
    gives unmanaged (equal-share) colocation — the baseline the
    QoS-weighted run is measured against.
    """

    def __init__(self, *, fabric: Fabric,
                 make_engine: Callable[[FabricRuntime], StagedServeEngine],
                 make_cluster: Callable[[FabricRuntime], TrainCluster],
                 qos: Optional[QoSPolicy] = None,
                 admission: Optional[AdmissionConfig] = None,
                 sample_every: float = 0.01, tracer=None):
        self.runtime = FabricRuntime(fabric, qos=qos, tracer=tracer)
        self.engine = make_engine(self.runtime)
        self.cluster = make_cluster(self.runtime)
        if self.engine.runtime is not self.runtime \
                or self.cluster.runtime is not self.runtime:
            raise ValueError("tenants must be built on the shared runtime "
                             "(pass runtime=rt in the factories)")
        if self.engine.tenant is None:
            self.engine.tenant = SERVE
        if self.cluster.tenant is None:
            self.cluster.tenant = TRAIN
        self.admission_cfg = admission
        self.controller: Optional[AdmissionController] = None
        self.sample_every = sample_every

    def run(self, requests: Sequence[Request], train_steps: int,
            *, max_sim_seconds: Optional[float] = None) -> InterferenceReport:
        """Launch both tenants, drive the shared clock until both are
        quiescent (or ``max_sim_seconds``), and report."""
        rt = self.runtime
        t0 = rt.clock.now
        self.cluster.begin(train_steps)
        for r in requests:
            self.engine.submit(r)
        self.engine.start()
        if self.admission_cfg is not None:
            self.controller = AdmissionController(
                rt, self.engine, self.cluster, self.admission_cfg).start()
        sampler = _OccupancySampler(rt, self.sample_every)
        until = None if max_sim_seconds is None else t0 + max_sim_seconds
        rt.clock.run(until=until,
                     stop=lambda: self.cluster.done and self.engine.idle)
        if self.controller is not None:
            self.controller.stop()
            # stop() resumed a still-paused cluster: drain the re-issued
            # transfers under a fresh deadline budget
            rt.clock.run(
                until=None if max_sim_seconds is None
                else rt.clock.now + max_sim_seconds,
                stop=lambda: self.cluster.done and self.engine.idle)
        train = self.cluster.finish()
        occupancy = sampler.finish()
        served, self.engine.finished = list(self.engine.finished), []
        elapsed = rt.clock.now - t0
        # the serve tenant's own makespan: its throughput must not be
        # diluted by the train tenant's tail (mirrors the cluster's
        # _done_at stamp)
        serve_end = max((r.finish_time for r in served
                         if r.finish_time is not None), default=rt.clock.now)
        events = sorted(
            (list(self.controller.events) if self.controller else [])
            + list(train.get("events", [])),
            key=lambda e: e["t"])
        return InterferenceReport(
            sim_seconds=elapsed,
            serve=serve_metrics(served, serve_end - t0),
            train=train,
            occupancy=occupancy,
            events=events,
            throttles=self.controller.throttles if self.controller else 0)


# ----------------------------------------------------------------------
# solo baselines (same fabric, one tenant absent)
# ----------------------------------------------------------------------

def solo_serve(fabric: Fabric,
               make_engine: Callable[[FabricRuntime], StagedServeEngine],
               requests: Sequence[Request]) -> Dict[str, float]:
    """The serve tenant alone on the merged fabric — the SLO baseline
    QoS/admission results are normalized against."""
    rt = FabricRuntime(fabric)
    eng = make_engine(rt)
    if eng.tenant is None:
        eng.tenant = SERVE
    t0 = rt.clock.now
    for r in requests:
        eng.submit(r)
    done = eng.run()
    return serve_metrics(done, rt.clock.now - t0)


def solo_train(fabric: Fabric,
               make_cluster: Callable[[FabricRuntime], TrainCluster],
               steps: int) -> Dict[str, object]:
    """The train tenant alone on the merged fabric."""
    rt = FabricRuntime(fabric)
    cluster = make_cluster(rt)
    if cluster.tenant is None:
        cluster.tenant = TRAIN
    return cluster.run(steps)
