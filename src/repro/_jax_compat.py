"""Compatibility layer for older jax releases (this container ships
jax 0.4.x; the code targets the current API).

Installs top-level aliases on `jax` when missing:
  * ``jax.shard_map``       — wraps ``jax.experimental.shard_map`` and
    translates ``check_vma`` -> ``check_rep`` and ``axis_names`` ->
    ``auto`` (the complement set);
  * ``jax.set_mesh``        — returns the Mesh itself, which is already
    a context manager on old jax (``with mesh:``);
  * ``jax.sharding.AxisType`` and the ``axis_types`` kwarg of
    ``jax.make_mesh`` — accepted and ignored (old meshes have no axis
    types; everything behaves like Auto).

Idempotent; imported from ``repro/__init__.py`` so any entry point gets
it before touching model code.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def _compat_shard_map():
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, *, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, check_rep=None, axis_names=None, **kw):
        kwargs = dict(kw)
        kwargs.update(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        rep = check_rep if check_rep is not None else check_vma
        if rep is not None:
            kwargs["check_rep"] = bool(rep)
        if axis_names is not None and mesh is not None:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _sm(f, **kwargs)

    return shard_map


def install() -> None:
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _compat_shard_map()

    if not hasattr(jax.lax, "axis_size"):
        # psum of the literal 1 constant-folds to the named-axis size
        jax.lax.axis_size = lambda axis_name: jax.lax.psum(1, axis_name)

    if not hasattr(jax, "set_mesh"):
        # old Mesh objects are context managers; `with jax.set_mesh(m):`
        # degrades to `with m:` (no ambient abstract mesh — callers that
        # probe it, e.g. parallel/sharding.get_abstract_mesh, handle None)
        jax.set_mesh = lambda mesh: mesh

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _mk = jax.make_mesh

        @functools.wraps(_mk)
        def make_mesh(*args, axis_types=None, **kw):
            return _mk(*args, **kw)

        jax.make_mesh = make_mesh


install()
