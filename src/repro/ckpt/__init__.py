from repro.ckpt.checkpoint import (CheckpointManager, load_checkpoint,
                                   save_checkpoint)
from repro.ckpt.replication import ReplicationPlan, plan_replication
