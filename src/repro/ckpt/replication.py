"""Replication planning — the LineFS §5.1 decision, parameterized by the
checkpoint's measured compression ratio and the live fabric budgets.

`plan_replication` builds the LineFS fabric, ranks A1/A2/A3 with the
MultipathRouter and returns the greedy combination plus predicted
bandwidths; CheckpointManager and the bench
(benchmarks/bench_replication.py) consume it. The same analysis drives
RunConfig.ckpt_compress.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from repro.core import hw
from repro.core.fabric import (Allocation, Fabric, MultipathRouter,
                               linefs_fabric, linefs_replication_alternatives)


@dataclass
class ReplicationPlan:
    ranked: List[str]
    allocations: List[Allocation]
    total_rate: float                # bytes/s of checkpoint data replicated
    use_compression: bool
    notes: str


def plan_replication(*, ratio: float,
                     net_bw: float = hw.DCN_BW_PER_CHIP,
                     staging_bw: float = hw.PCIE_BW,
                     soc_rate: Optional[float] = None,
                     fabric: Optional[Fabric] = None) -> ReplicationPlan:
    """ratio = compressed/raw (from the last checkpoint's stats).

    net_bw: replication network budget per host (DCN).
    staging_bw: host staging link (PCIe), the paper's P.
    soc_rate: compression throughput cap (None = unbounded).
    fabric: pre-built fabric to plan on (defaults to the LineFS fabric
    at the given bandwidths).
    """
    fabric = fabric if fabric is not None else linefs_fabric(net_bw, staging_bw)
    alts = linefs_replication_alternatives(
        net_bw, staging_bw, ratio,
        soc_rate=soc_rate if soc_rate else math.inf)
    router = MultipathRouter(fabric)
    # paper §5.1: A2 dominates A1 (same traffic, no double-crossing);
    # rank A2 vs A3 by solo rate, then combine greedily.
    a1, a2, a3 = alts
    ranked = router.rank([a2, a3])
    allocs, total = router.allocate(ranked)
    use_comp = ranked[0].name == "A2"
    return ReplicationPlan(
        ranked=[a.name for a in ranked],
        allocations=allocs,
        total_rate=total,
        use_compression=use_comp,
        notes=(f"ratio={ratio:.2f}: A1={a1.solo_rate(fabric)/1e9:.1f} "
               f"A2={a2.solo_rate(fabric)/1e9:.1f} "
               f"A3={a3.solo_rate(fabric)/1e9:.1f} GB/s; "
               f"combined={total/1e9:.1f} GB/s"),
    )
