"""Replication planning + simulation — the LineFS §5.1 decision,
parameterized by the checkpoint's measured compression ratio and the
live fabric budgets.

`plan_replication` builds the LineFS fabric, ranks A1/A2/A3 with the
MultipathRouter and returns the greedy combination plus predicted
bandwidths; CheckpointManager and the bench
(benchmarks/bench_replication.py) consume it. The same analysis drives
RunConfig.ckpt_compress.

`simulate_replication` executes the chosen offload path on the
event-driven fabric runtime as chunked two-stage transfers — stage the
raw chunk over the offload path (A2's ③* DMA by default, A1's shared
internal link optionally), then send the compressed chunk over the
network — either sequentially or pipelined (chunk i+1 stages while
chunk i is on the wire). The pipeline overlap is the paper's ~30%
LineFS win, reproduced as a simulated-latency assertion in
tests/test_runtime.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

from repro.core import hw
from repro.core.fabric import (Allocation, Fabric, MultipathRouter,
                               linefs_fabric, linefs_replication_alternatives)
from repro.core.runtime import FabricRuntime, Signal


@dataclass
class ReplicationPlan:
    ranked: List[str]
    allocations: List[Allocation]
    total_rate: float                # bytes/s of checkpoint data replicated
    use_compression: bool
    notes: str


def plan_replication(*, ratio: float,
                     net_bw: float = hw.DCN_BW_PER_CHIP,
                     staging_bw: float = hw.PCIE_BW,
                     soc_rate: Optional[float] = None,
                     fabric: Optional[Fabric] = None) -> ReplicationPlan:
    """ratio = compressed/raw (from the last checkpoint's stats).

    net_bw: replication network budget per host (DCN).
    staging_bw: host staging link (PCIe), the paper's P.
    soc_rate: compression throughput cap (None = unbounded).
    fabric: pre-built fabric to plan on (defaults to the LineFS fabric
    at the given bandwidths).
    """
    fabric = fabric if fabric is not None else linefs_fabric(net_bw, staging_bw)
    alts = linefs_replication_alternatives(
        net_bw, staging_bw, ratio,
        soc_rate=soc_rate if soc_rate else math.inf)
    router = MultipathRouter(fabric)
    # paper §5.1: A2 dominates A1 (same traffic, no double-crossing);
    # rank A2 vs A3 by solo rate, then combine greedily.
    a1, a2, a3 = alts
    ranked = router.rank([a2, a3])
    allocs, total = router.allocate(ranked)
    use_comp = ranked[0].name == "A2"
    return ReplicationPlan(
        ranked=[a.name for a in ranked],
        allocations=allocs,
        total_rate=total,
        use_compression=use_comp,
        notes=(f"ratio={ratio:.2f}: A1={a1.solo_rate(fabric)/1e9:.1f} "
               f"A2={a2.solo_rate(fabric)/1e9:.1f} "
               f"A3={a3.solo_rate(fabric)/1e9:.1f} GB/s; "
               f"combined={total/1e9:.1f} GB/s"),
    )


# ----------------------------------------------------------------------
# simulated-time execution (LineFS pipelining, paper §5.1)
# ----------------------------------------------------------------------

@dataclass
class ReplicationTiming:
    """Result of a simulated chunked replication."""
    seconds: float                    # completion time of the last chunk
    pipelined: bool
    chunks: int
    chunk_bytes: float
    ratio: float
    stage_path: str
    net_path: str
    chunk_finish_s: List[float] = field(default_factory=list)
    # per-chunk completion timestamps (since start) — percentile columns

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100) of the chunk *completion times*
        since start (cumulative timestamps, not per-chunk transfer
        latencies): percentile(50) is when half the chunks were durable
        on the replica — the replication-progress curve."""
        lats = sorted(self.chunk_finish_s)
        if not lats:
            return 0.0
        idx = min(len(lats) - 1, int(math.ceil(q / 100.0 * len(lats))) - 1)
        return lats[max(idx, 0)]


def simulate_replication(total_bytes: float, ratio: float, *,
                         chunks: int = 8, pipelined: bool = True,
                         net_bw: float = hw.DCN_BW_PER_CHIP,
                         staging_bw: float = hw.PCIE_BW,
                         fabric: Optional[Fabric] = None,
                         stage_path: str = "dma", net_path: str = "net",
                         runtime: Optional[FabricRuntime] = None,
                         ) -> ReplicationTiming:
    """Replicate ``total_bytes`` of checkpoint data as ``chunks``
    two-stage transfers on the LineFS fabric: stage the raw chunk over
    ``stage_path`` (③* DMA for A2, "internal" for A1's double-crossing
    path), then send ``ratio`` x the bytes over ``net_path``.

    ``pipelined=False`` runs stage->send->stage->send strictly in
    order; ``pipelined=True`` lets chunk i+1 stage while chunk i is on
    the network — the transfers live on different interference groups,
    so the runtime overlaps them and the LineFS pipelining win falls
    out of the timeline instead of being asserted as a constant."""
    if chunks < 1:
        raise ValueError("chunks must be >= 1")
    fabric = fabric if fabric is not None else linefs_fabric(net_bw, staging_bw)
    rt = runtime if runtime is not None else FabricRuntime(fabric)
    start = rt.clock.now
    chunk = total_bytes / chunks
    finish: List[float] = []

    if pipelined:
        staged_upto = [0]               # chunks staged so far
        advanced = Signal(rt.clock)

        def stage_proc():
            for i in range(chunks):
                yield rt.transfer(stage_path, chunk, flow=f"stage:{i}")
                staged_upto[0] = i + 1
                advanced.fire()

        def send_proc():
            for i in range(chunks):
                while staged_upto[0] <= i:
                    yield advanced
                yield rt.transfer(net_path, chunk * ratio, flow=f"send:{i}")
                finish.append(rt.clock.now - start)

        rt.process(stage_proc(), name="replication-stage")
        rt.process(send_proc(), name="replication-send")
    else:
        def serial_proc():
            for i in range(chunks):
                yield rt.transfer(stage_path, chunk, flow=f"stage:{i}")
                yield rt.transfer(net_path, chunk * ratio, flow=f"send:{i}")
                finish.append(rt.clock.now - start)

        rt.process(serial_proc(), name="replication-serial")

    # stop at our own completion: a shared runtime's later events stay put
    rt.clock.run(stop=lambda: len(finish) == chunks)
    if len(finish) != chunks:
        raise RuntimeError(f"replication stalled: {len(finish)}/{chunks} "
                           "chunks completed (insufficient path budget?)")
    return ReplicationTiming(seconds=finish[-1], pipelined=pipelined,
                             chunks=chunks, chunk_bytes=chunk, ratio=ratio,
                             stage_path=stage_path, net_path=net_path,
                             chunk_finish_s=finish)
