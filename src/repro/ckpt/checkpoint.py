"""Checkpoint save/restore with async staging and chain replication.

LineFS case study (paper §5.1) mapped to training-state persistence:
the "file" is the checkpoint shard, the "remote NVM backups" are
replica targets, and the three alternatives are

  A1  compress on the offload path, then replicate (double-crossing the
      staging link: raw in, compressed out);
  A2  compress via the DMA-analogue staging path (bypasses the primary
      link);
  A3  replicate raw, directly from the source (no compression, more
      "network" bytes but no staging bottleneck).

On this CPU container replica targets are directories and the path
bandwidths are the modeled constants (core/hw.py); the *decision logic*
(planner ranking + greedy combine) and the *mechanics* (compression,
chain ordering, atomic commit, manifest validation, async staging) are
real and tested.

Layout per checkpoint:
  <dir>/step_<k>/manifest.msgpack       tree structure + shapes + hashes
  <dir>/step_<k>/data.npz[.zst]         flattened leaves
  <dir>/step_<k>/COMMIT                 written last (atomicity marker)
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.compression import BYTE_CODECS, byte_codec, default_codec

PyTree = Any

# compat alias: the codec table now lives in core/compression.py so the
# offload tier (offload/compression.py) runs the *same* callables.
_CODECS = BYTE_CODECS


@dataclass(frozen=True)
class StagingOption:
    """A staging strategy for ``choose_staging`` to cost against live
    occupancy: the wire a save crosses, how many bytes per raw byte it
    puts there (``wire_scale`` < 1 when compressed first), and the
    optional ops/s resource that runs the codec."""
    name: str                       # tag returned when this option wins
    path: str                       # wire resource the staged bytes cross
    wire_scale: float = 1.0         # wire bytes per raw checkpoint byte
    compute: Optional[str] = None   # ops/s resource running the codec
    ops_scale: float = 0.0          # codec ops per raw checkpoint byte


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                        for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(path: str, tree: PyTree, *, step: int,
                    compress: bool = True, meta: Optional[dict] = None,
                    compressor: Optional[Callable[[str, bytes], bytes]] = None,
                    ) -> Dict[str, float]:
    """Writes atomically (COMMIT marker last). Returns size/timing stats.

    ``compressor(codec_name, raw) -> payload`` reroutes the codec run —
    e.g. through an offload tenant that accounts the cycles on the SoC —
    but must return the same bytes the named codec would (the manifest
    hash is over the payload, so a divergent compressor is caught at
    restore time on any replica that compressed elsewhere).
    """
    t0 = time.monotonic()
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_names(tree)
    buf = io.BytesIO()
    np.savez(buf, **{name: arr for name, arr in leaves})
    raw = buf.getvalue()
    codec = default_codec(compress)
    ext, comp, _ = _CODECS[codec]
    payload = compressor(codec, raw) if compressor is not None else comp(raw)
    fname = "data.npz" + ext
    with open(os.path.join(tmp, fname), "wb") as f:
        f.write(payload)

    manifest = {
        "step": step,
        "compress": compress,
        "codec": codec,
        "raw_bytes": len(raw),
        "stored_bytes": len(payload),
        "sha256": hashlib.sha256(payload).hexdigest(),
        "names": [n for n, _ in leaves],
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write(str(step))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    dt = time.monotonic() - t0
    return {"raw_bytes": len(raw), "stored_bytes": len(payload),
            "ratio": len(payload) / max(len(raw), 1), "seconds": dt}


def load_checkpoint(path: str, like: PyTree) -> Tuple[PyTree, int]:
    """Validates COMMIT + hash, reconstructs the pytree of `like`."""
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    # checkpoints from before the codec header used zstd whenever compressed
    codec = manifest.get("codec", "zstd" if manifest["compress"] else "none")
    ext, _, decomp = byte_codec(codec)   # raises IOError if zstd absent
    with open(os.path.join(path, "data.npz" + ext), "rb") as f:
        payload = f.read()
    if hashlib.sha256(payload).hexdigest() != manifest["sha256"]:
        raise IOError(f"checkpoint {path} corrupt (hash mismatch)")
    raw = decomp(payload)
    npz = np.load(io.BytesIO(raw))
    flat_names = [n for n, _ in _flatten_with_names(like)]
    assert flat_names == manifest["names"], "tree structure changed"
    leaves = [npz[n] for n in flat_names]
    treedef = jax.tree_util.tree_structure(like)
    restored = jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(a, dtype=l.dtype)
                  for a, l in zip(leaves, jax.tree_util.tree_leaves(like))])
    return restored, int(manifest["step"])


class CheckpointManager:
    """Periodic async checkpoints + chain replication + retention.

    Async staging = snapshot to host (np.asarray) on the caller thread
    (cheap; the paper's "DMA to staging memory"), then a background
    thread does compress+write+replicate — training continues.
    """

    @staticmethod
    def choose_staging(candidates: List[Union[str, StagingOption]], *,
                       ledger=None, direction: str = "out",
                       fallback: Optional[str] = None) -> str:
        """Pick the staging strategy for one save from *live* occupancy.

        The paper's §6.1 lesson is that the right staging path (direct
        host PCIe vs the weaker SoC DMA engine) depends on what else is
        on the wire *right now*, not on a startup constant. Plain string
        candidates are wires: the one with the most available
        ``direction`` budget (discount and current holders included)
        wins. A ``StagingOption`` is costed per raw byte instead —
        ``wire_scale`` bytes over its wire plus ``ops_scale`` ops on its
        compute resource, each at the *available* rate — so
        compress-then-stage strategies compete with raw staging on equal
        footing (this is how ``ckpt_path="auto"`` learns that
        soc-compress wins only when the host side is busy). Returns the
        winning string, or the winning option's ``name``. Ties keep
        candidate order, so listing the preferred strategy first
        reproduces the static choice on an idle fabric. Without a
        ledger the static ``fallback`` (or the first candidate) is used
        — existing call sites keep their behaviour.
        """
        if not candidates:
            raise ValueError("choose_staging needs at least one candidate")

        def label(c):
            return c.name if isinstance(c, StagingOption) else c

        if ledger is None:
            return fallback if fallback is not None else label(candidates[0])

        def avail(resource, dirn):
            return max(ledger.available(resource, dirn, joining="ckpt"), 1e-30)

        def cost(c) -> float:           # seconds per raw byte, lower wins
            if isinstance(c, StagingOption):
                s = c.wire_scale / avail(c.path, direction)
                if c.compute is not None and c.ops_scale > 0.0:
                    s += c.ops_scale / avail(c.compute, "out")
                return s
            return 1.0 / avail(c, direction)

        return label(min(candidates, key=cost))

    def __init__(self, directory: str, *, every: int = 100, keep: int = 2,
                 compress: bool = True, replicas: int = 0,
                 replica_dirs: Optional[List[str]] = None):
        self.dir = directory
        self.every = every
        self.keep = keep
        self.compress = compress
        self.replica_dirs = list(replica_dirs or [])
        if replicas and not self.replica_dirs:
            self.replica_dirs = [os.path.join(directory, f"replica_{i}")
                                 for i in range(replicas)]
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.stats: List[dict] = []

    # ------------------------------------------------------------------
    def _step_dir(self, step: int, root: Optional[str] = None) -> str:
        return os.path.join(root or self.dir, f"step_{step:08d}")

    def maybe_save(self, step: int, tree: PyTree, *, blocking: bool = False) -> bool:
        if self.every <= 0 or step % self.every:
            return False
        self.save(step, tree, blocking=blocking)
        return True

    def save(self, step: int, tree: PyTree, *, blocking: bool = False):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)   # stage
        self.wait()                                               # one writer

        def work():
            st = save_checkpoint(self._step_dir(step), host_tree,
                                 step=step, compress=self.compress)
            # chain replication: primary -> r0 -> r1 -> ... (paper §5.1)
            src = self._step_dir(step)
            for rdir in self.replica_dirs:
                dst = self._step_dir(step, rdir)
                os.makedirs(rdir, exist_ok=True)
                if os.path.exists(dst):
                    shutil.rmtree(dst)
                shutil.copytree(src, dst)
                src = dst
            st["step"] = step
            self.stats.append(st)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def _complete_steps(self, root: str) -> List[int]:
        if not os.path.isdir(root):
            return []
        steps = []
        for d in os.listdir(root):
            if d.startswith("step_") and \
                    os.path.exists(os.path.join(root, d, "COMMIT")):
                steps.append(int(d.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        """Newest committed step across primary + replicas (a failed
        primary is recovered from the chain)."""
        best: Optional[int] = None
        for root in [self.dir] + self.replica_dirs:
            steps = self._complete_steps(root)
            if steps and (best is None or steps[-1] > best):
                best = steps[-1]
        return best

    def restore(self, like: PyTree, step: Optional[int] = None) -> Tuple[PyTree, int]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        errors = []
        for root in [self.dir] + self.replica_dirs:
            try:
                return load_checkpoint(self._step_dir(step, root), like)
            except (FileNotFoundError, IOError, AssertionError) as e:
                errors.append(str(e))
        raise IOError(f"step {step} unrecoverable from any replica: {errors}")

    def _gc(self):
        for root in [self.dir] + self.replica_dirs:
            steps = self._complete_steps(root)
            for s in steps[:-self.keep]:
                shutil.rmtree(self._step_dir(s, root), ignore_errors=True)
