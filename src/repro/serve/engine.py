"""Batched serving engine: prefill + continuous-batching decode.

Slot model: a fixed decode batch of ``slots``; each slot holds one
request's cache rows. New requests prefill (per-request, bucketed
lengths), their cache rows are spliced into the slot cache, and the
decode step advances every active slot one token with per-row positions.

Multi-path notes (DrTM-KV mapping): the KV cache is the "value store";
decode's cache read is the hot path the disagg layer places (batch-
sharded on ICI for decode_32k, sequence-sharded context-parallel for
long_500k). When a Fabric is supplied, the engine routes the §5.2
alternatives over it at startup to pick the decode cache placement
(SoC cache vs host) — see serve/disagg.plan_decode_placement. Sampling
is greedy or temperature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fabric import Fabric
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) or (S, C) token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, impl: str = "auto",
                 cache_dtype=jnp.float32, seed: int = 0,
                 fabric: Optional[Fabric] = None,
                 cache_hit_mass: float = 0.7, placement_costs=None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.impl = slots, max_len, impl
        self.cache, _ = M.init_cache(cfg, slots, max_len, cache_dtype)
        self.pos = jnp.zeros((slots,), jnp.int32)       # next write index
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []   # retired, not yet drained by run()
        self.key = jax.random.PRNGKey(seed)
        self.placement = None
        if fabric is not None:
            from repro.serve.disagg import plan_decode_placement
            self.placement = plan_decode_placement(
                fabric, hit_mass=cache_hit_mass, costs=placement_costs)
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos, impl=impl))
        self._prefill = jax.jit(
            lambda p, t: M.prefill(cfg, p, t, max_len, impl=impl,
                                   cache_dtype=cache_dtype),
            static_argnames=())
        self.stats: Dict[str, float] = {"prefill_tokens": 0, "decode_steps": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _splice_cache(self, slot: int, row_cache):
        """Copy a prefilled (batch=1) cache into slot `slot`."""
        def put(dst, src):
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
        self.cache = jax.tree.map(put, self.cache, row_cache)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt)[None]          # (1, S[,C])
                logits, cache1, npos = self._prefill(self.params, toks)
                self._splice_cache(s, cache1)
                self.pos = self.pos.at[s].set(npos)
                tok = self._sample(logits[:, -1], req.temperature)
                req.out_tokens.append(int(np.asarray(tok).reshape(-1)[0]))
                self.active[s] = req
                self.stats["prefill_tokens"] += int(toks.shape[1])

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns number
        of active requests."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        cb = self.cfg.num_codebooks
        last = np.zeros((self.slots,) + ((cb,) if cb > 1 else ()), np.int32)
        for s in act:
            t = self.active[s].out_tokens[-1]
            last[s] = t
        tokens = jnp.asarray(last)[:, None]                    # (B,1[,C])
        logits, self.cache = self._decode(self.params, tokens, self.cache, self.pos)
        self.pos = self.pos + jnp.asarray(
            [1 if self.active[s] is not None else 0 for s in range(self.slots)],
            jnp.int32)
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in act:
            req = self.active[s]
            if req.temperature > 0:
                tok = self._sample(logits[s:s + 1, 0], req.temperature)
                val = np.asarray(tok).reshape(-1)
            else:
                val = nxt[s].reshape(-1)
            req.out_tokens.append(int(val[0]) if val.size == 1 else val.tolist())
            if len(req.out_tokens) >= req.max_new_tokens or \
                    int(self.pos[s]) >= self.max_len - 1:
                req.done = True
                self.active[s] = None
                self.finished.append(req)
        return len(act)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive step() until queues drain; returns (and drains) the
        requests retired since the last run() call, in retirement order
        — the engine holds no unbounded completion history."""
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        completed, self.finished = self.finished, []
        return completed
