"""Batched serving: prefill + continuous-batching decode, two runtimes.

Slot model: a fixed decode batch of ``slots``; each slot holds one
request's cache rows. New requests prefill (per-request, power-of-two
bucketed lengths), their cache rows are spliced into the slot cache, and
the decode step advances every active slot one token with per-row
positions.

Two engines share the compute core (``_EngineCore``):

``ServeEngine``       the synchronous baseline: ``step()`` = admit (each
                      prefill runs to completion, blocking everything)
                      + one decode step. Optionally timestamps its work
                      on a ``FabricRuntime`` so it is comparable with
                      the staged engine on the same simulated timeline.
``StagedServeEngine`` the event-driven pipeline: ``PrefillStage``
                      prefills queued requests as soon as they arrive
                      (overlapping transfers fair-share the prefill
                      path), ``AdmitStage`` splices ready caches into
                      free slots — re-evaluating the §5.2 decode-cache
                      placement per admitted request from *live* ledger
                      occupancy — and ``DecodeStage`` advances active
                      slots while prefill transfers are still in
                      flight. Time-to-first-token no longer waits for a
                      free slot or for other requests' decode steps.

Both engines produce identical output tokens for greedy sampling: each
decode-batch row is independent (per-row positions + masks), so overlap
changes *when* a token exists on the simulated clock, never *which*
token it is. The simulated-time model is ``ServeTimeModel``: real jax
compute runs eagerly, and its communication cost (prefill KV-cache
shipment, per-step decode cache reads) is charged as fabric transfers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fabric import Fabric
from repro.core.runtime import FabricRuntime, Signal
from repro.models import model as M
from repro.models.params import layer_period, slot_kind


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) or (S, C) token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    arrival: float = 0.0                # simulated arrival time (seconds)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_time: Optional[float] = None   # simulated TTFT timestamp
    finish_time: Optional[float] = None
    placement: Optional[str] = None     # decode-cache placement decision

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival


@dataclasses.dataclass(frozen=True)
class ServeTimeModel:
    """How engine work maps onto fabric transfers (simulated time).

    ``prefill_path`` carries one transfer of
    ``prompt_len * prefill_units_per_token`` per admitted request (the
    prefilled KV cache shipping to its decode slot); ``decode_path``
    carries ``n_active * decode_units_per_slot`` per decode step (the
    batched cache read). ``placement_paths`` optionally routes a slot's
    decode traffic by its ``PlacementPlan.location`` (e.g.
    ``{"soc_cache": "soc_read", "host": "host_read"}``)."""
    prefill_path: str
    decode_path: str
    prefill_units_per_token: float = 1.0
    decode_units_per_slot: float = 1.0
    placement_paths: Optional[Dict[str, str]] = None

    def decode_path_for(self, placement: Optional[str]) -> str:
        if self.placement_paths and placement in self.placement_paths:
            return self.placement_paths[placement]
        return self.decode_path


class _EngineCore:
    """Model compute + slot bookkeeping shared by both engines.

    ``compute`` selects the token source: ``"jax"`` (default) runs the
    real model; ``"sim"`` replaces prefill/decode with a deterministic
    per-request hash stream (``_sim_token``) and needs no ``cfg``/
    ``params`` at all. The slot model, queues, timestamps and fabric
    transfers are identical either way — sim mode is what makes
    hundreds-of-requests fleet traces affordable while keeping
    bit-identity assertions meaningful (the token at position ``i`` of
    request ``rid`` is a pure function of ``(rid, i)``, so any
    scheduling change that reorders or drops work changes the bytes)."""

    MIN_BUCKET = 8

    def __init__(self, cfg: Optional[ModelConfig], params: Any, *,
                 slots: int = 4, max_len: int = 256, impl: str = "auto",
                 cache_dtype=jnp.float32, seed: int = 0,
                 bucket_prefill: bool = True, compute: str = "jax"):
        if compute not in ("jax", "sim"):
            raise ValueError(f"compute must be 'jax' or 'sim', got {compute!r}")
        self.compute = compute
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.impl = slots, max_len, impl
        self.tenant: Optional[str] = None   # QoS tag on fabric transfers
        #: (completion sim-time, ttft) samples — admission control input
        self.ttft_log: List[Tuple[float, float]] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.finished: List[Request] = []   # retired, not yet drained by run()
        self.stats: Dict[str, float] = {
            "prefill_tokens": 0, "decode_steps": 0,
            "prefill_compilations": 0, "prefill_padded_tokens": 0}
        self._compiled_buckets: set = set()
        if compute == "sim":
            self.cache = None
            self.pos = np.zeros((slots,), np.int64)
            self.bucket_prefill = False
            return
        if cfg is None:
            raise ValueError("compute='jax' needs a ModelConfig")
        self.cache, _ = M.init_cache(cfg, slots, max_len, cache_dtype)
        self.pos = jnp.zeros((slots,), jnp.int32)       # next write index
        self.key = jax.random.PRNGKey(seed)
        # bucketing needs causal attention's inert pad tail; SSM state
        # runs through every position, so those configs prefill exact.
        self._attn_only = all(slot_kind(cfg, s)["kind"] == "attn"
                              for s in range(layer_period(cfg)))
        self.bucket_prefill = bucket_prefill and self._attn_only
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfg, p, t, c, pos, impl=impl))
        self._prefill = jax.jit(
            lambda p, t, n: M.prefill(cfg, p, t, max_len, impl=impl,
                                      cache_dtype=cache_dtype, length=n))

    @staticmethod
    def _sim_token(rid: int, i: int) -> int:
        """Deterministic token ``i`` of request ``rid`` in sim mode."""
        return (rid * 1315423911 + i * 2654435761) & 0x7FFF

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _bucket_len(self, n: int) -> int:
        """Pad target: next power of two (>= MIN_BUCKET), clamped to the
        cache length so the padded prefill still fits."""
        if not self.bucket_prefill:
            return n
        bucket = max(self.MIN_BUCKET, 1 << (max(n - 1, 0)).bit_length())
        return bucket if bucket <= self.max_len else n

    def _prefill_request(self, req: Request) -> Tuple[Any, int]:
        """Real prefill compute for one request (bucketed): appends the
        first output token and returns (cache_row, next_pos)."""
        if self.compute == "sim":
            n = len(np.asarray(req.prompt))
            req.out_tokens.append(self._sim_token(req.rid, 0))
            self.stats["prefill_tokens"] += n
            return None, n
        prompt = np.asarray(req.prompt)
        n = prompt.shape[0]
        bucket = self._bucket_len(n)
        if bucket > n:
            pad = np.zeros((bucket - n,) + prompt.shape[1:], prompt.dtype)
            prompt = np.concatenate([prompt, pad])
        self._compiled_buckets.add((bucket,) + prompt.shape[1:])
        toks = jnp.asarray(prompt)[None]                  # (1, S[,C])
        logits, cache1, npos = self._prefill(self.params, toks,
                                             jnp.asarray(n, jnp.int32))
        tok = self._sample(logits[:, -1], req.temperature)
        req.out_tokens.append(int(np.asarray(tok).reshape(-1)[0]))
        self.stats["prefill_tokens"] += n
        self.stats["prefill_padded_tokens"] += bucket - n
        self.stats["prefill_compilations"] = len(self._compiled_buckets)
        return cache1, int(npos)

    def _splice_cache(self, slot: int, row_cache):
        """Copy a prefilled (batch=1) cache into slot `slot`."""
        def put(dst, src):
            return dst.at[:, slot].set(src[:, 0].astype(dst.dtype))
        self.cache = jax.tree.map(put, self.cache, row_cache)

    def _activate(self, slot: int, req: Request, cache1, npos: int):
        if self.compute == "sim":
            self.pos[slot] = npos
            self.active[slot] = req
            return
        self._splice_cache(slot, cache1)
        self.pos = self.pos.at[slot].set(npos)
        self.active[slot] = req

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    # ------------------------------------------------------------------
    def _decode_compute(self, act: List[int]) -> Optional[jax.Array]:
        """One real decode step for the active slots; returns logits."""
        if self.compute == "sim":
            for s in range(self.slots):
                if self.active[s] is not None:
                    self.pos[s] += 1
            self.stats["decode_steps"] += 1
            return None
        cb = self.cfg.num_codebooks
        last = np.zeros((self.slots,) + ((cb,) if cb > 1 else ()), np.int32)
        for s in act:
            last[s] = self.active[s].out_tokens[-1]
        tokens = jnp.asarray(last)[:, None]                    # (B,1[,C])
        logits, self.cache = self._decode(self.params, tokens, self.cache,
                                          self.pos)
        self.pos = self.pos + jnp.asarray(
            [1 if self.active[s] is not None else 0 for s in range(self.slots)],
            jnp.int32)
        self.stats["decode_steps"] += 1
        return logits

    def _finish_decode(self, act: List[int], logits) -> List[Request]:
        """Append sampled tokens, retire finished requests."""
        if self.compute == "sim":
            retired = []
            for s in act:
                req = self.active[s]
                req.out_tokens.append(
                    self._sim_token(req.rid, len(req.out_tokens)))
                if len(req.out_tokens) >= req.max_new_tokens or \
                        int(self.pos[s]) >= self.max_len - 1:
                    req.done = True
                    self.active[s] = None
                    self.finished.append(req)
                    retired.append(req)
            return retired
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        retired: List[Request] = []
        for s in act:
            req = self.active[s]
            if req.temperature > 0:
                tok = self._sample(logits[s:s + 1, 0], req.temperature)
                val = np.asarray(tok).reshape(-1)
            else:
                val = nxt[s].reshape(-1)
            req.out_tokens.append(int(val[0]) if val.size == 1 else val.tolist())
            if len(req.out_tokens) >= req.max_new_tokens or \
                    int(self.pos[s]) >= self.max_len - 1:
                req.done = True
                self.active[s] = None
                self.finished.append(req)
                retired.append(req)
        return retired

    def _free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if self.active[s] is None:
                return s
        return None


class ServeEngine(_EngineCore):
    """Synchronous engine. Optional ``runtime`` + ``time_model`` charge
    each prefill and decode step as *blocking* fabric transfers, putting
    this engine on the same simulated timeline as StagedServeEngine —
    with zero overlap, which is exactly the baseline the staged pipeline
    is measured against."""

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, impl: str = "auto",
                 cache_dtype=jnp.float32, seed: int = 0,
                 fabric: Optional[Fabric] = None,
                 cache_hit_mass: float = 0.7, placement_costs=None,
                 runtime: Optional[FabricRuntime] = None,
                 time_model: Optional[ServeTimeModel] = None,
                 bucket_prefill: bool = True,
                 tenant: Optional[str] = None):
        super().__init__(cfg, params, slots=slots, max_len=max_len, impl=impl,
                         cache_dtype=cache_dtype, seed=seed,
                         bucket_prefill=bucket_prefill)
        self.runtime, self.tm = runtime, time_model
        self.tenant = tenant
        if runtime is not None and time_model is None:
            raise ValueError("a runtime needs a ServeTimeModel")
        self.placement = None
        if fabric is not None:
            from repro.serve.disagg import plan_decode_placement
            self.placement = plan_decode_placement(
                fabric, hit_mass=cache_hit_mass, costs=placement_costs)

    # ------------------------------------------------------------------
    def _charge(self, path: str, amount: float, flow: str) -> None:
        """Run a transfer to completion (the sync engine blocks on it)."""
        if self.runtime is None or amount <= 0:
            return
        tr = self.runtime.transfer(path, amount, flow=flow,
                                   tenant=self.tenant)
        self.runtime.clock.run(stop=lambda: tr.done)

    def _now(self) -> Optional[float]:
        return self.runtime.clock.now if self.runtime is not None else None

    def _arrived(self, req: Request) -> bool:
        return self.runtime is None or req.arrival <= self.runtime.clock.now

    def _advance_to_next_arrival(self) -> None:
        """When idle but requests are still due, jump the clock."""
        if self.runtime is None or any(a is not None for a in self.active):
            return
        pending = [r.arrival for r in self.queue
                   if r.arrival > self.runtime.clock.now]
        if pending and not any(self._arrived(r) for r in self.queue):
            self.runtime.clock.run(until=min(pending))

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is not None:
                continue
            idx = next((i for i, r in enumerate(self.queue)
                        if self._arrived(r)), None)
            if idx is None:
                break
            req = self.queue.pop(idx)
            if self.placement is not None:
                req.placement = self.placement.location
            cache1, npos = self._prefill_request(req)
            if self.tm is not None:
                amt = len(np.asarray(req.prompt)) * self.tm.prefill_units_per_token
                self._charge(self.tm.prefill_path, amt, f"prefill:{req.rid}")
            req.first_token_time = self._now()
            if req.first_token_time is not None:
                self.ttft_log.append((req.first_token_time, req.ttft))
            self._activate(s, req, cache1, npos)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns number
        of active requests."""
        self._advance_to_next_arrival()
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        logits = self._decode_compute(act)
        if self.tm is not None:
            placements = {self.active[s].placement for s in act}
            for pl in sorted(placements, key=str):
                n = sum(1 for s in act if self.active[s].placement == pl)
                self._charge(self.tm.decode_path_for(pl),
                             n * self.tm.decode_units_per_slot, f"decode:{pl}")
        retired = self._finish_decode(act, logits)
        for req in retired:
            req.finish_time = self._now()
        return len(act)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive step() until queues drain; returns (and drains) the
        requests retired since the last run() call, in retirement order
        — the engine holds no unbounded completion history."""
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        completed, self.finished = self.finished, []
        return completed


# ----------------------------------------------------------------------
# the staged pipeline
# ----------------------------------------------------------------------

class PrefillStage:
    """Dispatches a prefill process per arrived request: real prefill
    compute, then the KV-cache transfer over ``tm.prefill_path``.
    Concurrent prefills fair-share the path (``max_inflight`` bounds
    them); TTFT is stamped at transfer completion — *before* a decode
    slot is free, which is where the staged win over the synchronous
    engine comes from."""

    def __init__(self, engine: "StagedServeEngine", max_inflight: int = 2):
        self.engine = engine
        self.max_inflight = max_inflight
        self.inflight = 0

    def process(self):
        eng = self.engine
        while True:
            while eng.queue and not eng.intake_paused \
                    and self.inflight < self.max_inflight:
                req = eng.queue.pop(0)
                self.inflight += 1
                eng.runtime.process(self._one(req), name=f"prefill:{req.rid}")
            yield eng.arrived

    def _one(self, req: Request):
        eng, tm = self.engine, self.engine.tm
        cache1, npos = eng._prefill_request(req)
        amt = len(np.asarray(req.prompt)) * tm.prefill_units_per_token
        if amt > 0:
            yield eng.runtime.transfer(tm.prefill_path, amt,
                                       flow=f"prefill:{req.rid}",
                                       tenant=eng.tenant)
        req.first_token_time = eng.clock.now
        eng.ttft_log.append((req.first_token_time, req.ttft))
        eng.ready.append((req, cache1, npos))
        self.inflight -= 1
        eng.arrived.fire()        # the dispatcher may start the next prefill
        eng.admittable.fire()


class AdmitStage:
    """Moves prefilled requests into free decode slots. With
    ``plan_placement`` the §5.2 decode-cache placement is re-evaluated
    *per admitted request* against the live ledger (current holders and
    reservations), not once at startup."""

    def __init__(self, engine: "StagedServeEngine"):
        self.engine = engine

    def process(self):
        eng = self.engine
        while True:
            admitted = False
            while eng.ready:
                s = eng._free_slot()
                if s is None:
                    break
                req, cache1, npos = eng.ready.pop(0)
                if eng.plan_placement:
                    req.placement = eng._plan_placement().location
                    eng.placements[req.placement] = \
                        eng.placements.get(req.placement, 0) + 1
                eng._activate(s, req, cache1, npos)
                admitted = True
            if admitted:
                eng.decodable.fire()
            yield eng.admittable


class DecodeReplica:
    """One decode-path worker in the engine's replica pool: a runtime
    Process that claims per-slot cache-read shards from
    ``engine._decode_items`` and moves them *concurrently* over its own
    path (continuous batching: each active slot's read is an
    independent flow, so a decode-heavy engine contends on a shared
    path in proportion to its live batch, and replicas absorb whole
    batches in parallel). The base replica (``fallback=True``) rides
    the time model's default decode path and only serves while no extra
    replicas exist — scaling out *moves* the decode traffic off the
    shared path instead of adding to it, which is how spawning replicas
    frees prefill bandwidth (and TTFT) on the path the tenants contend
    on. Retirement cancels the in-flight shard transfers; the
    completion callback re-queues each unmoved remainder — work is
    deferred to the survivors, never lost, so token streams are
    bit-identical across scale events."""

    def __init__(self, engine: "StagedServeEngine", path: str,
                 fallback: bool = False):
        self.engine = engine
        self.path = path
        self.fallback = fallback
        self.retired = False
        self.proc = None
        self.inflight: List = []

    def serve(self):
        eng = self.engine
        while True:
            if eng._decode_items and not (self.fallback and eng._extras()):
                # claim my fair share of the queued shards (ceil split
                # over the serving replicas); a straggler shard left by
                # rounding re-fires the signal and drains at the same
                # simulated instant
                live = len(eng._extras()) or 1
                take = min(-(-len(eng._decode_items) // live),
                           len(eng._decode_items))
                for _ in range(take):
                    amt = eng._decode_items.pop(0)
                    # accounting lives in the completion callback, not
                    # after a yield: a retired replica's generator is
                    # closed, but its callbacks still run
                    t = eng.runtime.transfer(
                        self.path, amt, flow=f"decode:{self.path}",
                        tenant=eng.tenant, on_complete=self._shard_done)
                    self.inflight.append(t)
                if eng._decode_items:
                    eng.decode_work.fire()
            yield eng.decode_work

    def _shard_done(self, t) -> None:
        if t in self.inflight:
            self.inflight.remove(t)
        self.engine._on_decode_shard_done(t)


class DecodeStage:
    """Advances every active slot one token per iteration; the step's
    batched cache read is charged as transfers on the decode path(s),
    overlapping any in-flight prefill transfers. With the engine's
    replica pool enabled, default-path reads are sharded across the
    live replicas (continuous batching: the batch membership at each
    step is whatever slots are active — replicas only change *where*
    the bytes move) while explicitly-placed reads keep their paths."""

    def __init__(self, engine: "StagedServeEngine"):
        self.engine = engine

    def process(self):
        eng, tm = self.engine, self.engine.tm
        while True:
            act = [s for s in range(eng.slots) if eng.active[s] is not None]
            if not act:
                if eng._n_open == 0:
                    return
                yield eng.decodable
                continue
            logits = eng._decode_compute(act)
            groups: Dict[str, int] = {}
            for s in act:
                path = tm.decode_path_for(eng.active[s].placement)
                groups[path] = groups.get(path, 0) + 1
            # start every placement group's cache read at once; the step
            # completes when the slowest path drains
            transfers = []
            pool_amt, pool_slots = 0.0, 0
            for path in sorted(groups):
                amt = groups[path] * tm.decode_units_per_slot
                if amt <= 0:
                    continue
                if eng._decode_pool and path == tm.decode_path:
                    pool_amt += amt
                    pool_slots += groups[path]
                else:
                    transfers.append(eng.runtime.transfer(
                        path, amt, flow=f"decode:{path}", tenant=eng.tenant))
            if pool_amt > 0:
                eng._dispatch_decode_pool(pool_amt, pool_slots)
            for tr in transfers:
                yield tr
            while eng._decode_open_amt > 1e-9:
                yield eng.decode_done
            retired = eng._finish_decode(act, logits)
            for req in retired:
                req.finish_time = eng.clock.now
                eng._n_open -= 1
            if retired:
                eng.admittable.fire()


class StagedServeEngine(_EngineCore):
    """The event-driven serving pipeline (see module docstring)."""

    def __init__(self, cfg: Optional[ModelConfig], params: Any, *,
                 slots: int = 4,
                 max_len: int = 256, impl: str = "auto",
                 cache_dtype=jnp.float32, seed: int = 0,
                 fabric: Optional[Fabric] = None,
                 time_model: Optional[ServeTimeModel] = None,
                 runtime: Optional[FabricRuntime] = None,
                 bucket_prefill: bool = True,
                 plan_placement: bool = False,
                 cache_hit_mass: float = 0.7, placement_costs=None,
                 max_inflight_prefills: int = 2,
                 tenant: Optional[str] = None,
                 compute: str = "jax",
                 decode_pool: bool = False,
                 tracer=None):
        super().__init__(cfg, params, slots=slots, max_len=max_len, impl=impl,
                         cache_dtype=cache_dtype, seed=seed,
                         bucket_prefill=bucket_prefill, compute=compute)
        self.tenant = tenant
        if runtime is None:
            if fabric is None:
                raise ValueError("StagedServeEngine needs a fabric or runtime")
            runtime = FabricRuntime(fabric, tracer=tracer)
        elif tracer is not None:
            raise ValueError("pass the tracer to the shared runtime, "
                             "not to the engine")
        if time_model is None:
            raise ValueError("StagedServeEngine needs a ServeTimeModel")
        self.runtime, self.tm = runtime, time_model
        self.clock = runtime.clock
        self.plan_placement = plan_placement
        self.cache_hit_mass, self.placement_costs = cache_hit_mass, placement_costs
        self.placements: Dict[str, int] = {}
        self.ready: List[Tuple[Request, Any, int]] = []
        self.arrived = Signal(self.clock)
        self.admittable = Signal(self.clock)
        self.decodable = Signal(self.clock)
        self.prefill_stage = PrefillStage(self, max_inflight=max_inflight_prefills)
        self.admit_stage = AdmitStage(self)
        self.decode_stage = DecodeStage(self)
        self._n_open = 0
        self._started = False
        self.intake_paused = False       # admission arbitration gate
        # -- decode replica pool (autoscaling target) ------------------
        self._decode_pool = decode_pool
        self._replicas: List[DecodeReplica] = []
        self._decode_items: List[float] = []   # sharded cache-read amounts
        self._decode_open_amt = 0.0            # dispatched, not yet moved
        self.decode_work = Signal(self.clock)  # shards queued
        self.decode_done = Signal(self.clock)  # all dispatched work moved
        self.scale_events: List[dict] = []
        if decode_pool:
            self.add_decode_replica(self.tm.decode_path, fallback=True)

    def _plan_placement(self):
        from repro.serve.disagg import plan_decode_placement
        return plan_decode_placement(
            self.runtime.fabric, hit_mass=self.cache_hit_mass,
            costs=self.placement_costs, ledger=self.runtime.ledger)

    # -- decode replica pool -------------------------------------------
    def _extras(self) -> List[DecodeReplica]:
        return [r for r in self._replicas if not r.fallback and not r.retired]

    @property
    def n_decode_replicas(self) -> int:
        """Extra (non-fallback) decode replicas currently serving."""
        return len(self._extras())

    def add_decode_replica(self, path: Optional[str] = None, *,
                           fallback: bool = False) -> DecodeReplica:
        """Scale out: spawn a decode worker on ``path`` (default: the
        time model's decode path) as a runtime Process."""
        if not self._decode_pool:
            raise ValueError("engine was built without decode_pool=True")
        path = path if path is not None else self.tm.decode_path
        if path not in self.runtime.fabric:
            raise ValueError(f"unknown decode path {path!r}")
        rep = DecodeReplica(self, path, fallback=fallback)
        rep.proc = self.runtime.process(rep.serve(),
                                        name=f"decode-replica:{path}")
        self._replicas.append(rep)
        if not fallback:
            self.scale_events.append({
                "t": self.clock.now, "event": "scale_out", "path": path,
                "replicas": self.n_decode_replicas})
            self.decode_work.fire()    # queued shards may now move here
        return rep

    def retire_decode_replica(self) -> Optional[DecodeReplica]:
        """Scale in: kill the newest extra replica. Its in-flight shard
        transfers cancel (reservation back to the ledger) and each
        unmoved remainder is re-queued for the survivors. The fallback
        replica is never retired — the pool cannot scale below the base
        capacity."""
        extras = self._extras()
        if not extras:
            return None
        rep = extras[-1]
        rep.retired = True
        self._replicas.remove(rep)
        rep.proc.kill()
        for t in list(rep.inflight):
            if not t.done:
                self.runtime.cancel(t)
        self.scale_events.append({
            "t": self.clock.now, "event": "scale_in", "path": rep.path,
            "replicas": self.n_decode_replicas})
        # the fallback may need to pick re-queued work back up
        self.decode_work.fire()
        return rep

    def _dispatch_decode_pool(self, amount: float, shards: int = 1) -> None:
        """Queue one decode step's default-path cache read as per-slot
        shards; the live replicas (extras if any exist, else the
        fallback) claim and move them concurrently."""
        n = max(int(shards), 1)
        share = amount / n
        self._decode_items.extend([share] * n)
        self._decode_open_amt += amount
        self.decode_work.fire()

    def _on_decode_shard_done(self, t) -> None:
        if t.canceled and t.remaining > 1e-9:
            # a retired replica's shard: defer the remainder
            self._decode_items.append(t.remaining)
            self._decode_open_amt -= t.amount - t.remaining
            self.decode_work.fire()
        else:
            self._decode_open_amt -= t.amount
        if self._decode_open_amt <= 1e-9 and not self._decode_items:
            self._decode_open_amt = 0.0
            self.decode_done.fire()

    # -- admission arbitration gate ------------------------------------
    def pause_intake(self) -> None:
        """Defer this tenant's prefill dispatch (already-inflight work
        keeps running) — the serve-tenant analog of
        ``TrainCluster.pause_transfers`` for K-tenant arbitration."""
        self.intake_paused = True

    def resume_intake(self) -> None:
        if self.intake_paused:
            self.intake_paused = False
            self.arrived.fire()

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Requests enter the queue at their ``arrival`` time."""
        self._n_open += 1
        self.clock.at(max(req.arrival, self.clock.now), self._on_arrival, req)

    def _on_arrival(self, req: Request):
        self.queue.append(req)
        # open-loop traffic: the decode loop drains and exits whenever
        # the engine goes momentarily idle — respawn it for the new wave
        if self._started and self._decode_proc.done:
            self._decode_proc = self.runtime.process(
                self.decode_stage.process(), name="DecodeStage")
        self.arrived.fire()

    def _start(self):
        if not self._started:
            self._started = True
            self.runtime.process(self.prefill_stage.process(), name="PrefillStage")
            self.runtime.process(self.admit_stage.process(), name="AdmitStage")
            self._decode_proc = self.runtime.process(
                self.decode_stage.process(), name="DecodeStage")

    def start(self) -> None:
        """Spawn the stage processes without driving the clock — for
        embedding this engine as one tenant in a larger timeline (the
        tenancy Colocation harness owns the clock there)."""
        self._start()

    @property
    def idle(self) -> bool:
        """True when every submitted request has been retired."""
        return self._n_open == 0

    @property
    def prefill_backlog(self) -> int:
        """Requests not yet through prefill: queued, in flight, or ready
        but unadmitted — the admission controller's 'serve still has
        latency-critical work pending' signal."""
        return len(self.queue) + self.prefill_stage.inflight + len(self.ready)

    def run(self, until: Optional[float] = None) -> List[Request]:
        """Run the simulated timeline until all submitted requests are
        served (or ``until``); returns and drains the retired requests."""
        self._start()
        if self._decode_proc.done and self._n_open > 0:
            # the decode loop drained on a previous run(); new work arrived
            self._decode_proc = self.runtime.process(
                self.decode_stage.process(), name="DecodeStage")
        self.clock.run(until=until)
        completed, self.finished = self.finished, []
        return completed
