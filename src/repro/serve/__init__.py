from repro.serve.engine import Request, ServeEngine
from repro.serve.disagg import DisaggKV, KVStoreParams
