from repro.serve.engine import (Request, ServeEngine, ServeTimeModel,
                                StagedServeEngine)
from repro.serve.disagg import (DisaggKV, KVStoreParams, PathCosts,
                                PlacementPlan, kv_alternatives, kv_fabric,
                                kv_serve_time_model, plan_decode_placement)
