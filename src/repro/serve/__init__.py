from repro.serve.engine import Request, ServeEngine
from repro.serve.disagg import (DisaggKV, KVStoreParams, PathCosts,
                                PlacementPlan, kv_alternatives, kv_fabric,
                                plan_decode_placement)
