"""DrTM-KV on an off-path SmartNIC — the paper's §5.2 case study.

A disaggregated key-value store with a cluster-chaining hash index
(one READ usually locates the value) and five offload alternatives
(paper Figure 16):

  A1  client READ index on host + READ value on host          (path ①x2)
  A2  client SEND to SoC; SoC walks index + DMA-reads value   (②+③*)
  A3  A2 with the index held in SoC memory                    (②+③*)
  A4  client READ index on SoC + READ value on host           (②+①)
  A5  client READ index on SoC + READ value from SoC cache    (②x2)
      (miss -> SoC returns the address; client falls back to A4)

The data plane is real: numpy hash index (cluster chaining), value
store, SoC-memory value cache with hot-key replication (Advice #1).
The *performance* plane is the calibrated path Fabric (latencies and
per-endpoint rate caps from the paper's Figure 3/17 measurements, as
ops/s paths), because this container has no RDMA fabric — every number
used is listed in PathCosts and cross-checked against the paper in
benchmarks/bench_kvserve.py. Throughput composition (e.g. A4+A5) goes
through the fabric's MultipathRouter, with the §4.1 concurrency
discount applied once by the fabric, not per call site.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import (Allocation, Alternative, Fabric,
                               MultipathRouter, OPS_PER_S, Path, Use)


@dataclasses.dataclass(frozen=True)
class PathCosts:
    """Calibrated against the paper (64 B payloads, µs / Mop/s)."""
    read_host_us: float = 2.6        # Fig 3: READ via ① on SNIC
    read_soc_us: float = 2.2         # Fig 3: READ via ② (≈15% faster)
    send_host_us: float = 3.6        # SEND/RECV ①
    send_soc_us: float = 4.6         # SEND/RECV ② (wimpy SoC, §3.2)
    dma_soc_host_us: float = 1.9     # ③* 64 B (§3.3)
    read_host_rate: float = 100e6    # one-sided ops/s the host path sustains
    read_soc_rate: float = 140e6     # §3.2: 1.08–1.48x faster to SoC
    rnic_read_rate: float = 110e6    # plain ConnectX-6 one-sided ops/s
    nic_core_rate: float = 195e6     # total NIC processing ops/s
    mixed_nic_efficiency: float = 0.6  # §4.1: host+SoC endpoints share most
    #                                    NIC cores; mixing costs efficiency
    send_soc_rate: float = 21.6e6    # §5.2: SoC SEND/RECV cap
    soc_cpu_rate: float = 25e6       # SoC index-walk ops/s
    dma_rate: float = 30e6           # ③* small-payload ops/s (Fig 11)
    concurrency_discount: float = 0.125  # §4.1: paths running concurrently
    #                                      lose 7–15% on shared resources


def kv_fabric(costs: PathCosts = PathCosts()) -> Fabric:
    """The §5.2 RDMA fabric: every endpoint rate cap is an ops/s path;
    each path is its own interference group, and the fabric carries the
    §4.1 concurrency discount (applied once, by the ledger/router —
    never at call sites)."""
    c = costs
    mk = lambda name, rate: Path(name, rate, OPS_PER_S, latency=1e-6,
                                 kind="rdma")
    return Fabric.of(
        mk("host_read", c.read_host_rate),
        mk("soc_read", c.read_soc_rate),
        mk("nic_cores", c.nic_core_rate),
        mk("soc_send", c.send_soc_rate),
        mk("soc_cpu", c.soc_cpu_rate),
        mk("dma", c.dma_rate),
        concurrency_discount=c.concurrency_discount,
    )


def kv_serve_time_model(units_per_token: float = 1e5):
    """The §5.2 ``ServeTimeModel`` for serving over ``kv_fabric()``:
    prefill ships the prompt KV over the ③* DMA path, decode cache
    reads go to the SoC cache or the host per the placement decision.
    One calibration, shared by the bench (fig18/staged_engine_ttft) and
    the --staged launcher so they cannot drift apart."""
    from repro.serve.engine import ServeTimeModel
    return ServeTimeModel(
        prefill_path="dma", decode_path="host_read",
        prefill_units_per_token=units_per_token,
        decode_units_per_slot=units_per_token,
        placement_paths={"soc_cache": "soc_read", "host": "host_read"})


def kv_alternatives(costs: PathCosts = PathCosts(),
                    reads_per_index: float = 1.0) -> Dict[str, Alternative]:
    """The five offload alternatives of Figure 16, declared in ops/s
    units against kv_fabric()."""
    c, r, ops = costs, reads_per_index, OPS_PER_S
    return {
        "A1": Alternative("A1", uses=[
            Use("host_read", out=r + 1, units=ops),
            Use("nic_cores", out=r + 1, units=ops)],
            criteria={"latency_us": (r + 1) * c.read_host_us}),
        "A2": Alternative("A2", uses=[
            Use("soc_send", out=1, units=ops), Use("soc_cpu", out=1, units=ops),
            Use("dma", out=1, units=ops), Use("nic_cores", out=1, units=ops)],
            criteria={"latency_us": c.send_soc_us + c.dma_soc_host_us}),
        "A3": Alternative("A3", uses=[
            Use("soc_send", out=1, units=ops), Use("soc_cpu", out=1, units=ops),
            Use("dma", out=1, units=ops), Use("nic_cores", out=1, units=ops)],
            criteria={"latency_us": c.send_soc_us + c.dma_soc_host_us}),
        "A4": Alternative("A4", uses=[
            Use("soc_read", out=r, units=ops),
            Use("host_read", out=1, units=ops),
            # mixed host+SoC endpoints underuse the shared NIC cores
            Use("nic_cores", out=(r + 1) / c.mixed_nic_efficiency, units=ops)],
            criteria={"latency_us": r * c.read_soc_us + c.read_host_us}),
        "A5": Alternative("A5", uses=[
            Use("soc_read", out=r + 1, units=ops),
            Use("nic_cores", out=r + 1, units=ops)],
            criteria={"latency_us": (r + 1) * c.read_soc_us}),
    }


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Decode-cache placement decision for the serving engine (§5.2
    wired into serving): where the hot value/KV-cache reads should land
    and the predicted get rate of that choice."""
    location: str                      # "soc_cache" | "host"
    rate: float                        # predicted gets/s of the choice
    baseline_rate: float               # host-only (A1) rate
    hit_mass: float
    allocations: List[Allocation]


def plan_decode_placement(fabric: Fabric, *, hit_mass: float = 0.7,
                          costs: Optional[PathCosts] = None,
                          reads_per_index: float = 1.0,
                          ledger=None, occupancy=None,
                          tenant: Optional[str] = None) -> PlacementPlan:
    """Choose where the decode cache lives by routing the §5.2
    alternatives over `fabric`: SoC cache placement (A5 hits + A4
    misses, blended at `hit_mass`) vs the best cache-less alternative
    (A1 host-only or A4 SoC-index). Pass the same `costs` the fabric
    was calibrated with (use coefficients like mixed_nic_efficiency
    come from it, not from the fabric).

    With a ``ledger`` (a ``BudgetLedger`` over the same fabric, e.g.
    the fabric runtime's), the plan is made from *live* occupancy: the
    current holders count toward the §4.1 discount and their
    reservations shrink every path budget — so the staged engine's
    AdmitStage can re-plan per admitted request and flip to the host
    path once the SoC-side budgets are eaten.

    ``occupancy`` (the ``InterferenceReport.occupancy`` attribution,
    ``path -> tenant -> fraction``) makes the plan *tenant-aware*
    without a live ledger: the other tenants' measured shares become
    external reservations, while ``tenant``'s own traffic is excluded —
    a tenant should not flee a path it is itself the load on. Ignored
    when an explicit ``ledger`` is given."""
    if ledger is None and occupancy is not None:
        # lazy import: tenancy builds on serve, not the other way round
        from repro.tenancy.colocation import occupancy_ledger
        ledger = occupancy_ledger(
            fabric, occupancy, exclude=(tenant,) if tenant is not None else ())
    alts = kv_alternatives(costs if costs is not None else PathCosts(),
                           reads_per_index)
    router = MultipathRouter(fabric)
    for alt in alts.values():
        fabric.validate(alt)
    base_alt = max(("A1", "A4"),
                   key=lambda n: alts[n].solo_rate(fabric, ledger=ledger))
    base_rate = alts[base_alt].solo_rate(fabric, ledger=ledger)
    total, allocs = router.blend([(alts["A5"], hit_mass),
                                  (alts["A4"], 1.0 - hit_mass)],
                                 ledger=ledger)
    if total > base_rate:
        return PlacementPlan("soc_cache", total, base_rate, hit_mass, allocs)
    return PlacementPlan("host", base_rate, base_rate, hit_mass,
                         [Allocation(base_alt, base_rate, "solo")])


@dataclasses.dataclass
class KVStoreParams:
    n_keys: int = 100_000
    value_bytes: int = 64
    key_bytes: int = 8
    buckets_factor: float = 1.5
    soc_cache_keys: int = 10_000     # SoC memory capacity (values)
    hot_replicas: int = 3            # Advice #1: replicate hot entries
    zipf_theta: float = 0.99


class DisaggKV:
    """Real index/value arrays + modeled path costs."""

    def __init__(self, params: KVStoreParams, costs: PathCosts = PathCosts(),
                 seed: int = 0):
        self.p, self.c = params, costs
        rng = np.random.default_rng(seed)
        n = params.n_keys
        self.nbuckets = int(n * params.buckets_factor)
        # cluster-chaining hash index: bucket -> up to 4 (key, addr) slots
        self.index_keys = np.full((self.nbuckets, 4), -1, np.int64)
        self.index_addr = np.zeros((self.nbuckets, 4), np.int64)
        self.values = rng.integers(0, 256, size=(n, params.value_bytes),
                                   dtype=np.uint8)
        self.overflow: Dict[int, int] = {}
        for k in range(n):
            b = hash((k, 0x9E3779B9)) % self.nbuckets
            slot = np.argmax(self.index_keys[b] == -1)
            if self.index_keys[b, slot] == -1:
                self.index_keys[b, slot] = k
                self.index_addr[b, slot] = k
            else:
                self.overflow[k] = k
        # SoC value cache: hottest keys under zipf (key id == hotness rank)
        self.soc_cached = set(range(min(params.soc_cache_keys, n)))

    # ------------------------------------------------------------------
    def _index_lookup(self, key: int) -> Tuple[int, int]:
        """Returns (addr, n_reads needed)."""
        b = hash((key, 0x9E3779B9)) % self.nbuckets
        hit = np.where(self.index_keys[b] == key)[0]
        if hit.size:
            return int(self.index_addr[b, hit[0]]), 1
        return self.overflow[key], 2

    def get(self, key: int, alternative: str) -> Tuple[np.ndarray, float]:
        """Executes the data plane, returns (value, modeled latency s)."""
        c = self.c
        addr, nidx = self._index_lookup(key)
        val = self.values[addr]
        if alternative == "A1":
            lat = nidx * c.read_host_us + c.read_host_us
        elif alternative == "A2":
            lat = c.send_soc_us + c.dma_soc_host_us
        elif alternative == "A3":
            lat = c.send_soc_us + c.dma_soc_host_us   # index walk on-SoC memory
        elif alternative == "A4":
            lat = nidx * c.read_soc_us + c.read_host_us
        elif alternative == "A5":
            if key in self.soc_cached:
                lat = nidx * c.read_soc_us + c.read_soc_us
            else:  # miss: SoC returns address, client READs host (=A4 tail)
                lat = nidx * c.read_soc_us + c.read_host_us
        else:
            raise ValueError(alternative)
        return val, lat * 1e-6

    # ------------------------------------------------------------------
    # throughput model (paper Fig 17b/18): fabric + alternatives
    # ------------------------------------------------------------------
    def fabric(self) -> Fabric:
        """The §5.2 RDMA fabric (see module-level kv_fabric)."""
        return kv_fabric(self.c)

    def alternatives(self, reads_per_index: float = 1.0) -> Dict[str, Alternative]:
        return kv_alternatives(self.c, reads_per_index)

    def cache_hit_mass(self) -> float:
        """Zipf probability mass of the SoC-cached (hottest) keys — the
        fraction of gets A5 can serve."""
        ranks = np.arange(1, self.p.n_keys + 1, dtype=np.float64)
        w = 1.0 / ranks ** self.p.zipf_theta
        w /= w.sum()
        return float(w[:len(self.soc_cached)].sum())

    def combined_a4_a5(self) -> Tuple[float, List[Allocation]]:
        """Paper's winning combination: cache hits go A5, misses A4; the
        hit fraction is the zipf mass of the cached keys ("cache misses
        are rare", §5.2). The MultipathRouter scales the fixed mix up to
        the first saturated resource, with the §4.1 discount applied by
        the fabric to resources touched by both members."""
        m = self.cache_hit_mass()
        alts = self.alternatives()
        router = MultipathRouter(self.fabric())
        return router.blend([(alts["A5"], m), (alts["A4"], 1.0 - m)])

    def filtered_scan(self, keys, predicate, *, where: str = "soc-filter",
                      ledger=None, stats=None):
        """DrTM-KV get/put filtering (the offload tier's §5.2 workload):
        run ``predicate`` over the candidate values on the SoC cores so
        only matches cross the wire (``where="soc-filter"``), or read
        everything over the host path and filter client-side
        (``where="host-filter"``). Results are bit-identical either way;
        see offload/kvfilter.KVFilter for the placement planner."""
        from repro.offload.kvfilter import KVFilter
        return KVFilter(self, stats=stats).scan(keys, predicate,
                                                where=where, ledger=ledger)

    def zipf_keys(self, n: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        # standard YCSB zipfian over key ranks
        ranks = np.arange(1, self.p.n_keys + 1, dtype=np.float64)
        w = 1.0 / ranks ** self.p.zipf_theta
        w /= w.sum()
        return rng.choice(self.p.n_keys, size=n, p=w)
