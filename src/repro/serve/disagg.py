"""DrTM-KV on an off-path SmartNIC — the paper's §5.2 case study.

A disaggregated key-value store with a cluster-chaining hash index
(one READ usually locates the value) and five offload alternatives
(paper Figure 16):

  A1  client READ index on host + READ value on host          (path ①x2)
  A2  client SEND to SoC; SoC walks index + DMA-reads value   (②+③*)
  A3  A2 with the index held in SoC memory                    (②+③*)
  A4  client READ index on SoC + READ value on host           (②+①)
  A5  client READ index on SoC + READ value from SoC cache    (②x2)
      (miss -> SoC returns the address; client falls back to A4)

The data plane is real: numpy hash index (cluster chaining), value
store, SoC-memory value cache with hot-key replication (Advice #1).
The *performance* plane is the calibrated path model (latencies and
per-endpoint rate caps from the paper's Figure 3/17 measurements),
because this container has no RDMA fabric — every number used is listed
in PathCosts and cross-checked against the paper in
benchmarks/bench_kvserve.py. Throughput composition (e.g. A4+A5) goes
through the §4.2 greedy planner.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.planner import Allocation, Alternative, PathPlanner, PathUse
from repro.core.paths import PathSpec


@dataclasses.dataclass(frozen=True)
class PathCosts:
    """Calibrated against the paper (64 B payloads, µs / Mop/s)."""
    read_host_us: float = 2.6        # Fig 3: READ via ① on SNIC
    read_soc_us: float = 2.2         # Fig 3: READ via ② (≈15% faster)
    send_host_us: float = 3.6        # SEND/RECV ①
    send_soc_us: float = 4.6         # SEND/RECV ② (wimpy SoC, §3.2)
    dma_soc_host_us: float = 1.9     # ③* 64 B (§3.3)
    read_host_rate: float = 100e6    # one-sided ops/s the host path sustains
    read_soc_rate: float = 140e6     # §3.2: 1.08–1.48x faster to SoC
    rnic_read_rate: float = 110e6    # plain ConnectX-6 one-sided ops/s
    nic_core_rate: float = 195e6     # total NIC processing ops/s
    mixed_nic_efficiency: float = 0.6  # §4.1: host+SoC endpoints share most
    #                                    NIC cores; mixing costs efficiency
    send_soc_rate: float = 21.6e6    # §5.2: SoC SEND/RECV cap
    soc_cpu_rate: float = 25e6       # SoC index-walk ops/s
    dma_rate: float = 30e6           # ③* small-payload ops/s (Fig 11)
    concurrency_discount: float = 0.125  # §4.1: paths running concurrently
    #                                      lose 7–15% on shared resources


@dataclasses.dataclass
class KVStoreParams:
    n_keys: int = 100_000
    value_bytes: int = 64
    key_bytes: int = 8
    buckets_factor: float = 1.5
    soc_cache_keys: int = 10_000     # SoC memory capacity (values)
    hot_replicas: int = 3            # Advice #1: replicate hot entries
    zipf_theta: float = 0.99


class DisaggKV:
    """Real index/value arrays + modeled path costs."""

    def __init__(self, params: KVStoreParams, costs: PathCosts = PathCosts(),
                 seed: int = 0):
        self.p, self.c = params, costs
        rng = np.random.default_rng(seed)
        n = params.n_keys
        self.nbuckets = int(n * params.buckets_factor)
        # cluster-chaining hash index: bucket -> up to 4 (key, addr) slots
        self.index_keys = np.full((self.nbuckets, 4), -1, np.int64)
        self.index_addr = np.zeros((self.nbuckets, 4), np.int64)
        self.values = rng.integers(0, 256, size=(n, params.value_bytes),
                                   dtype=np.uint8)
        self.overflow: Dict[int, int] = {}
        for k in range(n):
            b = hash((k, 0x9E3779B9)) % self.nbuckets
            slot = np.argmax(self.index_keys[b] == -1)
            if self.index_keys[b, slot] == -1:
                self.index_keys[b, slot] = k
                self.index_addr[b, slot] = k
            else:
                self.overflow[k] = k
        # SoC value cache: hottest keys under zipf (key id == hotness rank)
        self.soc_cached = set(range(min(params.soc_cache_keys, n)))

    # ------------------------------------------------------------------
    def _index_lookup(self, key: int) -> Tuple[int, int]:
        """Returns (addr, n_reads needed)."""
        b = hash((key, 0x9E3779B9)) % self.nbuckets
        hit = np.where(self.index_keys[b] == key)[0]
        if hit.size:
            return int(self.index_addr[b, hit[0]]), 1
        return self.overflow[key], 2

    def get(self, key: int, alternative: str) -> Tuple[np.ndarray, float]:
        """Executes the data plane, returns (value, modeled latency s)."""
        c = self.c
        addr, nidx = self._index_lookup(key)
        val = self.values[addr]
        if alternative == "A1":
            lat = nidx * c.read_host_us + c.read_host_us
        elif alternative == "A2":
            lat = c.send_soc_us + c.dma_soc_host_us
        elif alternative == "A3":
            lat = c.send_soc_us + c.dma_soc_host_us   # index walk on-SoC memory
        elif alternative == "A4":
            lat = nidx * c.read_soc_us + c.read_host_us
        elif alternative == "A5":
            if key in self.soc_cached:
                lat = nidx * c.read_soc_us + c.read_soc_us
            else:  # miss: SoC returns address, client READs host (=A4 tail)
                lat = nidx * c.read_soc_us + c.read_host_us
        else:
            raise ValueError(alternative)
        return val, lat * 1e-6

    # ------------------------------------------------------------------
    # throughput model (paper Fig 17b/18): planner alternatives
    # ------------------------------------------------------------------
    def paths(self) -> Dict[str, PathSpec]:
        c = self.c
        mk = lambda name, rate: PathSpec(name, "ici", None, 2, rate, 1e-6,
                                         True, name)
        return {
            "host_read": mk("host_read", c.read_host_rate),
            "soc_read": mk("soc_read", c.read_soc_rate),
            "nic_cores": mk("nic_cores", c.nic_core_rate),
            "soc_send": mk("soc_send", c.send_soc_rate),
            "soc_cpu": mk("soc_cpu", c.soc_cpu_rate),
            "dma": mk("dma", c.dma_rate),
        }

    def alternatives(self, reads_per_index: float = 1.0) -> Dict[str, Alternative]:
        r = reads_per_index
        return {
            "A1": Alternative("A1", uses=[
                PathUse("host_read", out_bytes=r + 1),
                PathUse("nic_cores", out_bytes=r + 1)],
                criteria={"latency_us": (r + 1) * self.c.read_host_us}),
            "A2": Alternative("A2", uses=[
                PathUse("soc_send", out_bytes=1), PathUse("soc_cpu", out_bytes=1),
                PathUse("dma", out_bytes=1), PathUse("nic_cores", out_bytes=1)],
                criteria={"latency_us": self.c.send_soc_us + self.c.dma_soc_host_us}),
            "A3": Alternative("A3", uses=[
                PathUse("soc_send", out_bytes=1), PathUse("soc_cpu", out_bytes=1),
                PathUse("dma", out_bytes=1), PathUse("nic_cores", out_bytes=1)],
                criteria={"latency_us": self.c.send_soc_us + self.c.dma_soc_host_us}),
            "A4": Alternative("A4", uses=[
                PathUse("soc_read", out_bytes=r), PathUse("host_read", out_bytes=1),
                # mixed host+SoC endpoints underuse the shared NIC cores
                PathUse("nic_cores",
                        out_bytes=(r + 1) / self.c.mixed_nic_efficiency)],
                criteria={"latency_us": r * self.c.read_soc_us + self.c.read_host_us}),
            "A5": Alternative("A5", uses=[
                PathUse("soc_read", out_bytes=r + 1),
                PathUse("nic_cores", out_bytes=r + 1)],
                criteria={"latency_us": (r + 1) * self.c.read_soc_us}),
        }

    def cache_hit_mass(self) -> float:
        """Zipf probability mass of the SoC-cached (hottest) keys — the
        fraction of gets A5 can serve."""
        ranks = np.arange(1, self.p.n_keys + 1, dtype=np.float64)
        w = 1.0 / ranks ** self.p.zipf_theta
        w /= w.sum()
        return float(w[:len(self.soc_cached)].sum())

    def combined_a4_a5(self) -> Tuple[float, List]:
        """Paper's winning combination: cache hits go A5, misses A4; the
        hit fraction is the zipf mass of the cached keys ("cache misses
        are rare", §5.2). Peak rate = min over resources of
        budget / (m * A5_use + (1-m) * A4_use)."""
        m = self.cache_hit_mass()
        paths = self.paths()
        alts = self.alternatives()
        usage: Dict[str, float] = {}
        touched: Dict[str, int] = {}
        for frac, alt in ((m, alts["A5"]), (1 - m, alts["A4"])):
            for u in alt.uses:
                usage[u.path] = usage.get(u.path, 0.0) + frac * u.out_bytes
                touched[u.path] = touched.get(u.path, 0) + 1
        # §4.1: resources shared by concurrently-active paths lose 7–15%
        disc = 1.0 - self.c.concurrency_discount
        total = min(paths[p].bw * (disc if touched[p] > 1 else 1.0) / use
                    for p, use in usage.items() if use > 0)
        allocs = [Allocation("A5", m * total, "soc_read:out"),
                  Allocation("A4", (1 - m) * total, "cache_miss_fraction")]
        return total, allocs

    def zipf_keys(self, n: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        # standard YCSB zipfian over key ranks
        ranks = np.arange(1, self.p.n_keys + 1, dtype=np.float64)
        w = 1.0 / ranks ** self.p.zipf_theta
        w /= w.sum()
        return rng.choice(self.p.n_keys, size=n, p=w)
