"""Blockwise int8 quantize / dequantize Pallas kernels.

Grid tiles rows of a (nblk, blk) layout; each tile lives in VMEM. The
quantizer is the compression hot spot for gradient sync over DCN and
checkpoint replication (paper: compress before the slow path)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0 + 1e-30
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, dtype):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(dtype)


def quantize_int8_pallas(x: jax.Array, *, rows_per_tile: int = 8,
                         interpret: bool = False):
    """x (nblk, blk) -> (q (nblk, blk) int8, scale (nblk, 1) f32)."""
    nblk, blk = x.shape
    rows = min(rows_per_tile, nblk)
    while nblk % rows:
        rows -= 1
    grid = (nblk // rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, blk), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, blk), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nblk, blk), jnp.int8),
                   jax.ShapeDtypeStruct((nblk, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_int8_pallas(q: jax.Array, scale: jax.Array, *,
                           dtype=jnp.float32, rows_per_tile: int = 8,
                           interpret: bool = False):
    nblk, blk = q.shape
    rows = min(rows_per_tile, nblk)
    while nblk % rows:
        rows -= 1
    grid = (nblk // rows,)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, dtype=dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, blk), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, blk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, blk), dtype),
        interpret=interpret,
    )(q, scale)
