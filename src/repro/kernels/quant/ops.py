"""Public jit'd wrappers for the int8 quant kernels. On CPU (this
container) they run the kernel body in interpret mode; on TPU the same
call compiles to Mosaic."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_int8(x: jax.Array, block: int = 256):
    """Any-shape x -> (q (nblk, block) int8, scale (nblk,1) f32, meta)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, block)
    q, s = K.quantize_int8_pallas(blocks, interpret=_on_cpu())
    return q, s


@functools.partial(jax.jit, static_argnames=("shape", "dtype"))
def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    out = K.dequantize_int8_pallas(q, scale, dtype=dtype, interpret=_on_cpu())
    n = 1
    for d in shape:
        n *= d
    return out.reshape(-1)[:n].reshape(shape)
