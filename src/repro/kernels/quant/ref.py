"""Oracle for the int8 blockwise quantization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array):
    """x (nblk, blk) f32/bf16 -> (q int8 (nblk, blk), scale f32 (nblk, 1))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
