from repro.kernels.quant.ops import quantize_int8, dequantize_int8
