"""jit'd public wrapper: (B,S,H,hd) layout like the model zoo."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_block", "kv_block"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_block: int = 256, kv_block: int = 256) -> jax.Array:
    """q (B,S,Hq,hd); k/v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = K.flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                 softcap=softcap, q_block=q_block,
                                 kv_block=kv_block, interpret=_on_cpu())
    return out.swapaxes(1, 2)
