"""Oracle: quadratic attention (delegates to the model-zoo reference so
kernel and model share one source of truth)."""
from repro.models.attention import attention_ref as attention_ref  # noqa: F401
