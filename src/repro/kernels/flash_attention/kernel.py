"""Flash attention forward, Pallas/TPU.

Layout: inputs pre-transposed to (B, H, S, hd). Grid =
(B, Hq, nq, nkv) with the KV dimension innermost — TPU grid iteration is
sequential, so (m, l, acc) scratch in VMEM carries across KV steps.
Blocks fully above the causal diagonal or left of the sliding window are
skipped with ``pl.when`` (no MXU work issued), which is what keeps
compiled FLOPs ≈ useful FLOPs (paper Advice #2/#3: granularity).

VMEM budget per step: q/k/v tiles (block × hd) + acc (block × hd f32)
+ m/l vectors — e.g. block=512, hd=256: 3·512·256·2B + 512·256·4B ≈ 1.3 MB,
far under the ~64–128 MB VMEM of a v5e core; block sizes are multiples
of 128 to keep the MXU fully tiled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, q_block: int, kv_block: int,
               causal: bool, window: Optional[int],
               softcap: Optional[float]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip (causal / window)
    needed = True
    if causal:
        needed = ki * kv_block <= qi * q_block + (q_block - 1)
    if window is not None:
        needed = jnp.logical_and(
            needed, (ki + 1) * kv_block - 1 > qi * q_block - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (qb, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (kb, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qb, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        msk = jnp.ones(s.shape, dtype=bool)
        if causal:
            msk = jnp.logical_and(msk, kpos <= qpos)
        if window is not None:
            msk = jnp.logical_and(msk, kpos > qpos - window)
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * msk                          # zero masked rows
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(p.astype(v.dtype), v,
                                              (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         q_block: int = 256, kv_block: int = 256,
                         interpret: bool = False) -> jax.Array:
    """q (B,Hq,S,hd); k/v (B,Hkv,S,hd); returns (B,Hq,S,hd)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    groups = hq // hkv
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    nq, nkv = s // q_block, s // kv_block
    grid = (b, hq, nq, nkv)

    kern = functools.partial(
        _fa_kernel, scale=1.0 / (d ** 0.5), q_block=q_block,
        kv_block=kv_block, causal=causal, window=window, softcap=softcap)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda b_, h, qi, ki, g=groups: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, d),
                         lambda b_, h, qi, ki, g=groups: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, d), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
