"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  flash_attention  — causal GQA attention w/ sliding window + logit softcap
  decode_attention — single-token flash-decoding against a KV cache
  ssd_scan         — Mamba2 SSD chunked scan (state carried across chunks)
  quant            — blockwise int8 compress/decompress (grad/ckpt/KV paths)
"""
