"""Oracles: the chunked-parallel SSD and the sequential recurrence from
the model zoo (one source of truth)."""
from repro.models.ssm import ssd_chunked as ssd_chunked_ref  # noqa: F401
from repro.models.ssm import ssd_ref as ssd_sequential_ref   # noqa: F401
