"""jit'd wrapper for the SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "head_tile"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, C: jax.Array, *, chunk: int = 128,
             head_tile: int = 8):
    """Mamba2 SSD: returns (y (B,S,H,P), final_state (B,H,P,N))."""
    return K.ssd_scan_pallas(x, dt, A, Bm, C, chunk=chunk,
                             head_tile=head_tile, interpret=_on_cpu())
