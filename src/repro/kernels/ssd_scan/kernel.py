"""Mamba2 SSD chunked scan, Pallas/TPU.

Grid = (B, n_head_tiles, n_chunks) with chunks innermost; the running
inter-chunk state (Ht, P, N) lives in VMEM scratch and carries across
chunk iterations — the TPU-native version of the paper's "keep the
recurrent state close to the compute" (the SoC analogue holds its own
working set; cf. DESIGN.md path mapping).

Per chunk and head-tile the kernel computes, entirely in VMEM:
  intra  = tril(C B^T * decay) @ x        (the quadratic branch, MXU)
  inter  = C @ h_prev * exp(cum)          (read of the carried state)
  h_new  = h_prev * exp(sum_dA) + sum_s exp(last-cum_s) dt_s B_s x_s

VMEM per step (L=chunk, Ht=head tile, P=head dim, N=state):
x (L,Ht,P) + scores (L,L,Ht) + state (Ht,P,N) f32 — e.g. L=128, Ht=8,
P=64, N=128: ~1.2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)         # (L, Ht, P)
    dt = dt_ref[0].astype(jnp.float32)       # (L, Ht)
    A = a_ref[0].astype(jnp.float32)         # (Ht,)
    Bm = b_ref[0].astype(jnp.float32)        # (L, N)
    C = c_ref[0].astype(jnp.float32)         # (L, N)

    dA = dt * A[None, :]                     # (L, Ht)
    cum = jnp.cumsum(dA, axis=0)             # (L, Ht)

    # ---- intra-chunk ----
    CB = jax.lax.dot_general(C, Bm, (((1,), (1,)), ((), ())))   # (L, L)
    decay = jnp.exp(cum[:, None, :] - cum[None, :, :])          # (L, L, Ht)
    L = x.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tril = (si <= ti)
    scores = CB[:, :, None] * decay * dt[None, :, :]            # (L, L, Ht)
    scores = jnp.where(tril[:, :, None], scores, 0.0)
    y = jnp.einsum("tsh,shp->thp", scores, x)                   # (L, Ht, P)

    # ---- inter-chunk: read carried state ----
    h_prev = h_ref[...]                                          # (Ht, P, N)
    y += jnp.einsum("tn,hpn->thp", C, h_prev) * jnp.exp(cum)[:, :, None]

    # ---- state update ----
    last = cum[-1:, :]                                           # (1, Ht)
    w = jnp.exp(last - cum) * dt                                 # (L, Ht)
    new_state = jnp.einsum("th,tn,thp->hpn", w, Bm, x)
    h_ref[...] = h_prev * jnp.exp(last[0])[:, None, None] + new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, C: jax.Array, *,
                    chunk: int = 128, head_tile: int = 8,
                    interpret: bool = False):
    """x (B,S,H,P); dt (B,S,H); A (H,); Bm/C (B,S,N).
    Returns (y (B,S,H,P) f32, final state (B,H,P,N) f32)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    ht = min(head_tile, h)
    while h % ht:
        ht -= 1
    nc, nh = s // chunk, h // ht

    # layouts: x -> (B, H/Ht, S, Ht, P)? keep (B,S,H,P) and block on S and H.
    kern = functools.partial(_ssd_kernel, chunk=chunk)
    y, hfin = pl.pallas_call(
        kern,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, ht, p), lambda b_, hi, ci: (b_, ci, hi, 0)),
            pl.BlockSpec((1, chunk, ht), lambda b_, hi, ci: (b_, ci, hi)),
            pl.BlockSpec((1, ht), lambda b_, hi, ci: (0, hi)),
            pl.BlockSpec((1, chunk, n), lambda b_, hi, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, hi, ci: (b_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, ht, p), lambda b_, hi, ci: (b_, ci, hi, 0)),
            pl.BlockSpec((1, ht, p, n), lambda b_, hi, ci: (b_, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ht, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A[None], Bm, C)
    return y, hfin
