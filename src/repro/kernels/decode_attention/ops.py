"""jit'd wrapper with the model-zoo (B,1,Hq,hd) / (B,S,Hkv,hd) layout."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.decode_attention import kernel as K


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("window", "softcap", "kv_block"))
def decode_attention_kernel(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                            cache_len, *, window: Optional[int] = None,
                            softcap: Optional[float] = None,
                            kv_block: int = 256) -> jax.Array:
    """q (B,1,Hq,hd); caches (B,S,Hkv,hd); cache_len scalar.
    Returns (B,1,Hq,hd)."""
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qk = q[:, 0].reshape(b, hkv, g, d)                 # (B,Hkv,G,hd)
    kc = k_cache.swapaxes(1, 2)                        # (B,Hkv,S,hd)
    vc = v_cache.swapaxes(1, 2)
    out = K.decode_attention_bhgd(qk, kc, vc, cache_len, window=window,
                                  softcap=softcap, kv_block=kv_block,
                                  interpret=_on_cpu())
    return out.reshape(b, hq, d)[:, None]
