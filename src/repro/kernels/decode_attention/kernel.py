"""Flash-decoding, Pallas/TPU: one query token vs a long KV cache.

Grid = (B, Hkv, n_kv_blocks), KV innermost; scratch carries (m, l, acc)
for the `groups` query heads that share each KV head. Blocks entirely
beyond ``cache_len`` are skipped (pl.when) — the serving analogue of the
paper's advice to never issue oversized reads: the cache is walked in
``kv_block`` segments, and segments past the fill line cost nothing.

This is the DrTM-KV hot spot: the "value read" of a get(). The serve/
layer chooses *where* this runs (which path the cache shard lives on);
this kernel makes each shard's read fast.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(clen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, kv_block: int, window: Optional[int],
                softcap: Optional[float]):
    ki = pl.program_id(2)
    nkv = pl.num_programs(2)
    clen = clen_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lo = ki * kv_block
    needed = lo < clen
    if window is not None:
        needed = jnp.logical_and(needed, lo + kv_block > clen - window)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (kb, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        msk = kpos < clen
        if window is not None:
            msk = jnp.logical_and(msk, kpos >= clen - window)
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * msk
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(p.astype(v.dtype), v,
                                              (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ki == nkv - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_bhgd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                          cache_len: jax.Array, *,
                          window: Optional[int] = None,
                          softcap: Optional[float] = None,
                          kv_block: int = 256,
                          interpret: bool = False) -> jax.Array:
    """q (B,Hkv,G,hd) — G = query heads per KV head; caches (B,Hkv,S,hd);
    cache_len scalar int32. Returns (B,Hkv,G,hd)."""
    b, hkv, g, d = q.shape
    s = k_cache.shape[2]
    kv_block = min(kv_block, s)
    assert s % kv_block == 0
    nkv = s // kv_block

    kern = functools.partial(_dec_kernel, scale=1.0 / (d ** 0.5),
                             kv_block=kv_block, window=window, softcap=softcap)
    clen = jnp.asarray(cache_len, jnp.int32).reshape(1)
    return pl.pallas_call(
        kern,
        grid=(b, hkv, nkv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h, ki: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, kv_block, d), lambda b_, h, ki: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, kv_block, d), lambda b_, h, ki: (b_, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, ki: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(clen, q, k_cache, v_cache)
