"""Oracle: single-token KV-cache attention from the model zoo."""
from repro.models.attention import decode_attention as decode_attention_ref  # noqa: F401
