from repro.data.pipeline import TokenPipeline
