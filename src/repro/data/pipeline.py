"""Deterministic, resumable token pipeline.

Stateless addressing: ``batch_at(step)`` regenerates the exact batch for
any step — the property checkpoint/restart (ft/) relies on: a restarted
run replays the identical stream with no pipeline state to persist.

Two sources:
- synthetic: an order-1 autoregressive stream with controllable noise
  (so small models visibly learn within a few hundred steps);
- memmap: a flat uint16/uint32 token file, sliced deterministically.

Sharding: ``batch_at`` returns the *global* batch; the launcher device_puts
it against the batch NamedSharding (per-host slicing in a real multi-host
job happens by indexing with jax.process_index() — same addressing).
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class TokenPipeline:
    #: extension -> token dtype, for dtype sniffing on memmap files
    _EXT_DTYPES = {".u16": np.uint16, ".uint16": np.uint16,
                   ".u32": np.uint32, ".uint32": np.uint32}

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 seed: int = 0, data_path: Optional[str] = None,
                 noise: float = 0.1, dtype: Optional[np.dtype] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.noise = noise
        self._mm = None
        if data_path and os.path.exists(data_path):
            self._mm = np.memmap(data_path, mode="r",
                                 dtype=self._token_dtype(data_path, dtype))

    def _token_dtype(self, data_path: str, dtype: Optional[np.dtype]):
        """Explicit ``dtype=`` wins; otherwise sniff the extension
        (.u16/.u32). The fallback stays uint16 — the only format the
        pre-dtype code ever read — so existing .bin files keep their
        meaning; a wide-vocab file must say so via dtype or extension."""
        if dtype is not None:
            dt = np.dtype(dtype)
            if dt not in (np.dtype(np.uint16), np.dtype(np.uint32)):
                raise ValueError(f"token files are uint16 or uint32, not {dt}")
            return dt
        ext = os.path.splitext(data_path)[1].lower()
        if ext in self._EXT_DTYPES:
            return np.dtype(self._EXT_DTYPES[ext])
        return np.dtype(np.uint16)

    # ------------------------------------------------------------------
    def _synthetic_tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        """next = (5*prev + 17) % V, with `noise` fraction resampled."""
        v = self.cfg.vocab_size
        first = rng.integers(0, v, size=(b, 1))
        toks = np.empty((b, s), dtype=np.int64)
        toks[:, 0] = first[:, 0]
        for t in range(1, s):
            toks[:, t] = (5 * toks[:, t - 1] + 17) % v
        flip = rng.random((b, s)) < self.noise
        toks[flip] = rng.integers(0, v, size=int(flip.sum()))
        return toks.astype(np.int32)

    def _memmap_tokens(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        hi = len(self._mm) - (s + 1)
        starts = rng.integers(0, hi, size=b)
        return np.stack([np.asarray(self._mm[st:st + s + 1], dtype=np.int32)
                         for st in starts])

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s = self.shape.global_batch, self.shape.seq_len
        cb = self.cfg.num_codebooks
        ft = self.cfg.frontend_tokens if self.cfg.frontend else 0
        s_text = s - ft
        rng = np.random.default_rng((self.seed << 20) ^ (step + 1))

        if self._mm is not None:
            seq = self._memmap_tokens(rng, b, s_text)
            tokens, labels = seq[:, :-1], seq[:, 1:]
            # pipeline emits s_text tokens; pad the final position
            tokens = np.concatenate([tokens, tokens[:, -1:]], axis=1)[:, :s_text]
            labels = np.concatenate([labels, labels[:, -1:]], axis=1)[:, :s_text]
        elif cb > 1:
            toks = np.stack([self._synthetic_tokens(rng, b, s_text + 1)
                             for _ in range(cb)], axis=-1) % self.cfg.vocab_size
            tokens, labels = toks[:, :-1], toks[:, 1:]
        else:
            seq = self._synthetic_tokens(rng, b, s_text + 1)
            tokens, labels = seq[:, :-1], seq[:, 1:]

        out: Dict[str, np.ndarray] = {
            "tokens": tokens,
            "labels": labels,
        }
        if ft:
            out["frontend_embeds"] = (
                rng.standard_normal((b, ft, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
            # labels/mask over the full (frontend + text) sequence
            pad_lab = np.zeros((b, ft) + labels.shape[2:], labels.dtype)
            out["labels"] = np.concatenate([pad_lab, labels], axis=1)
            out["loss_mask"] = np.concatenate(
                [np.zeros((b, ft), np.float32), np.ones((b, s_text), np.float32)], axis=1)
        else:
            out["loss_mask"] = np.ones((b, s_text), np.float32)
        return out
