"""Elastic re-meshing: choose the best (pod, data, model) mesh for the
surviving device count and reshard state onto it.

Policy: keep the model axis (TP degree) fixed if possible — TP is
constrained by head/expert divisibility — and shrink data (FSDP) first;
drop to fewer pods only when a whole pod died. Resharding is a
device_put against the new NamedShardings (XLA moves the bytes; on a
real fleet this is the ICI/DCN reshard traffic the planner budgets).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.parallel.sharding import tree_shardings


def best_mesh_for(devices: int, *, model: int = 16,
                  prefer_pods: int = 2) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest mesh shape <= devices with the given TP degree.
    Returns (shape, axis_names)."""
    while model > 1 and devices % model:
        model //= 2
    rest = devices // model
    for pods in range(min(prefer_pods, rest), 0, -1):
        if rest % pods == 0:
            data = rest // pods
            if pods > 1:
                return (pods, data, model), ("pod", "data", "model")
            return (data, model), ("data", "model")
    return (rest, model), ("data", "model")


def make_mesh(shape: Tuple[int, ...], names: Tuple[str, ...],
              devices=None) -> jax.sharding.Mesh:
    n = 1
    for s in shape:
        n *= s
    devs = (devices or jax.devices())[:n]
    import numpy as np
    return jax.sharding.Mesh(np.array(devs).reshape(shape), names)


def reshard(tree, logical_tree, new_mesh: jax.sharding.Mesh):
    """Move a (params/opt) pytree onto a new mesh via its logical axes."""
    shapes = jax.tree.map(lambda x: x, tree)
    sh = tree_shardings(logical_tree, shapes, new_mesh)
    return jax.device_put(tree, sh)
