"""Fault tolerance: heartbeat failure detection + checkpoint/restart.

On a real cluster each host heartbeats to this manager (or to etcd/GCS);
here nodes are registered entities whose heartbeats tests (or the
simulated ``TrainCluster``) drive explicitly. The recovery policy is the
deliverable:

  failure detected -> quiesce -> pick survivor mesh (ft/elastic.py)
  -> restore newest committed checkpoint (any replica in the chain)
  -> reshard state onto the survivor mesh -> resume at step k+1.

Because the data pipeline is stateless-addressable (data/pipeline.py),
resume needs nothing beyond the step index.

Two detection modes:

- wall clock (default): callers poll ``check()``, which sweeps for
  lapsed heartbeats — the original behaviour, preserved.
- event-driven (``runtime=`` a ``FabricRuntime``): every heartbeat
  re-arms a per-node watchdog on the simulated clock; a node that goes
  silent fires the ``failed`` Signal exactly ``timeout`` simulated
  seconds after its last heartbeat, with no polling loop. The
  TrainCluster's failure watch yields on that Signal.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.ckpt.checkpoint import CheckpointManager


class NodeFailure(RuntimeError):
    """A node went silent and its failure watchdog expired. Raised by
    single-node drivers (Trainer.run_steps) once the event-driven
    detection fires; the recovery path is checkpoint restore."""


@dataclass
class NodeState:
    name: str
    last_heartbeat: float
    alive: bool = True
    devices: int = 0


class FaultToleranceManager:
    def __init__(self, ckpt: Optional[CheckpointManager], *,
                 timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 runtime=None):
        self.ckpt = ckpt
        self.timeout = timeout
        self.runtime = runtime
        self.clock = (lambda: runtime.clock.now) if runtime is not None \
            else clock
        self.nodes: Dict[str, NodeState] = {}
        self.events: List[dict] = []
        #: fires with the node name when a watchdog expires (runtime mode)
        self.failed = runtime.signal() if runtime is not None else None
        #: expired-watchdog queue — a Signal fire with no waiter drops
        #: its value, so watchers drain this after each wake-up
        self.pending_failures: List[str] = []
        self._watchdogs: Dict[str, object] = {}

    # ---- membership ----
    def register(self, name: str, devices: int = 1):
        self.nodes[name] = NodeState(name, self.clock(), True, devices)
        self._arm(name)

    def heartbeat(self, name: str):
        self.nodes[name].last_heartbeat = self.clock()
        self._arm(name)

    def check(self) -> List[str]:
        """Mark nodes whose heartbeat lapsed; returns newly-failed names.
        (Wall-clock polling mode; the runtime mode needs no polling.)"""
        now = self.clock()
        failed = []
        for n in self.nodes.values():
            if n.alive and now - n.last_heartbeat > self.timeout:
                self._fail(n)
                failed.append(n.name)
        return failed

    def alive_devices(self) -> int:
        return sum(n.devices for n in self.nodes.values() if n.alive)

    # ---- event-driven watchdogs (runtime mode) ----
    def _arm(self, name: str) -> None:
        if self.runtime is None:
            return
        clock = self.runtime.clock
        clock.cancel(self._watchdogs.get(name))
        self._watchdogs[name] = clock.schedule(
            self.timeout * (1 + 1e-9), self._expire, name)

    def _expire(self, name: str) -> None:
        self._watchdogs.pop(name, None)
        n = self.nodes.get(name)
        if n is not None and n.alive:
            self._fail(n)
            self.pending_failures.append(name)
            if self.failed is not None:
                self.failed.fire(name)

    def _fail(self, n: NodeState) -> None:
        n.alive = False
        self.events.append({"t": self.clock(), "event": "node_failed",
                            "node": n.name})

    def disarm(self) -> None:
        """Cancel every pending watchdog (lets a SimClock heap drain)."""
        if self.runtime is not None:
            for ev in self._watchdogs.values():
                self.runtime.clock.cancel(ev)
        self._watchdogs.clear()

    # ---- recovery ----
    def recover(self, like_tree, *, step: Optional[int] = None):
        """Restore the newest committed checkpoint (chain fallback built
        into CheckpointManager.restore). Returns (tree, resume_step)."""
        tree, k = self.ckpt.restore(like_tree, step)
        self.events.append({"t": self.clock(), "event": "restored", "step": k})
        return tree, k + 1
