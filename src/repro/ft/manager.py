"""Fault tolerance: heartbeat failure detection + checkpoint/restart.

On a real cluster each host heartbeats to this manager (or to etcd/GCS);
here nodes are registered entities whose heartbeats tests drive
explicitly. The recovery policy is the deliverable:

  failure detected -> quiesce -> pick survivor mesh (ft/elastic.py)
  -> restore newest committed checkpoint (any replica in the chain)
  -> reshard state onto the survivor mesh -> resume at step k+1.

Because the data pipeline is stateless-addressable (data/pipeline.py),
resume needs nothing beyond the step index.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.ckpt.checkpoint import CheckpointManager


@dataclass
class NodeState:
    name: str
    last_heartbeat: float
    alive: bool = True
    devices: int = 0


class FaultToleranceManager:
    def __init__(self, ckpt: CheckpointManager, *, timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.ckpt = ckpt
        self.timeout = timeout
        self.clock = clock
        self.nodes: Dict[str, NodeState] = {}
        self.events: List[dict] = []

    # ---- membership ----
    def register(self, name: str, devices: int = 1):
        self.nodes[name] = NodeState(name, self.clock(), True, devices)

    def heartbeat(self, name: str):
        self.nodes[name].last_heartbeat = self.clock()

    def check(self) -> List[str]:
        """Mark nodes whose heartbeat lapsed; returns newly-failed names."""
        now = self.clock()
        failed = []
        for n in self.nodes.values():
            if n.alive and now - n.last_heartbeat > self.timeout:
                n.alive = False
                failed.append(n.name)
                self.events.append({"t": now, "event": "node_failed", "node": n.name})
        return failed

    def alive_devices(self) -> int:
        return sum(n.devices for n in self.nodes.values() if n.alive)

    # ---- recovery ----
    def recover(self, like_tree, *, step: Optional[int] = None):
        """Restore the newest committed checkpoint (chain fallback built
        into CheckpointManager.restore). Returns (tree, resume_step)."""
        tree, k = self.ckpt.restore(like_tree, step)
        self.events.append({"t": self.clock(), "event": "restored", "step": k})
        return tree, k + 1
