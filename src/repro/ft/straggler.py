"""Straggler detection + mitigation hooks.

Detection: per-step wall times per node; a node whose EMA exceeds
``threshold`` x the fleet median is flagged. Mitigation on a real fleet:
(1) deprioritize its DCN traffic (planner slack rule), (2) shrink its
microbatch share (skewed-batch rebalance), (3) if persistent, treat as
failed -> elastic re-mesh. Here the detector + rebalance math are real;
tests drive them with synthetic timings.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class StragglerDetector:
    alpha: float = 0.3            # EMA coefficient
    threshold: float = 1.5        # x median => straggler
    ema: Dict[str, float] = field(default_factory=dict)

    def observe(self, node: str, step_seconds: float):
        prev = self.ema.get(node)
        self.ema[node] = (step_seconds if prev is None
                          else self.alpha * step_seconds + (1 - self.alpha) * prev)

    def stragglers(self) -> List[str]:
        if len(self.ema) < 2:
            return []
        med = float(np.median(list(self.ema.values())))
        return [n for n, v in self.ema.items() if v > self.threshold * med]

    def rebalanced_shares(self, total_microbatches: int) -> Dict[str, int]:
        """Give each node work inversely proportional to its step time —
        the skew-taming advice (#1) applied to compute instead of memory."""
        if not self.ema:
            return {}
        inv = {n: 1.0 / v for n, v in self.ema.items()}
        z = sum(inv.values())
        raw = {n: total_microbatches * w / z for n, w in inv.items()}
        shares = {n: max(1, int(round(r))) for n, r in raw.items()}
        # fix rounding drift
        drift = total_microbatches - sum(shares.values())
        order = sorted(shares, key=lambda n: -raw[n])
        i = 0
        while drift != 0 and order:
            n = order[i % len(order)]
            if drift > 0:
                shares[n] += 1; drift -= 1
            elif shares[n] > 1:
                shares[n] -= 1; drift += 1
            i += 1
        return shares
