"""Straggler detection + mitigation hooks.

Detection is two-signal:

- per-step wall (or simulated) times per node: a node whose EMA exceeds
  ``threshold`` x the fleet median is flagged — the lagging indicator;
- per-node *path occupancy* read straight from the BudgetLedger
  (``observe_ledger``): the fraction of a node's host-direction budget
  already reserved by other flows — the leading indicator. A node whose
  host path is spoken for will straggle on its next allreduce whether
  or not its step times have degraded yet (the paper's §6.1 host-load
  effect).

Mitigation on a real fleet: (1) deprioritize its DCN traffic (planner
slack rule), (2) shrink its microbatch share (skewed-batch rebalance),
(3) if persistent, treat as failed -> elastic re-mesh. Here the
detector + rebalance math are real; tests and the simulated
TrainCluster drive them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class StragglerDetector:
    alpha: float = 0.3            # EMA coefficient
    threshold: float = 1.5        # x median => straggler
    occupancy_threshold: float = 0.5   # reserved fraction => straggler
    ema: Dict[str, float] = field(default_factory=dict)
    occupancy: Dict[str, float] = field(default_factory=dict)

    def observe(self, node: str, step_seconds: float):
        prev = self.ema.get(node)
        self.ema[node] = (step_seconds if prev is None
                          else self.alpha * step_seconds + (1 - self.alpha) * prev)

    def observe_occupancy(self, node: str, fraction: float):
        """Record the externally-reserved fraction of a node's path."""
        prev = self.occupancy.get(node)
        self.occupancy[node] = (fraction if prev is None
                                else self.alpha * fraction + (1 - self.alpha) * prev)

    def observe_ledger(self, node: str, ledger, path: str,
                       direction: str = "out") -> float:
        """Sample a node's path occupancy from a live BudgetLedger —
        call *before* the node's own flow joins the path, so the
        reading is what everyone else holds."""
        cap = ledger.fabric.direction_capacity(path, direction)
        frac = ledger.reserved(path, direction) / cap if cap > 0 else 0.0
        self.observe_occupancy(node, frac)
        return frac

    def occupied(self) -> List[str]:
        """Nodes whose host-direction occupancy EMA exceeds the cutoff."""
        return [n for n, v in self.occupancy.items()
                if v > self.occupancy_threshold]

    def stragglers(self) -> List[str]:
        """Union of time-lagging nodes and occupancy-flagged nodes."""
        flagged = set(self.occupied())
        if len(self.ema) >= 2:
            med = float(np.median(list(self.ema.values())))
            flagged |= {n for n, v in self.ema.items()
                        if v > self.threshold * med}
        return sorted(flagged)

    def rebalanced_shares(self, total_microbatches: int,
                          nodes: Optional[List[str]] = None) -> Dict[str, int]:
        """Give each node work inversely proportional to its step time —
        the skew-taming advice (#1) applied to compute instead of memory.
        ``nodes`` restricts the split to the named (live) nodes; dead
        nodes' stale EMA entries must not absorb shares."""
        ema = self.ema if nodes is None \
            else {n: self.ema[n] for n in nodes if n in self.ema}
        if not ema:
            return {}
        inv = {n: 1.0 / v for n, v in ema.items()}
        z = sum(inv.values())
        raw = {n: total_microbatches * w / z for n, w in inv.items()}
        shares = {n: max(1, int(round(r))) for n, r in raw.items()}
        # fix rounding drift
        drift = total_microbatches - sum(shares.values())
        order = sorted(shares, key=lambda n: -raw[n])
        i = 0
        while drift != 0 and order:
            n = order[i % len(order)]
            if drift > 0:
                shares[n] += 1; drift -= 1
            elif shares[n] > 1:
                shares[n] -= 1; drift += 1
            i += 1
        return shares

    def microbatch_shares(self, node_names: List[str],
                          per_node: int) -> tuple:
        """Per-node microbatch counts, in ``node_names`` order, for the
        *real* data path (train/train_step.py ``node_shares``): the
        rebalanced split when a straggler is flagged and every named
        node has a time signal, the equal ``per_node`` split otherwise.
        Always sums to ``per_node * len(node_names)`` — the total jax
        work per step is invariant, only its placement skews — and the
        equal fallback is exactly the uniform tuple, which is what lets
        a consumer dispatch to the unskewed (bit-identical) compute
        path when there is nothing to rebalance."""
        equal = tuple([per_node] * len(node_names))
        if per_node < 1 or len(node_names) < 2:
            return equal
        if not self.stragglers() \
                or any(n not in self.ema for n in node_names):
            return equal
        shares = self.rebalanced_shares(per_node * len(node_names),
                                        nodes=node_names)
        return tuple(shares[n] for n in node_names)
