from repro.ft.manager import FaultToleranceManager, NodeState
from repro.ft.elastic import best_mesh_for, reshard
from repro.ft.straggler import StragglerDetector
