"""SoC programs: transfer-in -> compute -> transfer-out pipelines.

An ``OffloadProgram`` is the offload tier's unit of work, run as a
tenant ``Process`` on a ``FabricRuntime``: stage the operands onto the
device (a ``Transfer`` in the shared ledger), execute the ops on the
device's roofline (a ``Compute`` reservation, fair-shared and
QoS-weighted like any flow), and stage results back. Because all three
stages live in one ledger, an offload program *contends honestly*: its
staging bytes fight the gradient traffic for the PCIe group and its
ops fight other programs for the device — nothing is a free lunch.

``OffloadStats`` is the host-cycles-saved / offload-hit accounting in
the idiom of SNIPPETS.md's smartnic_offload.py — since PR 10 backed by
an ``obs.metrics.MetricsRegistry`` (one ``Counter`` per field) with the
same public surface: a ``counters`` dict view plus a
``get_performance_stats()`` snapshot with the derived ratios.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

from repro.core.fabric import IN, OUT
from repro.core.runtime import FabricRuntime, Process
from repro.obs.metrics import MetricsRegistry

#: default QoS tag for offload-tier traffic (tenancy/qos registers it)
OFFLOAD = "offload"


class OffloadStats:
    """Offload accounting (smartnic_offload.py idiom): what ran on the
    SoC, and what the host therefore did not have to do.

    ``cpu_cycles_saved`` counts host ops avoided 1:1 with the ops
    executed off-host (byte-granular work: one op per byte, so this is
    also "host bytes not touched"); ``packets_offloaded`` counts results
    filtered out on the SoC that never crossed the host wire.

    The fields live as ``Counter`` metrics in a ``MetricsRegistry``
    (pass one to share a registry across consumers); ``counters``
    remains the dict-shaped snapshot the pre-obs implementation
    exposed."""

    _FIELDS = ("cpu_cycles_saved", "compression_operations_offloaded",
               "compression_bytes_in", "compression_bytes_out",
               "packets_offloaded", "packets_total", "programs_run",
               "ops_executed")

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.metrics = registry if registry is not None else MetricsRegistry()
        for name in self._FIELDS:
            self.metrics.counter(name)
        # cycles/ops accumulate fractional op counts; start them float
        self.metrics.counter("cpu_cycles_saved").value = 0.0
        self.metrics.counter("ops_executed").value = 0.0

    @property
    def counters(self) -> Dict[str, float]:
        return {name: self.metrics.counter(name).value
                for name in self._FIELDS}

    # -- recording ------------------------------------------------------
    def record_program(self, ops: float) -> None:
        self.metrics.counter("programs_run").inc(1)
        self.metrics.counter("ops_executed").inc(ops)

    def record_compression(self, bytes_in: int, bytes_out: int, *,
                           ops: Optional[float] = None,
                           offloaded: bool = True) -> None:
        """One codec run. ``offloaded=False`` records a host-side run
        for the comparison denominators without crediting savings."""
        self.metrics.counter("compression_bytes_in").inc(bytes_in)
        self.metrics.counter("compression_bytes_out").inc(bytes_out)
        if offloaded:
            self.metrics.counter("compression_operations_offloaded").inc(1)
            self.metrics.counter("cpu_cycles_saved").inc(
                ops if ops is not None else float(bytes_in))

    def record_filter(self, scanned: int, matched: int, *,
                      ops: Optional[float] = None) -> None:
        """One SoC-side filter pass: ``scanned`` candidates examined on
        the SoC, ``matched`` survivors forwarded to the host — the
        difference never crossed the wire."""
        self.metrics.counter("packets_total").inc(scanned)
        self.metrics.counter("packets_offloaded").inc(scanned - matched)
        self.metrics.counter("cpu_cycles_saved").inc(
            ops if ops is not None else float(scanned))

    # -- reporting ------------------------------------------------------
    def get_performance_stats(self) -> Dict[str, float]:
        c = dict(self.counters)
        c["compression_ratio"] = (
            c["compression_bytes_out"] / c["compression_bytes_in"]
            if c["compression_bytes_in"] else 0.0)
        c["offload_hit_rate"] = (
            c["packets_offloaded"] / c["packets_total"]
            if c["packets_total"] else 0.0)
        return c

    def __repr__(self) -> str:
        s = self.get_performance_stats()
        return (f"OffloadStats(cycles_saved={s['cpu_cycles_saved']:.3g}, "
                f"compressions={s['compression_operations_offloaded']}, "
                f"hit_rate={s['offload_hit_rate']:.2f})")


class OffloadProgram:
    """One transfer-in -> compute -> transfer-out pipeline template.

    ``launch`` spawns the pipeline as a Process; every stage carries the
    program's tenant tag, so a QoS policy weighs offload traffic
    against the serve/train tenants it shares paths and devices with.
    Stages with zero amount are skipped (a filter program that reads
    device-resident data has no transfer-in)."""

    def __init__(self, runtime: FabricRuntime, name: str, *,
                 tenant: Optional[str] = OFFLOAD,
                 stats: Optional[OffloadStats] = None):
        self.runtime = runtime
        self.name = name
        self.tenant = tenant
        self.stats = stats if stats is not None else OffloadStats()

    def launch(self, *, compute: str, ops: float,
               in_path: Optional[str] = None, in_bytes: float = 0.0,
               out_path: Optional[str] = None, out_bytes: float = 0.0,
               in_direction: str = OUT, out_direction: str = IN,
               max_rate: float = math.inf, flow: Optional[str] = None,
               on_done: Optional[Callable[[Process], None]] = None,
               ) -> Process:
        """Run one pipeline instance. Returns its Process (yieldable;
        ``result`` is the simulated completion time)."""
        flow = flow if flow is not None else self.name
        proc = self.runtime.process(
            self._body(compute, ops, in_path, in_bytes, out_path, out_bytes,
                       in_direction, out_direction, max_rate, flow),
            name=f"offload:{self.name}")
        if on_done is not None:
            proc._waiters.append(lambda _res: on_done(proc))
        return proc

    def _body(self, compute, ops, in_path, in_bytes, out_path, out_bytes,
              in_direction, out_direction, max_rate, flow):
        rt = self.runtime
        span = rt.tracer.begin_phase(f"offload:{self.name}",
                                     tenant=self.tenant, flow=flow,
                                     compute=compute, ops=ops) \
            if rt._trace else None
        if in_path is not None and in_bytes > 0:
            yield rt.transfer(in_path, in_bytes, direction=in_direction,
                              flow=f"{flow}:in", tenant=self.tenant)
        if ops > 0:
            yield rt.compute(compute, ops, flow=f"{flow}:ops",
                             max_rate=max_rate, tenant=self.tenant)
        if out_path is not None and out_bytes > 0:
            yield rt.transfer(out_path, out_bytes, direction=out_direction,
                              flow=f"{flow}:out", tenant=self.tenant)
        self.stats.record_program(ops)
        if span is not None:
            rt.tracer.end_phase(span)
        return rt.clock.now
