"""DrTM-KV-style get/put filtering on the SoC path (paper §5.2).

A filtered scan asks "of these candidate keys, which values satisfy a
predicate?". Placed on the host path, every candidate value crosses the
host wire and the client discards the misses. Placed on the SoC, the
wimpy ARM cores run the predicate next to the data and only the
*matches* cross (via the ③* DMA path) — the classic offload trade:
slower cores, radically fewer bytes on the contended wire.

The data plane is real (numpy predicate over the DisaggKV value store,
bit-identical results for either placement); the performance plane is
the same calibrated kv_fabric the §5.2 alternatives use, optionally
against a live ``BudgetLedger`` — which is where the win comes from:
idle, the host path's 100 Mop/s beats the SoC's 25 Mop/s cores; once a
serving tenant holds the host path, the SoC placement keeps its rate
and wins (benchmarks/bench_offload.py sweeps exactly this flip).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.fabric import Alternative, BudgetLedger, Fabric, OPS_PER_S, Use
from repro.offload.program import OffloadStats

HOST_FILTER, SOC_FILTER = "host-filter", "soc-filter"


def kv_filter_alternatives(costs=None, selectivity: float = 0.1,
                           ) -> Dict[str, Alternative]:
    """The two filter placements as §4.2 alternatives over kv_fabric(),
    per scanned key: host-filter READs every candidate value over the
    host path; soc-filter spends one SoC-core op per candidate and only
    ``selectivity`` of them cross the ③* DMA path."""
    from repro.serve.disagg import PathCosts
    c = costs if costs is not None else PathCosts()
    if not 0.0 <= selectivity <= 1.0:
        raise ValueError(f"selectivity must be in [0, 1], got {selectivity}")
    ops = OPS_PER_S
    return {
        HOST_FILTER: Alternative(HOST_FILTER, uses=[
            Use("host_read", out=1.0, units=ops),
            Use("nic_cores", out=1.0, units=ops)],
            criteria={"latency_us": c.read_host_us}),
        SOC_FILTER: Alternative(SOC_FILTER, uses=[
            Use("soc_cpu", out=1.0, units=ops),
            Use("dma", out=selectivity, units=ops),
            Use("nic_cores", out=selectivity, units=ops)],
            criteria={"latency_us": c.send_soc_us + c.dma_soc_host_us}),
    }


@dataclasses.dataclass(frozen=True)
class FilterPlan:
    """Where the filter should run, per live occupancy."""
    location: str                   # "soc-filter" | "host-filter"
    rate: float                     # predicted scans/s of the choice
    host_rate: float                # host placement's rate (baseline)
    soc_rate: float                 # SoC placement's rate
    selectivity: float


def plan_filter_placement(fabric: Fabric, *, selectivity: float = 0.1,
                          costs=None,
                          ledger: Optional[BudgetLedger] = None) -> FilterPlan:
    """Route both placements over ``fabric`` (against the ledger's live
    budgets when given) and pick the faster — the same decision shape as
    serve/disagg.plan_decode_placement. Ties prefer the host (no
    dispatch to a remote complex for nothing)."""
    alts = kv_filter_alternatives(costs, selectivity)
    for alt in alts.values():
        fabric.validate(alt)
    host = alts[HOST_FILTER].solo_rate(fabric, ledger=ledger)
    soc = alts[SOC_FILTER].solo_rate(fabric, ledger=ledger)
    loc = SOC_FILTER if soc > host else HOST_FILTER
    return FilterPlan(loc, max(soc, host), host, soc, selectivity)


@dataclasses.dataclass(frozen=True)
class FilterScan:
    """One executed scan: real results + modeled cost."""
    keys: np.ndarray                # matching keys
    values: np.ndarray              # their values (n_matched, value_bytes)
    where: str                      # placement that ran
    scanned: int
    matched: int
    seconds: float                  # modeled wall time of the scan


class KVFilter:
    """Filtered scans over a ``DisaggKV``, placement-aware.

    ``predicate`` is vectorized: ``(n, value_bytes) uint8 -> (n,) bool``.
    Both placements run the *same* predicate over the *same* value
    store, so results are bit-identical; only the modeled seconds and
    the ``OffloadStats`` accounting differ (SoC placement credits the
    misses as packets that never crossed the wire)."""

    def __init__(self, kv, *, stats: Optional[OffloadStats] = None):
        self.kv = kv
        self.stats = stats if stats is not None else OffloadStats()
        self._fabric = kv.fabric()

    def _rate(self, resource: str, ledger: Optional[BudgetLedger]) -> float:
        if ledger is not None:
            return max(ledger.available(resource, "out", joining="kvfilter"),
                       1e-30)
        return self._fabric[resource].capacity

    def scan(self, keys: np.ndarray,
             predicate: Callable[[np.ndarray], np.ndarray], *,
             where: str = SOC_FILTER,
             ledger: Optional[BudgetLedger] = None) -> FilterScan:
        if where not in (HOST_FILTER, SOC_FILTER):
            raise ValueError(f"where must be {HOST_FILTER!r} or "
                             f"{SOC_FILTER!r}, got {where!r}")
        keys = np.asarray(keys)
        addrs = np.fromiter((self.kv._index_lookup(int(k))[0] for k in keys),
                            dtype=np.int64, count=len(keys))
        values = self.kv.values[addrs]
        mask = np.asarray(predicate(values), dtype=bool)
        n, m = int(len(keys)), int(mask.sum())
        if where == SOC_FILTER:
            secs = n / self._rate("soc_cpu", ledger) \
                + m / self._rate("dma", ledger)
            self.stats.record_filter(n, m, ops=float(n))
        else:
            secs = n / self._rate("host_read", ledger)
        return FilterScan(keys[mask], values[mask], where, n, m, secs)

    def plan(self, *, selectivity: float = 0.1,
             ledger: Optional[BudgetLedger] = None) -> FilterPlan:
        return plan_filter_placement(self._fabric, selectivity=selectivity,
                                     costs=self.kv.c, ledger=ledger)
