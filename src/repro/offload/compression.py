"""Checkpoint-compression offload (the LineFS §5.1 workload, computed).

Two halves, deliberately separated:

*The bytes are real.* ``SoCCompressor`` is a ``save_checkpoint``
``compressor=`` hook that runs the *canonical* codec from
core/compression.py (the same table ckpt/checkpoint.py uses), so a
checkpoint "compressed on the SoC" is bit-identical to one compressed
on the host — placement moves cycles, never bytes (asserted in
tests/test_offload.py). What changes is the accounting: every run is
recorded as host cycles saved in ``OffloadStats``.

*The cycles are simulated.* ``compression_program`` runs the same save
as a FabricRuntime pipeline: stage the raw shard toward the device,
spend ``bytes x CODEC_OPS_PER_BYTE`` ops on the device's roofline,
stage the compressed bytes out. train/cluster.py's soc-compress /
host-compress staging modes inline this shape into the step loop (with
pause-safe re-issue), which is what makes the host-vs-SoC crossover
*emerge* from scheduling: under host-side load the compressed-bytes
win on the loaded wire beats the DCA's slower codec; idle, the host's
fat cores win outright.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from repro.core.compression import byte_codec, default_codec
from repro.core.fabric import OUT
from repro.core.runtime import FabricRuntime, Process
from repro.offload.device import BF2_ARM, DeviceSpec
from repro.offload.program import OFFLOAD, OffloadProgram, OffloadStats

#: modeled codec cost in ops per input byte (1 op == 1 byte through the
#: codec at the device's roofline; zlib is the slower, denser codec)
CODEC_OPS_PER_BYTE: Dict[str, float] = {"zstd": 1.0, "zlib": 2.5, "none": 0.0}

#: modeled compressed fraction for mixed fp32/int8 training state — the
#: wire sees this many bytes per raw byte after a compress-then-stage
CKPT_RATIO = 0.5


def codec_ops(nbytes: float, codec: Optional[str] = None) -> float:
    """Ops to push ``nbytes`` through ``codec`` (default: the codec a
    compressing save would pick)."""
    codec = codec if codec is not None else default_codec(True)
    return nbytes * CODEC_OPS_PER_BYTE.get(codec, 1.0)


class SoCCompressor:
    """``save_checkpoint(compressor=...)`` hook: same codec, same bytes,
    SoC-side accounting.

    The host-side twin is ``host_compressor(stats)`` — it runs the
    identical codec and records the run with ``offloaded=False``, so a
    bench comparing placements has both denominators."""

    def __init__(self, *, device: DeviceSpec = BF2_ARM,
                 stats: Optional[OffloadStats] = None):
        self.device = device
        self.stats = stats if stats is not None else OffloadStats()

    def __call__(self, codec: str, raw: bytes) -> bytes:
        _ext, comp, _decomp = byte_codec(codec)
        payload = comp(raw)
        self.stats.record_compression(len(raw), len(payload),
                                      ops=codec_ops(len(raw), codec))
        return payload


def host_compressor(stats: OffloadStats):
    """The host-placement twin of ``SoCCompressor``: identical codec and
    bytes, recorded without crediting offload savings."""
    def run(codec: str, raw: bytes) -> bytes:
        _ext, comp, _decomp = byte_codec(codec)
        payload = comp(raw)
        stats.record_compression(len(raw), len(payload), offloaded=False)
        return payload
    return run


def compression_program(runtime: FabricRuntime, *, nbytes: float,
                        compute: str, stage_path: str,
                        ratio: float = CKPT_RATIO,
                        codec: Optional[str] = None,
                        tenant: Optional[str] = OFFLOAD,
                        stats: Optional[OffloadStats] = None,
                        flow: str = "ckpt-compress") -> Process:
    """One compress-then-stage checkpoint save as a runtime pipeline:
    ``nbytes`` through the codec on ``compute``, then ``ratio * nbytes``
    over ``stage_path`` (compress where the cycles live, stage the
    compressed bytes over that side's wire). Returns the Process."""
    stats = stats if stats is not None else OffloadStats()
    prog = OffloadProgram(runtime, flow, tenant=tenant, stats=stats)
    stats.record_compression(int(nbytes), int(ratio * nbytes),
                             ops=codec_ops(nbytes, codec))
    return prog.launch(compute=compute, ops=codec_ops(nbytes, codec),
                       out_path=stage_path, out_bytes=ratio * nbytes,
                       out_direction=OUT, flow=flow)
