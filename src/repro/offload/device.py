"""Per-device compute rooflines for the offload tier.

The paper's premise is an off-path SoC that *computes*; this module is
where each computing device's envelope lives, calibrated against
"Performance Characteristics of the BlueField-2 SmartNIC" (PAPERS.md):
the BF-2's 8 ARM A72 cores are "wimpy" — a fraction of a host socket on
throughput work — and its single-channel DDR4 feeds them ~19 GB/s, so
byte-granular work (compression, filtering) is memory-shaped long
before it is core-shaped. "Demystifying Datapath Accelerator Enhanced
Off-path SmartNIC" (PAPERS.md) adds the third device class: a DCA-style
fixed-function engine with far higher streaming throughput than the
ARM complex but a real per-dispatch cost.

A ``DeviceSpec`` turns into a fabric ``Path`` (fabric.compute_path /
dca_path) whose capacity is the classic roofline
``min(peak_ops, intensity * mem_bw)`` at the workload's operational
intensity — for the byte-granular offload workloads in this repo one
op is one byte processed, so intensity defaults to 1 op/byte. Once the
device is a Path, ``FabricRuntime.compute`` reservations fair-share it
exactly like a wire: occupancy, QoS weights, the §4.1 discount on a
``shared_group``, and ledger conservation all come for free.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.fabric import COMPUTE, DCA, Path, compute_path, dca_path


@dataclass(frozen=True)
class DeviceSpec:
    """One computing device's roofline envelope.

    ``ops_per_core`` is sustained ops/s per core on the offload
    workloads (byte-granular: 1 op == 1 byte through a codec or
    predicate), ``mem_bw`` the memory system feeding the cores — the
    BF-2 lesson is that the second number binds first on the SoC."""
    name: str
    cores: int
    ops_per_core: float
    mem_bw: float
    dispatch_latency: float = 0.0      # doorbell/IPI cost per program
    kind: str = COMPUTE

    def __post_init__(self):
        if self.cores < 1 or self.ops_per_core <= 0 or self.mem_bw <= 0:
            raise ValueError(f"device {self.name}: non-positive envelope")

    @property
    def peak_ops(self) -> float:
        return self.cores * self.ops_per_core

    def roofline(self, intensity: float = 1.0) -> float:
        """Attainable ops/s at ``intensity`` ops per memory byte — the
        compute ceiling or the memory ceiling, whichever binds."""
        if intensity <= 0:
            raise ValueError("operational intensity must be > 0")
        return min(self.peak_ops, intensity * self.mem_bw)

    def path(self, name: Optional[str] = None, *, intensity: float = 1.0,
             shared_group: Optional[str] = None) -> Path:
        """This device as a compute-tier fabric Path (capacity = the
        roofline at ``intensity``)."""
        rate = self.roofline(intensity)
        if self.kind == DCA:
            return dca_path(name or self.name, rate,
                            latency=self.dispatch_latency,
                            shared_group=shared_group)
        return compute_path(name or self.name, rate,
                            latency=self.dispatch_latency,
                            shared_group=shared_group, kind=self.kind)


#: BlueField-2 ARM complex: 8x A72, single-channel DDR4. Codec-grade
#: throughput ~0.4 GB/s/core — wimpy next to a host socket (§3.2).
BF2_ARM = DeviceSpec("bf2-arm", cores=8, ops_per_core=0.4e9, mem_bw=19e9,
                     dispatch_latency=2e-6)

#: DCA-style datapath accelerator on the NIC: one fixed-function engine
#: with high streaming throughput but a real per-dispatch doorbell cost
#: (the "Demystifying DCA" characterization).
BF2_DCA = DeviceSpec("bf2-dca", cores=1, ops_per_core=10e9, mem_bw=12e9,
                     dispatch_latency=5e-6, kind=DCA)

#: The host socket the offload competes with: many fat cores behind a
#: multi-channel memory system.
HOST_CPU = DeviceSpec("host-cpu", cores=32, ops_per_core=0.5e9, mem_bw=80e9,
                      dispatch_latency=1e-6)

#: canonical specs by name (benches/launchers select by string)
DEVICES = {d.name: d for d in (BF2_ARM, BF2_DCA, HOST_CPU)}


def node_compute_paths(index: int, *, host=HOST_CPU, soc=BF2_ARM,
                       dca=BF2_DCA, intensity: float = 1.0) -> list:
    """The compute tier of one trainer node, as fabric Paths:
    ``cpu:host:i`` (the host socket), ``cpu:soc:i`` (the SoC's ARM
    complex) and ``dca:i`` (the NIC's datapath accelerator). Merged into
    the node's wire paths by train/cluster.train_fabric, so staging
    bytes and codec cycles live in one ledger."""
    return [
        host.path(f"cpu:host:{index}", intensity=intensity),
        soc.path(f"cpu:soc:{index}", intensity=intensity),
        dca.path(f"dca:{index}", intensity=intensity),
    ]
