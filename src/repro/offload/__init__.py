"""The SoC compute tier (paper premise: an off-path SoC that computes).

``device``       per-device rooflines (BF-2 ARM complex, DCA engine,
                 host socket) as compute-tier fabric Paths.
``program``      transfer-in -> compute -> transfer-out pipelines as
                 tenant Processes, plus the smartnic-idiom OffloadStats.
``compression``  checkpoint-compression offload: the real codecs as an
                 SoC tenant (bit-identical bytes, relocated cycles).
``kvfilter``     DrTM-KV-style get/put filtering on the SoC path.
"""
from repro.offload.device import (BF2_ARM, BF2_DCA, DEVICES, HOST_CPU,
                                  DeviceSpec, node_compute_paths)
from repro.offload.program import OFFLOAD, OffloadProgram, OffloadStats
from repro.offload.compression import (CKPT_RATIO, CODEC_OPS_PER_BYTE,
                                       SoCCompressor, codec_ops,
                                       compression_program, host_compressor)
from repro.offload.kvfilter import (FilterPlan, FilterScan, HOST_FILTER,
                                    KVFilter, SOC_FILTER,
                                    kv_filter_alternatives,
                                    plan_filter_placement)

__all__ = [
    "BF2_ARM", "BF2_DCA", "DEVICES", "HOST_CPU", "DeviceSpec",
    "node_compute_paths",
    "OFFLOAD", "OffloadProgram", "OffloadStats",
    "CKPT_RATIO", "CODEC_OPS_PER_BYTE", "SoCCompressor", "codec_ops",
    "compression_program", "host_compressor",
    "FilterPlan", "FilterScan", "HOST_FILTER", "KVFilter", "SOC_FILTER",
    "kv_filter_alternatives", "plan_filter_placement",
]
