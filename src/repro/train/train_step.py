"""The jitted train step: forward + CE, backward, clip, AdamW.

Multi-path hooks (set via RunConfig, chosen by the planner):
- ``microbatch``: grad accumulation via lax.scan (keeps peak activation
  memory ~1/k — the memory-roofline lever);
- ``pod_sync="compressed"``: gradient sync across the pod (DCN) axis runs
  as an int8 ring inside a pod-manual shard_map — the LineFS
  "compress before the slow path" alternative. ``"auto"`` leaves the DCN
  all-reduce to XLA SPMD (paper-faithful single-path baseline);
- remat policy: none | minimal | full.

Batch sharding carries ("pod","data") on dim 0; weights carry
(fsdp="data", tensor="model"); XLA SPMD therefore emits
reduce-scatter(data) + all-reduce(pod) for gradients natively — the
hierarchical schedule of core/collectives, produced by sharding choice.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import compressed_ring_all_reduce_inner
from repro.models import model as M
from repro.optim.adamw import adamw_update
from repro.optim.schedule import lr_at

PyTree = Any


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array], *,
            impl: str = "auto", remat: str = "minimal",
            capacity_factor: float = 1.25, loss_chunk: int = 512,
            unroll: int = 1):
    res = M.forward(cfg, params, batch["tokens"],
                    batch.get("frontend_embeds"), impl=impl, remat=remat,
                    capacity_factor=capacity_factor, unroll=unroll)
    ce = M.cross_entropy(cfg, params, res.hidden, batch["labels"],
                         batch["loss_mask"], chunk=loss_chunk)
    aux_w = cfg.router_aux_loss if cfg.num_experts else 0.0
    return ce + aux_w * res.aux_loss, {"ce": ce, "aux": res.aux_loss}


def _split_microbatches(batch: Dict[str, jax.Array], k: int):
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, run: RunConfig, *,
                    impl: str = "auto",
                    mesh=None,
                    donate: bool = True,
                    unroll: int = 1,
                    capacity_factor: float = 1.25,
                    loss_chunk: int = 512):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics). jit-compiled by the caller (launch/train.py) so
    in/out shardings can be attached there."""

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, impl=impl,
                              remat=run.remat_policy,
                              capacity_factor=capacity_factor,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True)(params)
        return loss, parts, grads

    def accumulate(params, batch):
        if run.microbatch and run.microbatch > 1:
            mb = _split_microbatches(batch, run.microbatch)

            def body(carry, b1):
                loss_acc, parts_acc, g_acc = carry
                loss, parts, g = grads_of(params, b1)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                parts_acc = jax.tree.map(lambda a, b: a + b, parts_acc, parts)
                return (loss_acc + loss, parts_acc, g_acc), None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            init = (jnp.zeros(()), {"ce": jnp.zeros(()), "aux": jnp.zeros(())}, zeros_g)
            (loss, parts, grads), _ = jax.lax.scan(body, init, mb)
            k = float(run.microbatch)
            return loss / k, jax.tree.map(lambda x: x / k, parts), \
                jax.tree.map(lambda g: g / k, grads)
        return grads_of(params, batch)

    def train_step(params, opt_state, batch, step):
        if run.pod_sync == "compressed" and mesh is not None and \
                "pod" in mesh.shape and mesh.shape["pod"] > 1:
            from repro.parallel.sharding import rule_overrides
            npod = mesh.shape["pod"]

            # manual over pod: per-pod grads + int8 ring sync (DCN path).
            # The batch's pod share moves to its own leading dim so pod
            # (manual) and data (auto) never mix on one dim; inside the
            # region "batch" resolves to data only.
            def per_pod(params, batch):
                batch = jax.tree.map(lambda x: x[0], batch)
                with rule_overrides({"batch": "data", "decode_batch": "data"}):
                    loss, parts, grads = accumulate(params, batch)
                grads = jax.tree.map(
                    lambda g: compressed_ring_all_reduce_inner(
                        g.astype(jnp.float32) / npod, "pod").astype(g.dtype),
                    grads)
                loss = jax.lax.pmean(loss, "pod")
                parts = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), parts)
                return loss, parts, grads

            batch_pod = jax.tree.map(
                lambda x: x.reshape((npod, x.shape[0] // npod) + x.shape[1:]),
                batch)
            batch_spec = jax.tree.map(lambda _: P("pod"), batch)
            loss, parts, grads = shard_map(
                per_pod, mesh=mesh,
                in_specs=(P(), batch_spec), out_specs=(P(), P(), P()),
                axis_names={"pod"}, check_vma=False,
            )(params, batch_pod)
        else:
            loss, parts, grads = accumulate(params, batch)

        lr = lr_at(step, base_lr=run.learning_rate,
                   warmup_steps=run.warmup_steps, total_steps=run.total_steps)
        moments = "int8" if getattr(run, "moments_int8", False) else "f32"
        params2, opt2, om = adamw_update(
            grads, opt_state, params, lr=lr, b1=run.b1, b2=run.b2,
            eps=run.eps, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip, moments=moments)
        metrics = {"loss": loss, "lr": lr, **parts, **om}
        return params2, opt2, metrics

    return train_step
