"""The jitted train step: forward + CE, backward, clip, AdamW.

Multi-path hooks (set via RunConfig, chosen by the planner):
- ``microbatch``: grad accumulation via lax.scan (keeps peak activation
  memory ~1/k — the memory-roofline lever);
- ``pod_sync="compressed"``: gradient sync across the pod (DCN) axis runs
  as an int8 ring inside a pod-manual shard_map — the LineFS
  "compress before the slow path" alternative. ``"auto"`` leaves the DCN
  all-reduce to XLA SPMD (paper-faithful single-path baseline);
- remat policy: none | minimal | full.

Batch sharding carries ("pod","data") on dim 0; weights carry
(fsdp="data", tensor="model"); XLA SPMD therefore emits
reduce-scatter(data) + all-reduce(pod) for gradients natively — the
hierarchical schedule of core/collectives, produced by sharding choice.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import compressed_ring_all_reduce_inner
from repro.models import model as M
from repro.optim.adamw import adamw_update
from repro.optim.schedule import lr_at

PyTree = Any


def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array], *,
            impl: str = "auto", remat: str = "minimal",
            capacity_factor: float = 1.25, loss_chunk: int = 512,
            unroll: int = 1):
    res = M.forward(cfg, params, batch["tokens"],
                    batch.get("frontend_embeds"), impl=impl, remat=remat,
                    capacity_factor=capacity_factor, unroll=unroll)
    ce = M.cross_entropy(cfg, params, res.hidden, batch["labels"],
                         batch["loss_mask"], chunk=loss_chunk)
    aux_w = cfg.router_aux_loss if cfg.num_experts else 0.0
    return ce + aux_w * res.aux_loss, {"ce": ce, "aux": res.aux_loss}


def _split_microbatches(batch: Dict[str, jax.Array], k: int):
    def split(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])
    return jax.tree.map(split, batch)


def split_by_shares(batch: Dict[str, jax.Array], shares) -> list:
    """Split a global batch into contiguous per-node sub-batches of
    ``shares[j]`` microbatches each (``sum(shares)`` microbatches
    total, so the microbatch size is ``B // sum(shares)``). This is the
    skew-aware batch assembly: a straggling node's share shrinks and
    its sub-batch — hence its actual jax work — shrinks with it, while
    the union of the sub-batches is exactly the original batch."""
    shares = tuple(int(s) for s in shares)
    if any(s < 1 for s in shares):
        raise ValueError(f"every share must be >= 1, got {shares}")
    m = sum(shares)
    sizes = {x.shape[0] for x in jax.tree.leaves(batch)}
    if len(sizes) != 1:
        raise ValueError(f"batch dim 0 must agree across leaves: {sizes}")
    b = sizes.pop()
    if b % m:
        raise ValueError(f"batch of {b} does not split into {m} "
                         f"microbatches (shares {shares})")
    mb = b // m
    subs, off = [], 0
    for s in shares:
        lo, hi = off * mb, (off + s) * mb
        subs.append(jax.tree.map(lambda x: x[lo:hi], batch))
        off += s
    return subs


def make_train_step(cfg: ModelConfig, run: RunConfig, *,
                    impl: str = "auto",
                    mesh=None,
                    donate: bool = True,
                    unroll: int = 1,
                    capacity_factor: float = 1.25,
                    loss_chunk: int = 512):
    """Returns train_step(params, opt_state, batch, step,
    node_shares=None) -> (params, opt_state, metrics). jit-compiled by
    the caller (launch/train.py) so in/out shardings can be attached
    there. ``node_shares`` (optional, a tuple of per-node microbatch
    counts — the straggler loop's rebalanced split routed into real
    data) must be **static** under jit: pass
    ``static_argnames=("node_shares",)``. Equal shares dispatch to the
    unchanged plain path, so they are bit-identical to passing no
    shares; skewed shares change each node's actual jax work (sub-batch
    shapes, scan lengths) while preserving the same global mean."""

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, impl=impl,
                              remat=run.remat_policy,
                              capacity_factor=capacity_factor,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True)(params)
        return loss, parts, grads

    def scan_sum(params, batch, k):
        """Sum (not mean) of loss/parts/f32-grads over ``k`` microbatches."""
        mb = _split_microbatches(batch, k)

        def body(carry, b1):
            loss_acc, parts_acc, g_acc = carry
            loss, parts, g = grads_of(params, b1)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            parts_acc = jax.tree.map(lambda a, b: a + b, parts_acc, parts)
            return (loss_acc + loss, parts_acc, g_acc), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        init = (jnp.zeros(()), {"ce": jnp.zeros(()), "aux": jnp.zeros(())}, zeros_g)
        (loss, parts, grads), _ = jax.lax.scan(body, init, mb)
        return loss, parts, grads

    def _mean(loss, parts, grads, k):
        k = float(k)
        return loss / k, jax.tree.map(lambda x: x / k, parts), \
            jax.tree.map(lambda g: g / k, grads)

    def accumulate(params, batch, node_shares=None):
        # skew-aware batching: ``node_shares`` are per-node microbatch
        # counts (static python ints — the straggler loop's
        # rebalanced_shares routed into real data). A *skewed* split
        # runs each node's contiguous sub-batch through its own
        # accumulation scan — per-node jax work (shapes, scan lengths)
        # actually changes — and combines the sums into the same global
        # mean. An *equal* split falls through to the uniform scan so
        # the computation is literally the plain-microbatch one: losses
        # stay bit-identical when there is nothing to rebalance.
        if node_shares is not None and len(node_shares) > 1 \
                and len(set(node_shares)) > 1:
            m = sum(node_shares)
            tot = None
            for s, sub in zip(node_shares, split_by_shares(batch, node_shares)):
                r = scan_sum(params, sub, s)
                tot = r if tot is None else (
                    tot[0] + r[0],
                    jax.tree.map(lambda a, b: a + b, tot[1], r[1]),
                    jax.tree.map(lambda a, b: a + b, tot[2], r[2]))
            return _mean(*tot, m)
        # equal (or absent) shares: literally the plain path — nothing
        # to rebalance, so the computation must be the unchanged one
        k = run.microbatch or 1
        if k > 1:
            return _mean(*scan_sum(params, batch, k), k)
        return grads_of(params, batch)

    def train_step(params, opt_state, batch, step, node_shares=None):
        if run.pod_sync == "compressed" and mesh is not None and \
                "pod" in mesh.shape and mesh.shape["pod"] > 1:
            from repro.parallel.sharding import rule_overrides
            npod = mesh.shape["pod"]

            # manual over pod: per-pod grads + int8 ring sync (DCN path).
            # The batch's pod share moves to its own leading dim so pod
            # (manual) and data (auto) never mix on one dim; inside the
            # region "batch" resolves to data only.
            def per_pod(params, batch):
                batch = jax.tree.map(lambda x: x[0], batch)
                with rule_overrides({"batch": "data", "decode_batch": "data"}):
                    loss, parts, grads = accumulate(params, batch,
                                                    node_shares=node_shares)
                grads = jax.tree.map(
                    lambda g: compressed_ring_all_reduce_inner(
                        g.astype(jnp.float32) / npod, "pod").astype(g.dtype),
                    grads)
                loss = jax.lax.pmean(loss, "pod")
                parts = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), parts)
                return loss, parts, grads

            batch_pod = jax.tree.map(
                lambda x: x.reshape((npod, x.shape[0] // npod) + x.shape[1:]),
                batch)
            batch_spec = jax.tree.map(lambda _: P("pod"), batch)
            loss, parts, grads = shard_map(
                per_pod, mesh=mesh,
                in_specs=(P(), batch_spec), out_specs=(P(), P(), P()),
                axis_names={"pod"}, check_vma=False,
            )(params, batch_pod)
        else:
            loss, parts, grads = accumulate(params, batch,
                                            node_shares=node_shares)

        lr = lr_at(step, base_lr=run.learning_rate,
                   warmup_steps=run.warmup_steps, total_steps=run.total_steps)
        moments = "int8" if getattr(run, "moments_int8", False) else "f32"
        params2, opt2, om = adamw_update(
            grads, opt_state, params, lr=lr, b1=run.b1, b2=run.b2,
            eps=run.eps, weight_decay=run.weight_decay,
            grad_clip=run.grad_clip, moments=moments)
        metrics = {"loss": loss, "lr": lr, **parts, **om}
        return params2, opt2, metrics

    return train_step
