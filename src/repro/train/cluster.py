"""Simulated training cluster on the event-driven FabricRuntime.

``TrainCluster`` runs N trainer nodes as runtime ``Process``es. Each
global step is, per node:

  compute phase       a simulated delay (roofline estimate, scaled by
                      the node's inherent speed and its mitigation-
                      adjusted work share);
  gradient allreduce  concurrent Transfers on the node's host<->client
                      path (device->host OUT, host->device IN) plus a
                      ring exchange on the shared ``net`` path, closed
                      by a ``runtime.barrier()`` — the data-parallel
                      synchronization point. With
                      ``ClusterTimeModel.buckets = K > 1`` the gradient
                      is split into K per-layer-group buckets
                      (``bucket_plan``) and each bucket's allreduce is
                      issued *as soon as its slice of backward
                      completes* — classic bucketed-DDP overlap: late
                      buckets compute while early buckets communicate,
                      each bucket closed by its own cyclic barrier, and
                      the overlap win (or its absence on an idle-fast
                      network) emerges from the ledger's scheduling,
                      never from a constant;
  checkpoint staging  on checkpoint steps, the node's checkpoint shard
                      is staged over its SoC *or* host path *in the
                      same ledger* as the gradient traffic, so
                      checkpoint-vs-gradient contention and the §6.1
                      host-load crossover (offload wins when the host
                      direction is busy, loses when it is idle) emerge
                      from scheduling instead of constants.

The numeric side is optional and exact: when ``step_fn``/``params`` are
given, the barrier release runs one *real* update per global step (data
parallelism replicates state, so one numeric stream is the truth for
every node) and ``CheckpointManager`` persists real bytes — which is
what makes the post-failure loss curve bit-identical to an
uninterrupted run. Without a ``step_fn`` the cluster is a timing-only
dry run (``launch/train.py --simulate``).

Fault tolerance is event-driven end to end: every node heartbeats via a
periodic runtime process into a ``FaultToleranceManager`` attached to
the same runtime; a silent node's watchdog fires a failure Signal in
simulated time; the cluster then kills the survivor processes
(cancelling their in-flight transfers — the ledger conserves), picks a
survivor mesh with ``ft.elastic.best_mesh_for``, restores the newest
committed checkpoint, and resumes the step loop with the smaller
membership — fail -> detect -> resize -> resume, all on the SimClock.

Tenancy (PR 5): the cluster can run as the *throughput tenant* of a
shared runtime — every transfer carries ``tenant=`` for the QoS
weighted fair-share, ``begin``/``done``/``finish`` let a harness
(tenancy/colocation.py) drive the clock, and
``pause_transfers``/``resume_transfers`` implement admission-control
deferral: in-flight allreduce/checkpoint transfers are canceled (their
reservations return to the ledger), node processes park on a resume
signal, and the canceled remainders are re-issued — deferral, never
loss. ``ckpt_path="auto"`` additionally picks each save's staging path
from live ledger occupancy (CheckpointManager.choose_staging) instead
of a startup constant.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import hw
from repro.core.fabric import Fabric, FabricError, OUT, IN, Path
from repro.core.runtime import Barrier, FabricRuntime, Process, Transfer
from repro.ckpt.checkpoint import CheckpointManager, StagingOption
from repro.ft.elastic import best_mesh_for
from repro.ft.manager import FaultToleranceManager
from repro.ft.straggler import StragglerDetector
from repro.obs.trace import PHASE, Span, Tracer
from repro.offload.compression import CKPT_RATIO
from repro.offload.device import node_compute_paths
from repro.offload.program import OffloadStats

SOC, HOST = "soc", "host"
AUTO = "auto"     # ckpt staging: pick per save from live ledger occupancy
#: compress-then-stage modes (offload tier): run the codec where the
#: cycles live — the NIC's DCA engine or the host socket — then stage
#: only the compressed bytes over that side's wire
SOC_COMPRESS, HOST_COMPRESS = "soc-compress", "host-compress"
_COMPRESS_MODES = (SOC_COMPRESS, HOST_COMPRESS)
_CKPT_MODES = (SOC, HOST, AUTO) + _COMPRESS_MODES


def train_fabric(nodes: int, *, host_bw: float = hw.PCIE_BW,
                 soc_frac: float = 0.7,
                 net_bw_per_node: float = hw.DCN_BW_PER_CHIP,
                 concurrency_discount: float = 0.1,
                 compute_tier: bool = True) -> Fabric:
    """The cluster fabric: per node a ``host:i`` path (the direct PCIe
    host path, the paper's P) and a weaker ``soc:i`` offload path (the
    SoC DMA engine, §3.3's ~0.7 P) sharing one interference group, plus
    one switch-aggregated ``net`` path all ring traffic crosses.

    With ``compute_tier`` (default), each node also carries its compute
    resources as ops/s paths — ``cpu:host:i``, ``cpu:soc:i`` and
    ``dca:i`` (offload/device rooflines) — so codec cycles and staging
    bytes are budgeted in one ledger and the host-vs-SoC compression
    crossover can emerge from scheduling."""
    paths = []
    for i in range(nodes):
        paths.append(Path(f"host:{i}", host_bw, latency=hw.PCIE_LAT,
                          kind="pcie", shared_group=f"pcie:{i}"))
        paths.append(Path(f"soc:{i}", soc_frac * host_bw, latency=hw.PCIE_LAT,
                          kind="pcie", shared_group=f"pcie:{i}"))
        if compute_tier:
            paths.extend(node_compute_paths(i))
    paths.append(Path("net", net_bw_per_node * nodes, latency=hw.DCN_LAT,
                      kind="dcn", shared_group="net"))
    return Fabric(paths, concurrency_discount=concurrency_discount)


#: named fabrics for ``launch/train.py --simulate`` (and benches): the
#: v5e-flavored default, a weaker SoC DMA engine, a fatter network, and
#: the LineFS §5.1 testbed bandwidths (200 Gb net / 256 Gb internal).
TRAIN_FABRICS: Dict[str, Callable[[int], Fabric]] = {
    "v5e": lambda n: train_fabric(n),
    "weak-soc": lambda n: train_fabric(n, soc_frac=0.4),
    "fast-net": lambda n: train_fabric(
        n, net_bw_per_node=4 * hw.DCN_BW_PER_CHIP),
    "linefs": lambda n: train_fabric(
        n, host_bw=256e9 / 8, net_bw_per_node=200e9 / 8),
}


@dataclass(frozen=True)
class BucketSlice:
    """One layer-group's slice of the per-step cost: the compute time
    of its backward segment and the gradient bytes it produces."""
    compute_s: float
    grad_bytes: float


def _exact_split(total: float, weights: List[float],
                 total_w: float) -> List[float]:
    """Split ``total`` into ``len(weights)`` non-negative float parts,
    proportional to ``weights``, whose left-to-right float sum is
    *exactly* ``total``: the split is taken on the integer grid of
    ``total``'s 53-bit significand, so every partial sum is an integer
    multiple of one scale below 2**53 — exactly representable, hence
    summation never rounds. Bucketing changes *when* cost is paid,
    never how much."""
    k = len(weights)
    if total == 0.0:
        return [0.0] * k
    m, e = math.frexp(total)
    scale = math.ldexp(1.0, e - 53)
    units = int(math.ldexp(m, 53))        # total == units * scale, exact
    parts: List[float] = []
    acc, cum = 0, 0.0
    for w in weights[:-1]:
        cum += w
        edge = int(round(units * (cum / total_w)))
        edge = min(max(edge, acc), units)
        parts.append((edge - acc) * scale)
        acc = edge
    parts.append((units - acc) * scale)
    return parts


def layer_group_weights(cfg, k: int) -> List[float]:
    """Per-bucket gradient-size weights from the *real* parameter tree:
    the model's tensors (configs.base._param_tree_sizes) are grouped
    into ``k`` contiguous layer groups — layer ``i`` lands in group
    ``i * k // num_layers`` — with the embedding riding the first group
    and the head/final norm the last (they produce their gradients at
    the edges of backward). The weights are plain parameter counts, so
    a ``bucket_plan(weights=...)`` split reflects where the bytes
    actually are: an embedding-heavy small model front-loads bucket 0,
    a deep uniform model degenerates to the uniform split."""
    from repro.configs.base import _param_tree_sizes
    num_layers = cfg.num_layers
    if not 1 <= k <= num_layers:
        raise ValueError(f"need 1 <= buckets <= num_layers ({num_layers}), "
                         f"got {k}")
    weights = [0.0] * k
    for name, size in _param_tree_sizes(cfg).items():
        if name.startswith("layer"):
            layer = int(name.split(".", 1)[0][len("layer"):])
            group = layer * k // num_layers
        elif name == "embed.table":
            group = 0
        else:                       # lm_head, final_norm, ...
            group = k - 1
        weights[group] += float(size)
    return weights


@dataclass(frozen=True)
class ClusterTimeModel:
    """Per-step cost model for one simulated node."""
    compute_s: float                 # roofline compute time per step
    grad_bytes: float                # gradient bytes staged host<->device
    ckpt_bytes: float = 0.0          # per-node checkpoint shard bytes
    ckpt_path: str = SOC             # staging mode, one of _CKPT_MODES
    tokens_per_step: int = 0         # global tokens, for tokens/s
    ckpt_ratio: float = CKPT_RATIO   # compressed fraction (compress modes)
    ckpt_codec_ops: float = 1.0      # modeled codec ops per raw byte —
    #                                  fixed here so the simulation does
    #                                  not depend on which codec wheel
    #                                  happens to be installed
    chunk_bytes: Optional[float] = None   # split tenant transfers into
    #                                  chunks of at most this size (the
    #                                  simulate_replication pipeline idea
    #                                  on the step path): an admission
    #                                  pause then takes effect at the
    #                                  next chunk boundary without
    #                                  cancel/re-issue (drain mode)
    buckets: int = 1                 # per-layer-group gradient buckets:
    #                                  K > 1 issues each bucket's
    #                                  allreduce as soon as its slice of
    #                                  backward completes (classic DDP
    #                                  overlap); 1 = single-shot
    bucket_weights: Optional[Tuple[float, ...]] = None
    #                                  per-bucket cost weights (one per
    #                                  bucket, e.g. layer_group_weights
    #                                  from the real param tree); None =
    #                                  uniform

    def __post_init__(self):
        if self.ckpt_path not in _CKPT_MODES:
            raise ValueError(f"ckpt_path must be one of {_CKPT_MODES}, "
                             f"got {self.ckpt_path!r}")
        if not 0.0 < self.ckpt_ratio <= 1.0:
            raise ValueError(f"ckpt_ratio must be in (0, 1], "
                             f"got {self.ckpt_ratio}")
        if self.ckpt_codec_ops < 0:
            raise ValueError(f"ckpt_codec_ops must be >= 0, "
                             f"got {self.ckpt_codec_ops}")
        if self.chunk_bytes is not None and not self.chunk_bytes > 0:
            raise ValueError(f"chunk_bytes must be > 0, "
                             f"got {self.chunk_bytes}")
        if self.buckets < 1 or self.buckets != int(self.buckets):
            raise ValueError(f"buckets must be a positive int, "
                             f"got {self.buckets}")
        if self.bucket_weights is not None:
            object.__setattr__(self, "bucket_weights",
                               tuple(self.bucket_weights))
            if len(self.bucket_weights) != self.buckets \
                    or any(w <= 0 for w in self.bucket_weights):
                raise ValueError(
                    f"bucket_weights needs {self.buckets} positive entries, "
                    f"got {self.bucket_weights}")

    def bucket_plan(self, k: Optional[int] = None, *,
                    weights: Optional[List[float]] = None
                    ) -> List[BucketSlice]:
        """The per-layer-group cost breakdown: ``k`` slices of
        (compute_s, grad_bytes) whose plain left-to-right sums equal
        *exactly* the step totals (see ``_exact_split`` — bucketing
        changes *when* bytes move, never how many). ``weights`` skews
        the split toward heavier layer groups (e.g. an
        embedding-dominated first group); defaults to the model's
        ``bucket_weights`` when they match ``k``, else uniform."""
        k = self.buckets if k is None else k
        if k < 1:
            raise ValueError(f"bucket_plan needs k >= 1, got {k}")
        if weights is None:
            weights = list(self.bucket_weights) \
                if self.bucket_weights is not None \
                and len(self.bucket_weights) == k else [1.0] * k
        if len(weights) != k or any(w <= 0 for w in weights):
            raise ValueError(f"need {k} positive weights, got {weights}")
        total_w = math.fsum(weights)
        cs = _exact_split(self.compute_s, weights, total_w)
        gs = _exact_split(self.grad_bytes, weights, total_w)
        return [BucketSlice(c, g) for c, g in zip(cs, gs)]

    @classmethod
    def from_config(cls, cfg, shape, *, nodes: int, devices_per_node: int = 8,
                    ckpt_path: str = SOC, grad_dtype_bytes: int = 2,
                    state_bytes_per_param: int = 10,
                    buckets: int = 1,
                    weighted_buckets: bool = False) -> "ClusterTimeModel":
        """Roofline estimate from a model config + batch shape: compute
        is 6*N*D over the cluster's peak FLOP/s; gradient staging is the
        bf16 gradient buffer; the checkpoint shard is params + AdamW
        moments split over the nodes. ``weighted_buckets`` sizes each
        gradient bucket from the model's *real* per-layer-group
        parameter counts (layer_group_weights) instead of splitting
        uniformly."""
        from repro.core.roofline import model_flops_for
        tokens = shape.global_batch * shape.seq_len
        flops = model_flops_for(cfg.active_param_count(), tokens, "train")
        peak = hw.PEAK_FLOPS_BF16 * nodes * devices_per_node
        n_params = cfg.param_count()
        return cls(
            compute_s=flops / peak,
            grad_bytes=grad_dtype_bytes * n_params / nodes,
            ckpt_bytes=state_bytes_per_param * n_params / nodes,
            ckpt_path=ckpt_path,
            tokens_per_step=tokens,
            buckets=buckets,
            bucket_weights=tuple(layer_group_weights(cfg, buckets))
            if weighted_buckets and buckets > 1 else None,
        )


@dataclass
class ClusterNode:
    name: str
    index: int
    devices: int = 8
    alive: bool = True
    compute_scale: float = 1.0       # inherent speed (a slow node > 1)
    share_scale: float = 1.0         # mitigation-adjusted work share
    proc: Optional[Process] = None
    hb_proc: Optional[Process] = None
    inflight: List[Transfer] = field(default_factory=list)
    subprocs: List[Process] = field(default_factory=list)  # bucket procs


class TrainCluster:
    """N simulated trainer nodes stepping in lockstep on one runtime.

    ``step_fn(params, opt_state, batch, step) -> (params, opt_state,
    metrics)`` + ``batch_at(step)`` drive the optional numeric stream;
    ``ckpt`` persists it (real checkpoints, real restore after a
    simulated failure). ``fail_at=(node_name, step)`` silences a node
    at the start of that step; detection, elastic resize and resume
    then happen in simulated time.
    """

    def __init__(self, nodes: int, time_model: ClusterTimeModel, *,
                 fabric: Optional[Fabric] = None,
                 runtime: Optional[FabricRuntime] = None,
                 step_fn: Optional[Callable] = None,
                 params: Any = None, opt_state: Any = None,
                 batch_at: Optional[Callable[[int], Any]] = None,
                 ckpt: Optional[CheckpointManager] = None,
                 ckpt_every: Optional[int] = None,
                 devices_per_node: int = 8,
                 model_axis: int = 1,
                 heartbeat_every: float = 0.5,
                 heartbeat_timeout: float = 2.0,
                 node_compute_scale: Optional[Dict[str, float]] = None,
                 host_load: Optional[Dict[str, float]] = None,
                 mitigate_stragglers: bool = False,
                 skew_batches: bool = False,
                 microbatches_per_node: int = 8,
                 fail_at: Optional[Tuple[str, int]] = None,
                 tenant: Optional[str] = None,
                 topology: Any = None,
                 tracer=None):
        if nodes < 1:
            raise ValueError("cluster needs at least one node")
        self.tm = time_model
        self.topology = topology         # PodTopology (train/pods.py) or None
        if topology is not None and topology.total_nodes != nodes:
            raise ValueError(
                f"topology is {topology.pods} pods x "
                f"{topology.nodes_per_pod} nodes = {topology.total_nodes}, "
                f"but the cluster has {nodes} nodes")
        if fabric is None:
            if topology is not None:
                from repro.train.pods import pod_fabric
                fabric = pod_fabric(topology.pods, topology.nodes_per_pod)
            else:
                fabric = train_fabric(nodes)
        self.fabric = fabric
        # a cluster that owns its runtime traces by default (bucket
        # phase spans back the bucket_timeline accessor); a cluster on
        # a *shared* runtime inherits that runtime's tracer instead
        if runtime is not None:
            if tracer is not None:
                raise ValueError("pass the tracer to the shared runtime, "
                                 "not to the cluster")
            self.runtime = runtime
        else:
            self.runtime = FabricRuntime(
                self.fabric, tracer=tracer if tracer is not None else Tracer())
        self.step_fn = step_fn
        self.params, self.opt_state = params, opt_state
        self.batch_at = batch_at
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every if ckpt_every is not None \
            else (ckpt.every if ckpt is not None else 0)
        self.model_axis = model_axis
        self.heartbeat_every = heartbeat_every
        self.heartbeat_timeout = heartbeat_timeout
        self.mitigate_stragglers = mitigate_stragglers
        self.skew_batches = skew_batches   # route straggler shares into
        #                                    real per-node microbatch
        #                                    counts (train_step
        #                                    node_shares) — the numeric
        #                                    twin of share_scale
        if microbatches_per_node < 1:
            raise ValueError(f"microbatches_per_node must be >= 1, "
                             f"got {microbatches_per_node}")
        self.microbatches_per_node = microbatches_per_node
        self.fail_at = fail_at
        self.tenant = tenant             # QoS tag on every fabric transfer
        self.offload = OffloadStats()    # host-cycles-saved accounting
        if time_model.ckpt_path in _COMPRESS_MODES \
                and time_model.ckpt_bytes > 0:
            kind = "dca" if time_model.ckpt_path == SOC_COMPRESS \
                else "cpu:host"
            missing = [self._node_path(i, kind) for i in range(nodes)
                       if self._node_path(i, kind) not in self.fabric]
            if missing:
                raise FabricError(
                    f"ckpt_path={time_model.ckpt_path!r} needs compute "
                    f"paths {missing} — build the fabric with "
                    "train_fabric(compute_tier=True)")
        self._paused = False             # admission-control throttle state
        self._resume = self.runtime.signal()
        self.straggler = StragglerDetector()
        self.ft = FaultToleranceManager(ckpt, timeout=heartbeat_timeout,
                                        runtime=self.runtime)
        self.nodes: List[ClusterNode] = [
            ClusterNode(f"node{i}", i, devices=devices_per_node)
            for i in range(nodes)]
        names = {n.name: n for n in self.nodes}
        for bad in set(node_compute_scale or ()) | set(host_load or ()):
            if bad not in names:
                raise ValueError(f"unknown node {bad!r} "
                                 f"(cluster has {sorted(names)})")
        if fail_at is not None and fail_at[0] not in names:
            raise ValueError(f"fail_at names unknown node {fail_at[0]!r} "
                             f"(cluster has {sorted(names)})")
        for n in self.nodes:
            n.compute_scale = (node_compute_scale or {}).get(n.name, 1.0)
        for name, frac in (host_load or {}).items():
            # a load at/above the discounted capacity stalls the node's
            # gradient flow at rate 0 forever: the clock never drains
            limit = 1.0 - self.fabric.concurrency_discount
            if not 0.0 <= frac < limit:
                raise ValueError(
                    f"host_load[{name!r}]={frac} must be in [0, {limit}) — "
                    "at or above 1 - concurrency_discount the node's own "
                    "traffic would stall forever")
            i = names[name].index
            hp = self._node_path(i, HOST)
            cap = self.fabric[hp].capacity
            self.runtime.ledger.reserve(hp, out=frac * cap,
                                        in_=frac * cap,
                                        flow=f"hostload:{name}")
        self.start_step = 0
        self.history: List[dict] = []
        self.events: List[dict] = []
        self.mesh_shape: Tuple[int, ...] = ()
        self._barrier: Optional[Barrier] = None
        self._bucket_barriers: List[Barrier] = []
        # open bucket phase spans keyed (step, bucket): opened by the
        # first node to issue the bucket's allreduce, closed at the
        # bucket barrier's release — the overlap timeline now lives in
        # the tracer (see the bucket_timeline accessor)
        self._bucket_spans: Dict[Tuple[int, int], Optional[Span]] = {}
        self._step = 0
        self._end = 0
        self._step_start = 0.0
        if ckpt is not None and step_fn is not None \
                and ckpt.latest_step() is not None:
            (self.params, self.opt_state), k = ckpt.restore(
                (self.params, self.opt_state))
            self.start_step = k + 1

    # -- path naming (pod-aware) -----------------------------------------
    def _node_path(self, index: int, kind: str) -> str:
        """The fabric name of global node ``index``'s per-node path of
        ``kind`` (``host``, ``soc``, ``dca``, ``cpu:host``, ...):
        ``pod{p}/<kind>:<local>`` under a PodTopology, ``<kind>:<index>``
        single-pod."""
        if self.topology is not None:
            return self.topology.node_path(index, kind)
        return f"{kind}:{index}"

    def _net_path(self, index: int) -> str:
        """The ring-allreduce path node ``index`` uses: its pod's
        ``pod{p}/net`` under a PodTopology, the shared ``net`` else."""
        if self.topology is not None:
            return self.topology.net_path(index)
        return "net"

    # -- membership ------------------------------------------------------
    def _live(self) -> List[ClusterNode]:
        return [n for n in self.nodes if n.alive]

    def _ring_peers(self, node: ClusterNode) -> int:
        """How many live nodes share ``node``'s intra-pod ring (all live
        nodes single-pod; the pod's live membership under a topology)."""
        live = self._live()
        if self.topology is None:
            return len(live)
        p = self.topology.pod_of(node.index)
        return sum(1 for n in live if self.topology.pod_of(n.index) == p)

    def _ckpt_step(self, step: int) -> bool:
        return (self.tm.ckpt_bytes > 0 and self.ckpt_every > 0
                and step % self.ckpt_every == 0)

    def _staging_mode(self, node: ClusterNode) -> str:
        """This save's staging strategy. ``auto`` costs the node's raw
        wires *and* — when the fabric carries the compute tier — the
        compress-then-stage strategies against live wire+compute
        occupancy (CheckpointManager.choose_staging with
        StagingOptions); a static config keeps the fixed §6.1 choice."""
        if self.tm.ckpt_path != AUTO:
            return self.tm.ckpt_path
        i, tm = node.index, self.tm
        host_p, soc_p = self._node_path(i, HOST), self._node_path(i, SOC)
        dca_p = self._node_path(i, "dca")
        cpu_p = self._node_path(i, "cpu:host")
        cands = [StagingOption(HOST, host_p),
                 StagingOption(SOC, soc_p)]
        ops_per_byte = tm.ckpt_codec_ops
        if dca_p in self.fabric:
            cands.append(StagingOption(SOC_COMPRESS, soc_p,
                                       wire_scale=tm.ckpt_ratio,
                                       compute=dca_p,
                                       ops_scale=ops_per_byte))
        if cpu_p in self.fabric:
            cands.append(StagingOption(HOST_COMPRESS, host_p,
                                       wire_scale=tm.ckpt_ratio,
                                       compute=cpu_p,
                                       ops_scale=ops_per_byte))
        return CheckpointManager.choose_staging(
            cands, ledger=self.runtime.ledger, direction=OUT)

    # -- admission-control throttling ------------------------------------
    def pause_transfers(self, cancel: bool = True) -> None:
        """Defer the train tenant's fabric traffic: cancel every
        in-flight transfer (the reservations go straight back to the
        ledger) and hold new ones until ``resume_transfers``. Node
        processes park on the resume signal and re-issue the canceled
        remainders — progress is deferred, never lost.

        ``cancel=False`` is drain mode: in-flight work finishes and the
        pause takes effect when each node reaches its next transfer —
        with a chunked time model (``ClusterTimeModel.chunk_bytes``)
        that is at most one chunk away, so the pause is still prompt
        but without any cancel/re-issue churn."""
        if self._paused:
            return
        self._paused = True
        self._resume = self.runtime.signal()
        self.events.append({"t": self.runtime.clock.now,
                            "event": "transfers_paused", "step": self._step,
                            "mode": "cancel" if cancel else "drain"})
        if not cancel:
            return
        for n in self.nodes:
            for t in n.inflight:
                if not t.done:
                    self.runtime.cancel(t)

    def resume_transfers(self) -> None:
        if not self._paused:
            return
        self._paused = False
        self.events.append({"t": self.runtime.clock.now,
                            "event": "transfers_resumed", "step": self._step})
        self._resume.fire()

    @property
    def paused(self) -> bool:
        return self._paused

    def _tenant_xfer(self, node: ClusterNode, path: str, amount: float,
                     direction: str, flow: str):
        """Move ``amount`` over ``path`` respecting throttle pauses: a
        transfer the admission controller cancels is re-issued with its
        remaining amount after resume (cancel + re-issue is the pause
        mechanism — the ledger conserves across every transition).

        With ``chunk_bytes`` set, the amount moves as a pipeline of
        chunks, so a drain-mode pause (``pause_transfers(cancel=False)``)
        takes effect at the next chunk boundary — preemptible transfers
        without cancel/re-issue."""
        chunk = self.tm.chunk_bytes
        remaining = amount
        while remaining > 1e-9:
            while self._paused:
                yield self._resume
            issue = remaining if chunk is None else min(remaining, chunk)
            t = self.runtime.transfer(path, issue, direction=direction,
                                      flow=flow, tenant=self.tenant)
            node.inflight.append(t)
            yield t
            remaining -= issue - t.remaining if t.canceled else issue

    def _tenant_compute(self, node: ClusterNode, resource: str, ops: float,
                        flow: str):
        """``_tenant_xfer`` for compute work: execute ``ops`` on an
        ops/s resource respecting throttle pauses — a canceled Compute
        is re-issued with its remaining ops after resume, and the
        reservation conserves across every transition."""
        remaining = ops
        while remaining > 1e-9:
            while self._paused:
                yield self._resume
            c = self.runtime.compute(resource, remaining, flow=flow,
                                     tenant=self.tenant)
            node.inflight.append(c)
            yield c
            if not c.canceled:
                return
            remaining = c.remaining

    def _ckpt_offload(self, node: ClusterNode, mode: str):
        """One compress-then-stage save (the offload tier on the step
        path): run the codec ops where the mode places them — the NIC's
        DCA engine or the host socket — then stage only the compressed
        bytes over that side's wire. Both stages are pause-safe; the SoC
        placement credits the codec ops as host cycles saved."""
        tm, i = self.tm, node.index
        ops = tm.ckpt_codec_ops * tm.ckpt_bytes
        wire_bytes = tm.ckpt_ratio * tm.ckpt_bytes
        if mode == SOC_COMPRESS:
            compute, wire = self._node_path(i, "dca"), self._node_path(i, SOC)
        else:
            compute = self._node_path(i, "cpu:host")
            wire = self._node_path(i, HOST)
        yield from self._tenant_compute(node, compute, ops,
                                        f"ckptcomp:{node.name}")
        yield from self._tenant_xfer(node, wire, wire_bytes, OUT,
                                     f"ckpt:{node.name}")
        self.offload.record_compression(
            int(tm.ckpt_bytes), int(wire_bytes), ops=ops,
            offloaded=(mode == SOC_COMPRESS))

    def _pod_sync(self, node: ClusterNode, grad_bytes: float, tag: str):
        """Inter-pod sync of one gradient slice over the shared DCN
        trunk (see train/pods.py). Only the pod *leader* — the
        lowest-indexed live node of the pod, so leadership survives
        pod-local failures — touches the trunk: a P_live-way ring
        exchange of the slice's pod-aggregate bytes,
        ``2 (P-1)/P * grad_bytes * nodes`` wire bytes per leader, all
        leaders contending on one trunk budget. Under
        ``sync="compressed"`` the leader first spends the codec ops on
        its pod-local host socket, then moves ``compress_ratio`` of the
        bytes — the simulated twin of RunConfig.pod_sync="compressed".
        Non-leaders skip straight to the closing barrier, which is what
        makes the trunk time part of every node's step. Bucketed runs
        call this once per bucket (``grad_bytes`` = the slice, ``tag``
        carries the bucket suffix), so several leader-rings are in
        flight on the trunk at once — the hierarchical pipeline that
        keeps trunk and pod-local paths concurrently busy. Pause-safe
        via _tenant_compute/_tenant_xfer like all tenant traffic."""
        topo = self.topology
        live = [n.index for n in self._live()]
        if topo.leader_of(topo.pod_of(node.index), live) != node.index:
            return
        live_pods = len({topo.pod_of(i) for i in live})
        if live_pods < 2:
            return
        g_full = grad_bytes * len(self.nodes)
        wire = 2.0 * (live_pods - 1) / live_pods * g_full
        if wire <= 0:
            return
        if topo.sync == "compressed":
            ops = topo.codec_ops_per_byte * g_full
            if ops > 0:
                yield from self._tenant_compute(
                    node, topo.node_path(node.index, "cpu:host"), ops,
                    f"podcodec:{tag}")
            wire *= topo.compress_ratio
        yield from self._tenant_xfer(node, topo.trunk, wire, OUT,
                                     f"podsync:{tag}")

    # -- the per-node step loop -----------------------------------------
    def _grad_bucket(self, node: ClusterNode, grad_bytes: float, tag: str):
        """One gradient slice's allreduce, hierarchical: device->host
        staging (host OUT), the pod-local ring on the node's net path,
        the leader's inter-pod trunk ring under a topology, then
        host->device (host IN). ``tag`` names the flows (per-bucket tags
        keep concurrent buckets *distinct* flows, so the §4.1 discount
        emerges across in-flight buckets exactly as it does across
        tenants). Single-shot steps run this inline with
        ``tag=node.name`` — byte- and flow-identical to the pre-bucket
        schedule."""
        host_p = self._node_path(node.index, HOST)
        yield from self._tenant_xfer(node, host_p, grad_bytes, OUT,
                                     f"grad:{tag}")
        live = max(self._ring_peers(node), 1)
        ring = 2.0 * (live - 1) / live * grad_bytes
        if ring > 0:
            yield from self._tenant_xfer(node, self._net_path(node.index),
                                         ring, OUT, f"ring:{tag}")
        if self.topology is not None:
            yield from self._pod_sync(node, grad_bytes, tag)
        yield from self._tenant_xfer(node, host_p, grad_bytes, IN,
                                     f"grad:{tag}")

    def _bucket_proc(self, node: ClusterNode, k: int, grad_bytes: float,
                     own_done: Dict[str, float]):
        """One in-flight bucket: the slice's allreduce closed by the
        bucket's own cyclic barrier. Records the node's *own* completion
        time before the rendezvous (straggler timing must not be
        flattened by the barrier) and stamps the timeline at release."""
        yield from self._grad_bucket(node, grad_bytes,
                                     f"{node.name}:b{k}")
        own_done["t"] = max(own_done["t"], self.runtime.clock.now)
        yield self._bucket_barriers[k].arrive()

    def _on_bucket_done(self, k: int, _generation: int) -> None:
        span = self._bucket_spans.pop((self._step, k), None)
        self.runtime.tracer.end_phase(span)

    @property
    def bucket_timeline(self) -> List[dict]:
        """Per-(step, bucket) overlap records derived from the tracer's
        bucket phase spans: ``t_issue`` (first node issued the bucket's
        allreduce) -> ``t_done`` (the bucket's barrier released), in
        close order. Empty for single-shot (k=1) runs — and for a
        cluster sharing an untraced runtime, where no spans exist."""
        return [{"step": s.meta["step"], "bucket": s.meta["bucket"],
                 "t_issue": s.t_start, "t_done": s.t_end}
                for s in self.runtime.tracer.spans
                if s.kind == PHASE and s.name == "bucket"
                and not s.meta.get("aborted")]

    def _node_proc(self, node: ClusterNode):
        rt, tm = self.runtime, self.tm
        plan = tm.bucket_plan()
        bucketed = len(plan) > 1 and tm.grad_bytes > 0
        while node.alive and self._step < self._end:
            step = self._step
            if self.fail_at is not None and node.name == self.fail_at[0] \
                    and step >= self.fail_at[1]:
                node.alive = False            # goes silent: no barrier, no
                if node.hb_proc is not None:  # heartbeat -> watchdog fires
                    node.hb_proc.kill()
                self.events.append({"t": rt.clock.now, "event": "node_silent",
                                    "node": node.name, "step": step})
                return
            t0 = rt.clock.now
            node.inflight = [t for t in node.inflight if not t.done]
            node.subprocs = []
            ck = None
            ck_mode: Optional[str] = None
            if self._ckpt_step(step) and not self._paused:
                ck_mode = self._staging_mode(node)
                if ck_mode not in _COMPRESS_MODES:
                    # raw staging early-starts and overlaps the step
                    ck = rt.transfer(self._node_path(node.index, ck_mode),
                                     tm.ckpt_bytes, direction=OUT,
                                     flow=f"ckpt:{node.name}",
                                     tenant=self.tenant)
                    node.inflight.append(ck)
            own_done = {"t": t0}
            if bucketed:
                # staggered DDP pipeline: run each layer group's slice
                # of backward, then immediately put its bucket's
                # allreduce in flight — late buckets compute while
                # early buckets communicate, and the step's comm time
                # hides behind the remaining compute
                self.straggler.observe_ledger(
                    node.name, rt.ledger, self._node_path(node.index, HOST))
                for k, sl in enumerate(plan):
                    yield sl.compute_s * node.compute_scale \
                        * node.share_scale
                    if (step, k) not in self._bucket_spans:
                        self._bucket_spans[(step, k)] = \
                            rt.tracer.begin_phase("bucket",
                                                  tenant=self.tenant,
                                                  step=step, bucket=k)
                    node.subprocs.append(rt.process(
                        self._bucket_proc(node, k, sl.grad_bytes, own_done),
                        name=f"bucket:{node.name}:{k}"))
                for bp in node.subprocs:
                    yield bp                  # join: every bucket closed
            else:
                yield tm.compute_s * node.compute_scale * node.share_scale
                if tm.grad_bytes > 0:
                    # sample external host-direction occupancy *before*
                    # our own gradient flow joins the path (detector
                    # input)
                    self.straggler.observe_ledger(
                        node.name, rt.ledger,
                        self._node_path(node.index, HOST))
                    yield from self._grad_bucket(node, tm.grad_bytes,
                                                 node.name)
                    own_done["t"] = rt.clock.now
            if ck is not None:
                yield ck                      # staging is on the step path
                if ck.canceled and ck.remaining > 1e-9:
                    # throttled mid-save: defer the rest, same path
                    yield from self._tenant_xfer(node, ck.path, ck.remaining,
                                                 OUT, f"ckpt:{node.name}")
            elif self._ckpt_step(step):
                # a compress-then-stage save, or a save whose start was
                # deferred by a pause (re-choose the mode at resume)
                mode = ck_mode if ck_mode is not None \
                    else self._staging_mode(node)
                if mode in _COMPRESS_MODES:
                    yield from self._ckpt_offload(node, mode)
                else:
                    yield from self._tenant_xfer(
                        node, self._node_path(node.index, mode),
                        tm.ckpt_bytes, OUT, f"ckpt:{node.name}")
            if bucketed:
                # the node's own finish line: its last bucket's
                # completion (pre-barrier) or its checkpoint wait —
                # not the globally-synchronized join time
                own_t = own_done["t"]
                if self._ckpt_step(step):
                    own_t = max(own_t, rt.clock.now)
                self.straggler.observe(node.name, own_t - t0)
            else:
                self.straggler.observe(node.name, rt.clock.now - t0)
            yield self._barrier.arrive()

    def _heartbeat(self, node: ClusterNode) -> None:
        if node.alive:
            self.ft.heartbeat(node.name)

    # -- global-step bookkeeping (barrier release) -----------------------
    def _on_step_complete(self, _generation: int) -> None:
        step = self._step
        now = self.runtime.clock.now
        rec = {"step": step, "sim_t": now,
               "sim_seconds": now - self._step_start,
               "nodes": len(self._live())}
        if self.tm.tokens_per_step and rec["sim_seconds"] > 0:
            rec["tokens_per_s"] = self.tm.tokens_per_step / rec["sim_seconds"]
        if self.step_fn is not None:
            import jax.numpy as jnp
            batch = self.batch_at(step)
            if self.skew_batches:
                # close the straggler loop into real data: the
                # detector's rebalanced split becomes per-node
                # microbatch counts for the jitted step (static args —
                # equal shares dispatch to the uniform, bit-identical
                # path inside train_step)
                shares = self.straggler.microbatch_shares(
                    [n.name for n in self._live()],
                    self.microbatches_per_node)
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch, jnp.asarray(step),
                    node_shares=shares)
                rec["microbatch_shares"] = list(shares)
            else:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch, jnp.asarray(step))
            rec.update({k: float(v) for k, v in metrics.items()})
            if self.ckpt is not None and self._ckpt_step(step):
                self.ckpt.save(step, (self.params, self.opt_state),
                               blocking=True)
        if self.mitigate_stragglers and self.straggler.stragglers():
            live = self._live()
            per = self.microbatches_per_node
            shares = self.straggler.rebalanced_shares(per * len(live))
            for n in live:
                n.share_scale = shares.get(n.name, per) / per
        self.history.append(rec)
        self._step = step + 1
        self._step_start = now
        # stamp completion at the last barrier release, so a colocated
        # run's summary is not diluted by other tenants' tail time
        self._done_at = now if self._step >= self._end else None

    # -- failure handling ------------------------------------------------
    def _failure_watch(self):
        while True:
            yield self.ft.failed
            # drain the queue, not just the fired value: two watchdogs
            # expiring at the same instant fire the Signal twice, but
            # only the first fire finds a registered waiter
            while self.ft.pending_failures:
                self._handle_failure(self.ft.pending_failures.pop(0))

    def _handle_failure(self, name: str) -> None:
        now = self.runtime.clock.now
        self.events.append({"t": now, "event": "failure_detected",
                            "node": name, "step": self._step})
        # quiesce: kill every step process (and its in-flight bucket
        # subprocesses) and cancel in-flight transfers
        for n in self.nodes:
            if n.proc is not None:
                n.proc.kill()
            for bp in n.subprocs:
                bp.kill()
            n.subprocs = []
            for t in n.inflight:
                if not t.done:
                    self.runtime.cancel(t)
            n.inflight = []
            if n.name == name:
                n.alive = False
                if n.hb_proc is not None:
                    n.hb_proc.kill()
        survivors = self._live()
        if not survivors:
            raise RuntimeError("no survivors after failure of " + name)
        shape, axes = best_mesh_for(sum(n.devices for n in survivors),
                                    model=self.model_axis)
        self.mesh_shape = shape
        resume = self._step
        if self.ckpt is not None and self.step_fn is not None:
            (self.params, self.opt_state), k = self.ckpt.restore(
                (self.params, self.opt_state))
            resume = k + 1
            self.history = [h for h in self.history if h["step"] < resume]
        self.events.append({"t": now, "event": "elastic_resize",
                            "nodes": len(survivors), "mesh": shape,
                            "axes": axes, "resume_step": resume})
        self._step = resume
        self._step_start = now
        # the aborted step's open bucket spans: close them marked
        # aborted so the timeline accessor skips them (the re-run step
        # opens fresh spans)
        for span in self._bucket_spans.values():
            self.runtime.tracer.end_phase(span, aborted=True)
        self._bucket_spans.clear()
        self._spawn(survivors)

    # -- lifecycle -------------------------------------------------------
    def _spawn(self, members: List[ClusterNode]) -> None:
        self._barrier = self.runtime.barrier(
            len(members), on_release=self._on_step_complete, name="allreduce")
        if self.tm.buckets > 1 and self.tm.grad_bytes > 0:
            # one cyclic barrier per bucket: bucket k of a step closes
            # when every member's bucket-k allreduce lands, independent
            # of the other buckets — the per-bucket rendezvous that
            # makes the overlap pipeline safe for the numeric stream
            self._bucket_barriers = self.runtime.barrier_pool(
                self.tm.buckets, len(members), name="bucket",
                on_release=self._on_bucket_done)
        else:
            self._bucket_barriers = []
        for n in members:
            n.proc = self.runtime.process(self._node_proc(n),
                                          name=f"step:{n.name}")

    def begin(self, num_steps: int) -> None:
        """Arm heartbeats/FT and spawn the step processes *without*
        driving the clock — for running this cluster as one tenant on a
        shared timeline (the tenancy Colocation harness owns the clock).
        Pair with ``done`` (poll) and ``finish()`` (teardown+summary);
        plain single-tenant callers just use ``run()``."""
        rt = self.runtime
        self._run_t0 = rt.clock.now
        self._num_steps = num_steps
        self._done_at: Optional[float] = None
        self._step = self.start_step
        self._end = self.start_step + num_steps
        self._step_start = self._run_t0
        for n in self._live():
            if n.name not in self.ft.nodes:
                self.ft.register(n.name, devices=n.devices)
            if n.hb_proc is None or n.hb_proc.done:
                n.hb_proc = rt.every(self.heartbeat_every,
                                     lambda n=n: self._heartbeat(n),
                                     name=f"hb:{n.name}", start_delay=0.0)
        self._watch = rt.process(self._failure_watch(), name="failure-watch")
        self._spawn(self._live())

    @property
    def done(self) -> bool:
        """True when every live node's step process has returned."""
        return all(n.proc is None or n.proc.done for n in self._live())

    def finish(self) -> dict:
        """Tear down the periodic machinery (so the heap can drain) and
        summarize the steps since ``begin``."""
        rt = self.runtime
        self._watch.kill()
        for n in self.nodes:
            if n.hb_proc is not None:
                n.hb_proc.kill()
                n.hb_proc = None
        self.ft.disarm()
        num_steps = self._num_steps
        first = self._end - num_steps
        self.start_step = self._step
        end_t = self._done_at if self._done_at is not None else rt.clock.now
        elapsed = end_t - self._run_t0
        summary = {
            "steps": self._step - first,    # completed by *this* call
            "sim_seconds": elapsed,
            "nodes": len(self._live()),
            "mesh": self.mesh_shape,
            "buckets": self.tm.buckets,
            "events": list(self.events),
        }
        if self.tm.tokens_per_step and elapsed > 0:
            summary["tokens_per_s"] = \
                self.tm.tokens_per_step * num_steps / elapsed
        if self.history and "loss" in self.history[-1]:
            summary["loss"] = self.history[-1]["loss"]
        return summary

    def run(self, num_steps: int) -> dict:
        """Advance ``num_steps`` global steps in simulated time. Returns
        a summary (simulated seconds, tokens/s, events)."""
        self.begin(num_steps)
        self.runtime.clock.run(stop=lambda: self.done)
        return self.finish()
