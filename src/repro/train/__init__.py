from repro.train.train_step import make_train_step, loss_fn
from repro.train.trainer import Trainer
