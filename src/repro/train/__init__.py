from repro.train.train_step import make_train_step, loss_fn
from repro.train.trainer import Trainer
from repro.train.cluster import (ClusterTimeModel, TrainCluster,
                                 TRAIN_FABRICS, train_fabric)
