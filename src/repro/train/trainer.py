"""Training loop: data -> step -> metrics -> checkpoints -> recovery.

Production shape: deterministic resumable pipeline, async replicated
checkpoints, straggler bookkeeping, failure-driven restart. The loop is
mesh-agnostic — launch/train.py owns jit/shardings and hands in the
compiled step.

Two timing modes:

- wall clock (default): each step is timed with ``time.monotonic`` —
  the original behaviour, preserved byte for byte.
- runtime (``runtime=`` a ``FabricRuntime`` + ``time_model=`` a
  ``ClusterTimeModel``): every step *also* advances simulated time —
  the roofline compute delay plus the gradient staging transfers on
  the node's host path (and checkpoint staging on the configured
  SoC/host path on checkpoint steps), all charged against the shared
  ledger. Step records then carry ``sim_seconds`` and ``tokens_per_s``
  so a config can be throughput-profiled on a fabric without TPU time.
  The numeric stream is identical in both modes.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.ft.manager import FaultToleranceManager, NodeFailure
from repro.ft.straggler import StragglerDetector


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, shape: ShapeConfig, *,
                 step_fn: Callable,            # (params, opt, batch, step) -> ...
                 params: Any, opt_state: Any,
                 put_batch: Optional[Callable] = None,
                 ckpt: Optional[CheckpointManager] = None,
                 log_path: Optional[str] = None,
                 node_name: str = "self",
                 runtime=None,                 # FabricRuntime (simulated time)
                 time_model=None,              # ClusterTimeModel
                 node_index: int = 0,
                 ft_timeout: float = 1.0):
        self.cfg, self.run, self.shape = cfg, run, shape
        self.step_fn = step_fn
        self.params, self.opt_state = params, opt_state
        self.put_batch = put_batch or (lambda b: jax.tree.map(jnp.asarray, b))
        self.pipeline = TokenPipeline(cfg, shape, seed=run.seed)
        self.ckpt = ckpt
        self.straggler = StragglerDetector()
        self.log_path = log_path
        self.node_name = node_name
        self.node_index = node_index
        self.time_model = time_model
        if runtime is None and time_model is not None:
            from repro.train.cluster import train_fabric
            from repro.core.runtime import FabricRuntime
            runtime = FabricRuntime(train_fabric(1))
        self.runtime = runtime
        self.ft_timeout = ft_timeout
        self.ft: Optional[FaultToleranceManager] = None
        self._hb_proc = None
        self.history: list = []
        self.start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            (self.params, self.opt_state), k = ckpt.restore(
                (self.params, self.opt_state))
            self.start_step = k + 1

    def _log(self, rec: Dict):
        self.history.append(rec)
        if self.log_path:
            with open(self.log_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    # -- simulated step timing (runtime mode) ---------------------------
    def _simulate_step(self, step: int) -> float:
        """One step's simulated duration: compute + gradient staging on
        the node's host path, checkpoint staging overlapped on the
        configured path. Single-node by construction — no ring exchange
        and no barrier, unlike a TrainCluster node step; multi-node
        callers want TrainCluster, not N Trainers."""
        from repro.core.fabric import IN, OUT
        rt, tm, i = self.runtime, self.time_model, self.node_index
        t0 = rt.clock.now
        will_ckpt = (tm.ckpt_bytes > 0 and self.ckpt is not None
                     and self.ckpt.every > 0 and step % self.ckpt.every == 0)
        finished = []

        def one_step():
            from repro.train.cluster import AUTO
            ck = None
            if will_ckpt:
                staging = (CheckpointManager.choose_staging(
                    [f"host:{i}", f"soc:{i}"], ledger=rt.ledger, direction=OUT)
                    if tm.ckpt_path == AUTO else f"{tm.ckpt_path}:{i}")
                ck = rt.transfer(staging, tm.ckpt_bytes,
                                 direction=OUT, flow=f"ckpt:{self.node_name}")
            yield tm.compute_s
            if tm.grad_bytes > 0:
                self.straggler.observe_ledger(self.node_name, rt.ledger,
                                              f"host:{i}")
                yield rt.transfer(f"host:{i}", tm.grad_bytes, direction=OUT,
                                  flow=f"grad:{self.node_name}")
                yield rt.transfer(f"host:{i}", tm.grad_bytes, direction=IN,
                                  flow=f"grad:{self.node_name}")
            if ck is not None:
                yield ck
            finished.append(True)

        rt.process(one_step(), name=f"step:{self.node_name}")
        rt.clock.run(stop=lambda: bool(finished))
        return rt.clock.now - t0

    # -- event-driven failure injection (ft/manager watchdogs) -----------
    def _arm_ft(self) -> None:
        """Register this node with an event-driven FT manager on the
        trainer's runtime (created on demand for wall-clock trainers).
        Heartbeats are a *periodic runtime process* (as on the cluster),
        not per-step calls — a simulated step longer than the timeout
        must not let the watchdog expire under a healthy node. A
        silenced node is then detected by its watchdog expiring on the
        simulated clock — no wall-clock path."""
        if self.runtime is None:
            from repro.train.cluster import train_fabric
            from repro.core.runtime import FabricRuntime
            self.runtime = FabricRuntime(train_fabric(1))
        if self.ft is None:
            self.ft = FaultToleranceManager(self.ckpt, timeout=self.ft_timeout,
                                            runtime=self.runtime)
        if self.node_name not in self.ft.nodes:
            self.ft.register(self.node_name)
        if self._hb_proc is None or self._hb_proc.done:
            self._hb_proc = self.runtime.every(
                self.ft_timeout / 4.0,
                lambda: self.ft.heartbeat(self.node_name),
                name=f"hb:{self.node_name}", start_delay=0.0)

    def _disarm_ft(self) -> None:
        if self._hb_proc is not None:
            self._hb_proc.kill()
            self._hb_proc = None
        if self.ft is not None:
            self.ft.disarm()

    def _fail_silently(self, step: int) -> None:
        """Go silent at `step`: kill the heartbeat process and run the
        simulated clock until the watchdog fires, then surface the
        detection."""
        rt = self.runtime
        self._hb_proc.kill()
        self._hb_proc = None
        rt.clock.run(stop=lambda: bool(self.ft.pending_failures))
        self.ft.disarm()
        if self.ckpt is not None:
            self.ckpt.wait()
        detected = self.ft.pending_failures.pop(0)
        raise NodeFailure(
            f"node {detected} failure detected at "
            f"sim t={rt.clock.now:.3f}s (silent since step {step})")

    def run_steps(self, num_steps: int, *, fail_at: Optional[int] = None) -> Dict:
        """Run `num_steps` from start_step. ``fail_at`` silences this
        node at that step: its per-step heartbeat stops, the
        FaultToleranceManager watchdog expires in *simulated* time, and
        the detection surfaces as ``NodeFailure`` (recovery = a fresh
        Trainer against the same checkpoint directory)."""
        step = self.start_step
        end = self.start_step + num_steps
        if fail_at is not None:
            self._arm_ft()
        tokens_per_step = (self.time_model.tokens_per_step
                           if self.time_model is not None
                           and self.time_model.tokens_per_step
                           else self.shape.global_batch * self.shape.seq_len)
        while step < end:
            if fail_at is not None and step == fail_at:
                self._fail_silently(step)
            t0 = time.monotonic()
            batch = self.put_batch(self.pipeline.batch_at(step))
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, jnp.asarray(step))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            rec = {"step": step, "seconds": dt, **metrics}
            if self.runtime is not None and self.time_model is not None:
                sim_dt = self._simulate_step(step)
                rec["sim_seconds"] = sim_dt
                if sim_dt > 0:
                    rec["tokens_per_s"] = tokens_per_step / sim_dt
                self.straggler.observe(self.node_name, sim_dt)
            else:
                self.straggler.observe(self.node_name, dt)
            self._log(rec)
            if self.ckpt is not None:
                self.ckpt.maybe_save(step, (self.params, self.opt_state))
            step += 1
        self._disarm_ft()
        if self.ckpt is not None:
            self.ckpt.wait()
        self.start_step = step
        return self.history[-1] if self.history else {}
