"""Training loop: data -> step -> metrics -> checkpoints -> recovery.

Production shape: deterministic resumable pipeline, async replicated
checkpoints, straggler bookkeeping, failure-driven restart. The loop is
mesh-agnostic — launch/train.py owns jit/shardings and hands in the
compiled step.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.ft.straggler import StragglerDetector


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, shape: ShapeConfig, *,
                 step_fn: Callable,            # (params, opt, batch, step) -> ...
                 params: Any, opt_state: Any,
                 put_batch: Optional[Callable] = None,
                 ckpt: Optional[CheckpointManager] = None,
                 log_path: Optional[str] = None):
        self.cfg, self.run, self.shape = cfg, run, shape
        self.step_fn = step_fn
        self.params, self.opt_state = params, opt_state
        self.put_batch = put_batch or (lambda b: jax.tree.map(jnp.asarray, b))
        self.pipeline = TokenPipeline(cfg, shape, seed=run.seed)
        self.ckpt = ckpt
        self.straggler = StragglerDetector()
        self.log_path = log_path
        self.history: list = []
        self.start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            (self.params, self.opt_state), k = ckpt.restore(
                (self.params, self.opt_state))
            self.start_step = k + 1

    def _log(self, rec: Dict):
        self.history.append(rec)
        if self.log_path:
            with open(self.log_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    def run_steps(self, num_steps: int, *, fail_at: Optional[int] = None) -> Dict:
        """Run `num_steps` from start_step. `fail_at` raises a simulated
        node failure at that step (tests drive recovery through ft/)."""
        step = self.start_step
        end = self.start_step + num_steps
        while step < end:
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            t0 = time.monotonic()
            batch = self.put_batch(self.pipeline.batch_at(step))
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, jnp.asarray(step))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.straggler.observe("self", dt)
            rec = {"step": step, "seconds": dt, **metrics}
            self._log(rec)
            if self.ckpt is not None:
                self.ckpt.maybe_save(step, (self.params, self.opt_state))
            step += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        self.start_step = step
        return self.history[-1] if self.history else {}
