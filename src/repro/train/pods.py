"""Multi-pod hierarchical fabrics: P pods of N nodes over a DCN trunk.

The single-pod ``train_fabric`` models one pod's host/soc/net paths.
Planet-scale training composes many such pods: each pod's fabric is
namespaced (``Fabric.namespaced("pod{p}")`` — every path and explicit
interference group gets the pod prefix, so structurally identical pods
coexist without colliding) and the copies are merged with
``merge_fabrics`` over one *shared* inter-pod trunk path, ``dcn:pod``.
The trunk is deliberately un-namespaced: every pod references the same
path name, so the merge folds it into a single budget all pods contend
on — and a conflicting trunk redefinition (two pods claiming different
trunk capacities) is a merge error, not a silent override.

``PodTopology`` is the runtime-side description ``TrainCluster``
consumes: node-index → pod mapping, path-name prefixing, and the
inter-pod gradient sync policy. Per global step each pod runs its
intra-pod ring allreduce on its own ``pod{p}/net``, then the pod
*leader* (the lowest-indexed live node — leadership survives pod-local
failures) exchanges the full gradient with the other pods over the
trunk: a P-way ring, ``2 (P_live - 1) / P_live * full_grad_bytes`` per
leader. ``sync="compressed"`` is the simulated twin of
``RunConfig.pod_sync="compressed"`` (train/train_step.py's int8 ring):
wire bytes shrink by ``compress_ratio`` but the leader first spends
``codec_ops_per_byte`` per raw byte on its pod-local host socket
(``pod{p}/cpu:host:<local>``). Whether that trade wins is emergent: a
thin trunk makes the halved wire bytes dominate (compressed wins), a
fat trunk makes the codec the bottleneck (raw wins) — asserted in
tests/test_pods.py across trunk bandwidths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import hw
from repro.core.fabric import Fabric, Path, merge_fabrics
from repro.train.cluster import train_fabric

#: the shared inter-pod DCN trunk path name (un-namespaced on purpose:
#: merging pods folds every reference into one budget)
TRUNK = "dcn:pod"

#: pod_sync modes (mirrors train/train_step.py RunConfig.pod_sync)
RAW, COMPRESSED = "auto", "compressed"
_SYNC_MODES = (RAW, COMPRESSED)


def trunk_path(trunk_bw: float, *, latency: float = hw.DCN_LAT) -> Path:
    """The inter-pod DCN trunk as a fabric Path (switch-aggregated:
    ``trunk_bw`` is the total cross-pod bandwidth all leaders share)."""
    return Path(TRUNK, trunk_bw, latency=latency, kind="dcn",
                shared_group=TRUNK)


@dataclass(frozen=True)
class PodTopology:
    """Node-index → pod mapping + inter-pod sync policy for
    ``TrainCluster``. Global node index ``i`` lives in pod
    ``i // nodes_per_pod`` with pod-local index ``i % nodes_per_pod``;
    its fabric paths carry the ``pod{p}<sep>`` prefix."""
    pods: int
    nodes_per_pod: int
    sync: str = RAW                    # RunConfig.pod_sync
    compress_ratio: float = 0.5        # int8 over bf16 wire bytes
    codec_ops_per_byte: float = 1.0    # leader encode+decode ops per raw byte
    sep: str = "/"
    trunk: str = TRUNK                 # shared inter-pod trunk path name

    def __post_init__(self):
        if self.pods < 1 or self.nodes_per_pod < 1:
            raise ValueError("PodTopology needs >= 1 pod of >= 1 node")
        if self.sync not in _SYNC_MODES:
            raise ValueError(f"sync must be one of {_SYNC_MODES}, "
                             f"got {self.sync!r}")
        if not 0.0 < self.compress_ratio <= 1.0:
            raise ValueError(f"compress_ratio must be in (0, 1], "
                             f"got {self.compress_ratio}")
        if self.codec_ops_per_byte < 0:
            raise ValueError("codec_ops_per_byte must be >= 0")

    @property
    def total_nodes(self) -> int:
        return self.pods * self.nodes_per_pod

    def pod_of(self, index: int) -> int:
        return index // self.nodes_per_pod

    def local_of(self, index: int) -> int:
        return index % self.nodes_per_pod

    def prefix(self, pod: int) -> str:
        return f"pod{pod}"

    def path(self, index: int, base: str) -> str:
        """The merged-fabric name of node ``index``'s pod-local path
        ``base`` — e.g. ``path(9, "host:1") == "pod2/host:1"`` at 4
        nodes/pod. ``base`` uses the *pod-local* node index."""
        return f"{self.prefix(self.pod_of(index))}{self.sep}{base}"

    def node_path(self, index: int, kind: str) -> str:
        """Pod-prefixed per-node path of ``kind`` (``host``, ``soc``,
        ``dca``, ``cpu:host``, ``cpu:soc``) for global node ``index``."""
        return self.path(index, f"{kind}:{self.local_of(index)}")

    def net_path(self, index: int) -> str:
        """The intra-pod ring path of global node ``index``'s pod."""
        return self.path(index, "net")

    def leader_of(self, pod: int, live: List[int]) -> Optional[int]:
        """The pod's trunk leader: its lowest-indexed *live* node (so
        leadership survives pod-local failures), or None when the pod
        has no survivors. ``live`` is the global indices of live
        nodes."""
        members = [i for i in live if self.pod_of(i) == pod]
        return min(members) if members else None


def pod_fabric(pods: int, nodes_per_pod: int, *,
               trunk_bw: Optional[float] = None,
               pod_fabric_fn=None, sep: str = "/",
               **train_fabric_kw) -> Fabric:
    """P structurally identical pod fabrics + the shared DCN trunk, as
    one merged Fabric. Each pod is ``train_fabric(nodes_per_pod)`` (or
    ``pod_fabric_fn(nodes_per_pod)``) namespaced ``pod{p}``; the trunk
    defaults to ``pods * DCN_BW_PER_CHIP`` aggregate bandwidth. The
    merged concurrency discount is the max over the inputs
    (merge_fabrics semantics)."""
    if pods < 1 or nodes_per_pod < 1:
        raise ValueError("pod_fabric needs >= 1 pod of >= 1 node")
    build = pod_fabric_fn if pod_fabric_fn is not None \
        else (lambda n: train_fabric(n, **train_fabric_kw))
    bw = trunk_bw if trunk_bw is not None else pods * hw.DCN_BW_PER_CHIP
    pod_fabs = [build(nodes_per_pod).namespaced(f"pod{p}", sep=sep)
                for p in range(pods)]
    trunk = Fabric.of(trunk_path(bw),
                      concurrency_discount=pod_fabs[0].concurrency_discount)
    return merge_fabrics(*pod_fabs, trunk)


def pod_cluster(pods: int, nodes_per_pod: int, time_model, *,
                sync: str = RAW, trunk_bw: Optional[float] = None,
                compress_ratio: float = 0.5, codec_ops_per_byte: float = 1.0,
                **cluster_kw):
    """Convenience builder: a ``TrainCluster`` over ``pod_fabric`` with
    the matching ``PodTopology`` attached."""
    from repro.train.cluster import TrainCluster
    topo = PodTopology(pods, nodes_per_pod, sync=sync,
                       compress_ratio=compress_ratio,
                       codec_ops_per_byte=codec_ops_per_byte)
    fab = pod_fabric(pods, nodes_per_pod, trunk_bw=trunk_bw)
    return TrainCluster(topo.total_nodes, time_model, fabric=fab,
                        topology=topo, **cluster_kw)
