"""Gemma-2 9B  [arXiv:2408.00118] — local+global alternating attention,
logit softcapping, GeGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    rope_theta=10000.0,
    window_size=4096,
    local_global_period=2,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_activation="gelu",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118",
)
