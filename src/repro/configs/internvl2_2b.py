"""InternVL2 2B  [arXiv:2404.16821] — InternViT frontend (STUB: precomputed
patch embeddings) + InternLM2-1.8B backbone."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1000000.0,
    mlp_activation="silu",
    frontend="vision",
    frontend_tokens=256,     # 448x448 / 14 patch / pixel-shuffle 0.5 => 256
    source="arXiv:2404.16821",
)
