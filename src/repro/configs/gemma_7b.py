"""Gemma 7B  [arXiv:2403.08295] — GeGLU, head_dim=256 (kv=16 == MHA on 7b)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    rope_theta=10000.0,
    mlp_activation="gelu",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2403.08295",
)
