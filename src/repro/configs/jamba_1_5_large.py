"""Jamba-1.5-large 398B  [arXiv:2403.19887] — hybrid Mamba+attention 1:7
interleave, MoE 16 experts top-2 every other layer.

Deviation (recorded in DESIGN.md): Mamba-2 (SSD) blocks are used in place
of Mamba-1 so the SSD Pallas kernel is shared with mamba2-2.7b.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_period=2,
    attn_period=8,           # 1 attention layer per 8 (1:7 mamba:attn)
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    mlp_activation="silu",
    source="arXiv:2403.19887",
)
