"""Config system: model / shape / mesh / run configs.

Every assigned architecture gets one module in this package exporting
``CONFIG: ModelConfig``. ``repro.configs.registry`` resolves ``--arch``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Covers dense / MoE / SSM / hybrid /
    VLM-backbone / audio-backbone families with one schema."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int

    # ---- attention ----
    num_heads: int = 0               # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0       # partial rotary (GLM-4 uses 0.5)
    window_size: Optional[int] = None        # sliding-window width (local layers)
    local_global_period: int = 0     # gemma2: 2 => alternate local/global
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    qk_norm: bool = False

    # ---- MLP ----
    d_ff: int = 0
    mlp_activation: str = "silu"     # silu (SwiGLU) | gelu (GeGLU)

    # ---- MoE ----
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_period: int = 1              # apply MoE every k-th layer (jamba: 2)
    router_aux_loss: float = 0.01

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # ---- hybrid (jamba) ----
    attn_period: int = 0             # attention every k-th layer (jamba: 8)

    # ---- modality frontend stub ----
    frontend: Optional[str] = None   # "vision" | "audio"
    frontend_tokens: int = 256       # prefix embeddings provided by the stub
    num_codebooks: int = 1           # musicgen: 4 EnCodec codebooks

    # ---- misc ----
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: multiply embeddings by sqrt(D)
    source: str = ""                 # provenance tag from the assignment

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_attention_layer(self, layer_idx: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_period:
            # jamba: one attention layer per attn_period block, at the
            # middle slot of each period (per the released config).
            return layer_idx % self.attn_period == self.attn_period // 2
        return True

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.num_experts:
            return False
        return (layer_idx % self.moe_period) == (self.moe_period - 1)

    def layer_kind(self, layer_idx: int) -> str:
        """'attn' | 'ssm' for the mixer of layer i."""
        return "attn" if self.is_attention_layer(layer_idx) else "ssm"

    def is_local_layer(self, layer_idx: int) -> bool:
        """Sliding-window (local) attention layer? gemma2 alternates
        local/global with period 2 starting from local."""
        if not self.local_global_period or self.window_size is None:
            return False
        return layer_idx % self.local_global_period == 0

    def param_count(self) -> int:
        """Total parameters (analytic, matches init exactly)."""
        return sum(int(x) for x in _param_tree_sizes(self).values())

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k of experts)."""
        total = 0
        for name, n in _param_tree_sizes(self).items():
            if ".moe." in name and "router" not in name:
                total += int(n * self.num_experts_per_tok / self.num_experts)
            else:
                total += int(n)
        return total

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(2, min(4, self.attn_period or 2) * (2 if self.family == "hybrid" else 1)),
            d_model=64,
            vocab_size=128,
            d_ff=128 if self.d_ff else 0,
            head_dim=16 if self.num_heads else 0,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(2, self.num_experts_per_tok) if self.num_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            window_size=16 if self.window_size else None,
            frontend_tokens=8 if self.frontend else 256,
            attn_period=min(self.attn_period, 4) if self.attn_period else 0,
            name=self.name + "-reduced",
        )
        if self.family == "hybrid":
            small["num_layers"] = 2 * (small["attn_period"] or 2)
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _param_tree_sizes(cfg: ModelConfig) -> dict:
    """Analytic per-tensor parameter counts; mirrors models/params.py init."""
    sizes: dict = {}
    sizes["embed.table"] = cfg.vocab_size * cfg.d_model * cfg.num_codebooks
    if not cfg.tie_embeddings:
        sizes["lm_head"] = cfg.vocab_size * cfg.d_model * cfg.num_codebooks
    for i in range(cfg.num_layers):
        p = f"layer{i}"
        if cfg.is_attention_layer(i):
            sizes[f"{p}.attn.wq"] = cfg.d_model * cfg.q_dim
            sizes[f"{p}.attn.wk"] = cfg.d_model * cfg.kv_dim
            sizes[f"{p}.attn.wv"] = cfg.d_model * cfg.kv_dim
            sizes[f"{p}.attn.wo"] = cfg.q_dim * cfg.d_model
        elif cfg.ssm_state:
            d_in = cfg.d_inner
            H = cfg.ssm_heads
            sizes[f"{p}.ssm.in_proj"] = cfg.d_model * (2 * d_in + 2 * cfg.ssm_state + H)
            sizes[f"{p}.ssm.conv"] = cfg.ssm_conv * (d_in + 2 * cfg.ssm_state)
            sizes[f"{p}.ssm.A_log"] = H
            sizes[f"{p}.ssm.D"] = H
            sizes[f"{p}.ssm.dt_bias"] = H
            sizes[f"{p}.ssm.out_proj"] = d_in * cfg.d_model
            sizes[f"{p}.ssm.norm"] = d_in
        has_ffn = False
        if cfg.is_moe_layer(i):
            sizes[f"{p}.moe.router"] = cfg.d_model * cfg.num_experts
            sizes[f"{p}.moe.w_in"] = cfg.num_experts * cfg.d_model * cfg.d_ff * 2
            sizes[f"{p}.moe.w_out"] = cfg.num_experts * cfg.d_ff * cfg.d_model
            has_ffn = True
        elif cfg.d_ff:
            sizes[f"{p}.mlp.w_in"] = cfg.d_model * cfg.d_ff * 2
            sizes[f"{p}.mlp.w_out"] = cfg.d_ff * cfg.d_model
            has_ffn = True
        sizes[f"{p}.norms"] = (2 if has_ffn else 1) * cfg.d_model
    sizes["final_norm"] = cfg.d_model
    return sizes


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch, shape) cell runnable? Returns (ok, reason)."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k requires sub-quadratic mixing (SSM/hybrid); " \
                      f"{cfg.name} is pure full-attention"
    return True, ""


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class RunConfig:
    """Trainer/serving hyper-parameters independent of architecture."""
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0              # 0 => no microbatching
    remat_policy: str = "minimal"    # none | minimal | full
    # --- paper-derived knobs (the planner sets these) ---
    grad_bucket_mb: int = 64         # doorbell-batching analogue
    pod_sync: str = "auto"           # auto (XLA SPMD) | compressed (int8 ring)
    moments_int8: bool = False       # blockwise-int8 AdamW moments
    collective_chunk_mb: int = 0     # 0 => unchunked (Advice #2/#3 analogue)
    ckpt_every: int = 0              # steps between checkpoints (0 = off)
    ckpt_dir: str = ""
    ckpt_replicas: int = 0           # chain-replication targets (LineFS)
    ckpt_compress: bool = True
    seed: int = 0
