from repro.configs.base import (
    SHAPES,
    MeshConfig,
    ModelConfig,
    MULTI_POD,
    RunConfig,
    ShapeConfig,
    SINGLE_POD,
    shape_applicable,
)
from repro.configs.registry import all_configs, get_config, list_archs

__all__ = [
    "SHAPES",
    "MeshConfig",
    "ModelConfig",
    "MULTI_POD",
    "RunConfig",
    "ShapeConfig",
    "SINGLE_POD",
    "shape_applicable",
    "all_configs",
    "get_config",
    "list_archs",
]
