"""--arch registry: resolve architecture ids to ModelConfigs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "glm4-9b": "repro.configs.glm4_9b",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "gemma-7b": "repro.configs.gemma_7b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_16b_a3b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown --arch {arch!r}; known: {', '.join(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch])
    cfg: ModelConfig = mod.CONFIG
    assert cfg.name == arch, (cfg.name, arch)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _MODULES}
