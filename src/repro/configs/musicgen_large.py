"""MusicGen-large  [arXiv:2306.05284] — decoder-only over EnCodec tokens,
4 codebooks (delay pattern handled by the audio frontend STUB), MHA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_activation="gelu",
    frontend="audio",
    frontend_tokens=64,      # conditioning frames from the stub
    num_codebooks=4,
    source="arXiv:2306.05284",
)
