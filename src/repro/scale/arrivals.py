"""Trace-driven open-loop load generation for the serving fleet.

The serve benchmarks so far replay a fixed request list — a *closed*
loop, where the client waits for the system. Real traffic is open-loop:
arrivals keep coming at the trace's rate whether or not the system
keeps up, which is exactly the regime where the paper's multipath
guidance (and the BlueField saturation cliff of arXiv:2105.06619)
matters. This module is that workload:

``TraceSpec``         a named arrival-rate curve: a Poisson base rate
                      modulated by a diurnal sinusoid and a set of
                      ``Burst`` windows (each multiplies the rate while
                      active), plus heavy-tailed (clamped lognormal)
                      prompt- and decode-length distributions.
``ArrivalGenerator``  seeded sampling of the trace into ``Request``s:
                      a nonhomogeneous Poisson process via thinning
                      (candidates at the peak rate, accepted with
                      probability rate(t)/peak), deterministic per
                      (spec, seed) — the same seed always produces the
                      identical request sequence, byte for byte.
``feed()``            the open-loop runtime Process: submits each
                      request at its simulated arrival time, generated
                      lazily as the clock advances, instead of a
                      pre-built list.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator, List, Tuple

import numpy as np

from repro.serve.engine import Request


@dataclass(frozen=True)
class Burst:
    """A transient load spike: the trace rate is multiplied by
    ``multiplier`` for ``start <= t < start + duration``."""
    start: float
    duration: float
    multiplier: float

    def __post_init__(self):
        if self.duration <= 0:
            raise ValueError(f"burst duration must be > 0, got {self.duration}")
        if self.multiplier <= 0:
            raise ValueError(f"burst multiplier must be > 0, "
                             f"got {self.multiplier}")

    def active(self, t: float) -> bool:
        return self.start <= t < self.start + self.duration


@dataclass(frozen=True)
class LengthSpec:
    """Heavy-tailed token-count distribution: lognormal with the given
    ``median`` and shape ``sigma``, clamped to [low, high]. Production
    prompt lengths are famously right-skewed — the tail, not the mean,
    is what fills decode slots."""
    median: float
    sigma: float = 0.6
    low: int = 1
    high: int = 512

    def __post_init__(self):
        if self.median <= 0 or self.sigma < 0:
            raise ValueError(f"need median > 0, sigma >= 0; "
                             f"got {self.median}, {self.sigma}")
        if not 1 <= self.low <= self.high:
            raise ValueError(f"need 1 <= low <= high, "
                             f"got [{self.low}, {self.high}]")

    def sample(self, rng: np.random.Generator) -> int:
        n = int(round(rng.lognormal(math.log(self.median), self.sigma)))
        return min(max(n, self.low), self.high)


@dataclass(frozen=True)
class TraceSpec:
    """One tenant's arrival-rate curve over ``duration`` seconds.

    ``rate(t) = base_rate * (1 + diurnal_amplitude *
    sin(2π (t - diurnal_phase) / diurnal_period)) * Π active bursts``,
    floored at 0. ``peak_rate`` is the exact supremum over burst
    combinations (diurnal bounded by its amplitude) — the thinning
    envelope."""
    name: str
    base_rate: float                       # requests/s
    duration: float                        # seconds of trace
    diurnal_amplitude: float = 0.0         # fraction of base_rate
    diurnal_period: float = 86400.0
    diurnal_phase: float = 0.0
    bursts: Tuple[Burst, ...] = ()
    prompt: LengthSpec = field(default_factory=lambda: LengthSpec(24, 0.6, 8, 96))
    decode: LengthSpec = field(default_factory=lambda: LengthSpec(8, 0.5, 2, 32))

    def __post_init__(self):
        if self.base_rate <= 0 or self.duration <= 0:
            raise ValueError("base_rate and duration must be > 0")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError(f"diurnal_amplitude must be in [0, 1], "
                             f"got {self.diurnal_amplitude}")
        if self.diurnal_period <= 0:
            raise ValueError("diurnal_period must be > 0")

    def rate(self, t: float) -> float:
        r = self.base_rate * (1.0 + self.diurnal_amplitude * math.sin(
            2.0 * math.pi * (t - self.diurnal_phase) / self.diurnal_period))
        for b in self.bursts:
            if b.active(t):
                r *= b.multiplier
        return max(r, 0.0)

    @property
    def peak_rate(self) -> float:
        """Supremum of ``rate`` over [0, duration): exact over the burst
        piecewise intervals, diurnal bounded by ``1 + amplitude``."""
        edges = {0.0}
        for b in self.bursts:
            edges.add(b.start)
            edges.add(b.start + b.duration)
        worst = 1.0
        for e in sorted(edges):
            if 0.0 <= e < self.duration:
                prod = 1.0
                for b in self.bursts:
                    if b.active(e):
                        prod *= b.multiplier
                worst = max(worst, prod)
        return self.base_rate * (1.0 + self.diurnal_amplitude) * worst

    @property
    def mean_rate(self) -> float:
        """Time-averaged rate (trapezoid over a 1 s grid) — offered-load
        sweeps scale traces by this, not the peak."""
        n = max(int(self.duration), 2)
        ts = [self.duration * i / n for i in range(n + 1)]
        rs = [self.rate(t) for t in ts]
        return sum((rs[i] + rs[i + 1]) / 2 for i in range(n)) / n


def burst_trace(name: str = "burst10x", *, base_rate: float = 2.0,
                duration: float = 120.0, burst_multiplier: float = 10.0,
                burst_start: float = 30.0, burst_duration: float = 45.0,
                diurnal_amplitude: float = 0.25,
                prompt: LengthSpec = None, decode: LengthSpec = None,
                ) -> TraceSpec:
    """The headline trace: a diurnal baseline with one 10x burst window
    — the regime where a static fleet's TTFT attainment collapses and
    an autoscaled one holds."""
    kw = {}
    if prompt is not None:
        kw["prompt"] = prompt
    if decode is not None:
        kw["decode"] = decode
    return TraceSpec(
        name, base_rate, duration,
        diurnal_amplitude=diurnal_amplitude, diurnal_period=duration,
        bursts=(Burst(burst_start, burst_duration, burst_multiplier),),
        **kw)


class ArrivalGenerator:
    """Seeded sampling of a ``TraceSpec`` into ``Request``s.

    Thinning keeps determinism trivially exact: every candidate arrival
    and its accept/reject draw comes from one ``np.random.default_rng``
    stream in a fixed order, so the request sequence is a pure function
    of (spec, seed, vocab, rid_base). ``rid_base`` namespaces request
    ids per tenant — in sim-compute engines the token stream is a hash
    of the rid, so distinct tenants provably produce distinct bytes.
    """

    def __init__(self, spec: TraceSpec, *, seed: int = 0, vocab: int = 32000,
                 rid_base: int = 0, start: float = 0.0):
        self.spec = spec
        self.seed = seed
        self.vocab = vocab
        self.rid_base = rid_base
        self.start = start

    def __iter__(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        spec = self.spec
        peak = spec.peak_rate
        t, rid = 0.0, self.rid_base
        while True:
            t += rng.exponential(1.0 / peak)
            if t >= spec.duration:
                return
            accept = rng.random() * peak <= spec.rate(t)
            # lengths are drawn for every candidate so the stream stays
            # aligned however the rate curve thins it
            plen = spec.prompt.sample(rng)
            dlen = spec.decode.sample(rng)
            prompt = rng.integers(1, self.vocab, size=plen).astype(np.int32)
            if not accept:
                continue
            yield Request(rid=rid, prompt=prompt, max_new_tokens=dlen,
                          arrival=self.start + t)
            rid += 1

    def requests(self) -> List[Request]:
        """The trace materialized up front (closed-loop replay and
        determinism tests)."""
        return list(self)

    def feed(self, engine):
        """The open-loop driver: a runtime Process that generates each
        request lazily and submits it at its simulated arrival time.
        Returns the Process (done when the trace is exhausted)."""
        def _feeder():
            for req in self:
                now = engine.clock.now
                if req.arrival > now:
                    yield req.arrival - now
                engine.submit(req)
        return engine.runtime.process(
            _feeder(), name=f"arrivals:{self.spec.name}")
