"""An SLO tenant fleet: N serving engines on one FabricRuntime.

The third leg of the scale/ subsystem: ``ServeFleet`` runs one
``StagedServeEngine`` per ``FleetTenantSpec`` as tenants of a single
runtime/ledger, fed open-loop by per-tenant ``ArrivalGenerator``s.
Every tenant's prefill *and* base decode ride one shared host path
(``fleet:host``), so the §4.1 concurrency discount, weighted fair
shares, and cross-tenant interference all emerge from the one timeline
— and scaling a tenant's decode out to a ``fleet:replica:<r>`` path
(``Autoscaler`` + the engine's decode replica pool) visibly returns
host bandwidth to everyone's prefill.

Tenant knobs per spec: a ``TraceSpec`` (its load), a TTFT SLO, a QoS
class/weight (fair-share rates), a priority (K-tenant admission
arbitration order: ``FleetAdmissionController`` pauses the
lowest-priority tenant's intake when a higher-priority tenant's SLO is
violated), and optionally an ``AutoscaleConfig`` (its decode
autoscaler, drawing replica paths from the fleet-shared
``ReplicaPool``).

Determinism: arrivals are seeded per tenant, engine compute is the sim
token stream, and every control action (scale, pause) only moves bytes
between paths or defers dispatch — so a tenant's served token streams
are bit-identical across static vs autoscaled vs arbitrated runs of
the same specs (asserted in tests/test_scale.py).

``headline_fleet`` pins the paper-style experiment: a latency tenant
under a 10x diurnal burst next to a steady standard tenant; the static
fleet's attainment collapses during the burst, the autoscaled fleet
holds its SLO (benchmarks/bench_scale.py reports both).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core import hw
from repro.core.fabric import Fabric, Path
from repro.core.runtime import FabricRuntime
from repro.scale.arrivals import ArrivalGenerator, TraceSpec, burst_trace
from repro.scale.autoscale import (AutoscaleConfig, Autoscaler, ReplicaPool,
                                   ttft_attainment)
from repro.serve.engine import Request, ServeTimeModel, StagedServeEngine
from repro.tenancy.admission import AdmittedTenant, FleetAdmissionController
from repro.tenancy.colocation import _OccupancySampler, serve_metrics
from repro.tenancy.qos import LATENCY, QoSPolicy, Tenant


def fleet_fabric(*, host_bw: float = 1000.0, replica_bw: float = 400.0,
                 replicas: int = 3,
                 concurrency_discount: float = 0.1) -> Fabric:
    """The fleet substrate: one shared host path every tenant's prefill
    and base decode contend on, plus ``replicas`` pre-provisioned
    replica-private paths the autoscalers can move decode traffic to.
    Units are abstract (the serve time models speak path-units, not
    bytes); the discount is the §4.1 concurrency penalty."""
    paths = [Path("fleet:host", host_bw, latency=hw.PCIE_LAT, kind="pcie")]
    for r in range(replicas):
        paths.append(Path(f"fleet:replica:{r}", replica_bw,
                          latency=hw.PCIE_LAT, kind="pcie"))
    return Fabric.of(*paths, concurrency_discount=concurrency_discount)


def replica_paths_of(fabric: Fabric) -> List[str]:
    return [name for name in fabric if name.startswith("fleet:replica:")]


@dataclass(frozen=True)
class FleetTenantSpec:
    """One tenant of the fleet: its load, SLO, QoS standing, and
    (optionally) its autoscaling policy."""
    name: str
    trace: TraceSpec
    slo_ttft: float
    tenant_class: str = LATENCY
    weight: float = 1.0
    priority: int = 0
    seed: int = 0
    slots: int = 8
    max_inflight_prefills: int = 4
    autoscale: Optional[AutoscaleConfig] = field(
        default_factory=AutoscaleConfig)

    def __post_init__(self):
        if self.slo_ttft <= 0:
            raise ValueError(f"tenant {self.name}: slo_ttft must be > 0")

    def tenant(self) -> Tenant:
        return Tenant(self.name, self.tenant_class, self.weight,
                      self.priority)


# ----------------------------------------------------------------------
# reports
# ----------------------------------------------------------------------

@dataclasses.dataclass
class TenantReport:
    """One tenant's outcome: serve metrics + SLO attainment + the scale
    trail (engine-side scale events and autoscaler decisions)."""
    name: str
    slo_ttft: float
    attainment: float
    metrics: Dict[str, float]
    scale_events: List[dict]
    autoscaler_events: List[dict]
    peak_replicas: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FleetReport:
    """The fleet run: per-tenant reports on one shared timeline, the
    occupancy attribution, admission-arbitration events, and the
    runtime's executed-event count (the events/s capacity figure)."""
    sim_seconds: float
    tenants: Dict[str, TenantReport]
    occupancy: Dict[str, Dict[str, float]]
    admission_events: List[dict]
    events_processed: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def attainment(self, name: str) -> float:
        return self.tenants[name].attainment


# ----------------------------------------------------------------------
# the fleet
# ----------------------------------------------------------------------

class ServeFleet:
    """N engines, one runtime, one ledger (module docstring). Single
    use: build a fresh fleet per run — engines and arrival generators
    are stateful."""

    def __init__(self, specs: Sequence[FleetTenantSpec], *,
                 fabric: Optional[Fabric] = None,
                 host_bw: float = 1000.0, replica_bw: float = 400.0,
                 replicas: int = 3,
                 prefill_units_per_token: float = 1.0,
                 decode_units_per_slot: float = 4.0,
                 arbitration: bool = False,
                 arbitration_check_every: float = 0.05,
                 sample_every: float = 0.05,
                 vocab: int = 32000,
                 tracer=None):
        if not specs:
            raise ValueError("ServeFleet needs at least one tenant spec")
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        self.specs = list(specs)
        self.fabric = fabric if fabric is not None else fleet_fabric(
            host_bw=host_bw, replica_bw=replica_bw, replicas=replicas)
        if "fleet:host" not in self.fabric:
            raise ValueError("fleet fabric must provide a 'fleet:host' path")
        self.replica_paths = replica_paths_of(self.fabric)
        qos = QoSPolicy.fleet([s.tenant() for s in self.specs])
        self.runtime = FabricRuntime(self.fabric, qos=qos, tracer=tracer)
        tm = ServeTimeModel(
            prefill_path="fleet:host", decode_path="fleet:host",
            prefill_units_per_token=prefill_units_per_token,
            decode_units_per_slot=decode_units_per_slot)
        self.engines: Dict[str, StagedServeEngine] = {}
        self.generators: Dict[str, ArrivalGenerator] = {}
        for i, s in enumerate(self.specs):
            self.engines[s.name] = StagedServeEngine(
                None, None, compute="sim", slots=s.slots,
                runtime=self.runtime, time_model=tm,
                max_inflight_prefills=s.max_inflight_prefills,
                tenant=s.name, decode_pool=True)
            self.generators[s.name] = ArrivalGenerator(
                s.trace, seed=s.seed, vocab=vocab,
                rid_base=(i + 1) * 1_000_000)
        self.arbitration = arbitration
        self.arbitration_check_every = arbitration_check_every
        self.sample_every = sample_every
        self.pool = ReplicaPool(self.replica_paths)
        self.autoscalers: Dict[str, Autoscaler] = {}
        self.controller: Optional[FleetAdmissionController] = None
        self.served: Dict[str, List[Request]] = {}
        self._ran = False

    def run(self, *, autoscale: bool = False,
            max_sim_seconds: Optional[float] = None) -> FleetReport:
        """Start every feeder, engine, and controller on the shared
        clock, drive it to quiescence (or ``max_sim_seconds`` of
        simulated time), and report per-tenant attainment."""
        if self._ran:
            raise RuntimeError("ServeFleet is single-use; build a new one")
        self._ran = True
        rt = self.runtime
        t0, ev0 = rt.clock.now, rt.clock.processed
        feeders = []
        for s in self.specs:
            eng = self.engines[s.name]
            eng.start()
            feeders.append(self.generators[s.name].feed(eng))
        if self.arbitration:
            self.controller = FleetAdmissionController(
                rt,
                [AdmittedTenant(name=s.name, priority=s.priority,
                                slo_ttft=s.slo_ttft,
                                engine=self.engines[s.name],
                                pause=self.engines[s.name].pause_intake,
                                resume=self.engines[s.name].resume_intake)
                 for s in self.specs],
                check_every=self.arbitration_check_every).start()
        if autoscale:
            for s in self.specs:
                if s.autoscale is None:
                    continue
                self.autoscalers[s.name] = Autoscaler(
                    rt, self.engines[s.name], slo_ttft=s.slo_ttft,
                    pool=self.pool, config=s.autoscale,
                    name=f"autoscaler:{s.name}").start()
        sampler = _OccupancySampler(rt, self.sample_every)
        until = None if max_sim_seconds is None else t0 + max_sim_seconds

        def quiescent():
            return (all(f.done for f in feeders)
                    and all(e.idle for e in self.engines.values()))

        rt.clock.run(until=until, stop=quiescent)
        for a in self.autoscalers.values():
            a.stop()
        if self.controller is not None:
            self.controller.stop()
            # a resumed tenant may still hold deferred work: drain it
            rt.clock.run(
                until=None if max_sim_seconds is None
                else rt.clock.now + max_sim_seconds,
                stop=quiescent)
        occupancy = sampler.finish()
        for a in self.autoscalers.values():
            a.release_all()
        elapsed = rt.clock.now - t0
        tenants: Dict[str, TenantReport] = {}
        for s in self.specs:
            eng = self.engines[s.name]
            served, eng.finished = list(eng.finished), []
            self.served[s.name] = served
            ttfts = [ttft for _, ttft in eng.ttft_log]
            auto = self.autoscalers.get(s.name)
            peaks = [e["replicas"] for e in eng.scale_events
                     if e["event"] == "scale_out"]
            tenants[s.name] = TenantReport(
                name=s.name, slo_ttft=s.slo_ttft,
                attainment=ttft_attainment(ttfts, s.slo_ttft),
                metrics=serve_metrics(served, elapsed),
                scale_events=list(eng.scale_events),
                autoscaler_events=list(auto.events) if auto else [],
                peak_replicas=max(peaks, default=0))
        return FleetReport(
            sim_seconds=elapsed,
            tenants=tenants,
            occupancy=occupancy,
            admission_events=(list(self.controller.events)
                              if self.controller else []),
            events_processed=rt.clock.processed - ev0)


# ----------------------------------------------------------------------
# the headline experiment
# ----------------------------------------------------------------------

def headline_specs(*, duration: float = 120.0,
                   autoscale: Optional[AutoscaleConfig] = None,
                   ) -> List[FleetTenantSpec]:
    """The canonical two-tenant burst experiment: ``premium`` (tight
    TTFT SLO, heavy weight, high priority) rides a 10x diurnal burst
    trace; ``standard`` (loose SLO, weight 1) offers steady load."""
    cfg = autoscale if autoscale is not None else AutoscaleConfig()
    return [
        FleetTenantSpec(
            name="premium",
            trace=burst_trace(base_rate=2.0, duration=duration,
                              burst_multiplier=10.0, burst_start=30.0,
                              burst_duration=45.0, diurnal_amplitude=0.25),
            slo_ttft=0.4, tenant_class=LATENCY, weight=8.0, priority=1,
            seed=7, autoscale=cfg),
        FleetTenantSpec(
            name="standard",
            trace=TraceSpec(name="steady", base_rate=2.0, duration=duration,
                            diurnal_amplitude=0.25, diurnal_period=duration),
            slo_ttft=2.0, tenant_class=LATENCY, weight=1.0, priority=0,
            seed=11, autoscale=cfg),
    ]


def headline_fleet(*, duration: float = 120.0,
                   autoscale_cfg: Optional[AutoscaleConfig] = None,
                   **fleet_kw) -> ServeFleet:
    """A fresh fleet wired for the headline run; call
    ``.run(autoscale=False)`` for the static baseline and build another
    for ``.run(autoscale=True)``. The host path is provisioned so the
    burst fits once decode is moved off it (autoscaled holds the SLO)
    but not while decode contends on it (static collapses)."""
    fleet_kw.setdefault("host_bw", 1400.0)
    return ServeFleet(headline_specs(duration=duration,
                                     autoscale=autoscale_cfg), **fleet_kw)
