"""TTFT-driven decode autoscaling on the FabricRuntime.

The control loop the burst trace demands: a periodic runtime process
per tenant watches its TTFT SLO *attainment* (fraction of recent
completions inside the SLO) and decode-path occupancy, and spawns or
retires decode replicas on the tenant's ``StagedServeEngine``
(``add_decode_replica``/``retire_decode_replica`` — runtime Processes,
retired via ``Process.kill()`` + transfer cancel, with the unmoved
remainder re-queued so token streams are bit-identical across scale
events).

Why scaling decode helps TTFT at all: in the fleet topology every
tenant's prefill shares one host path with the base decode traffic.
Spawning a replica *moves* a tenant's decode reads onto a replica-
private path (the base fallback stops serving while extras exist), so
the shared path drains for prefill — the same bytes, a different wire,
which is the paper's multipath guideline applied as a control action.

Hysteresis: scale-out and scale-in have separate cooldowns (out short —
react to a burst; in long — don't flap on noise), and scale-in
additionally requires sustained attainment at target, an empty prefill
backlog, and low occupancy on the newest replica's path. On steady
in-capacity load the autoscaler provably does nothing (tested).

``ReplicaPool`` is the fleet-wide inventory of pre-provisioned replica
paths: autoscalers acquire/release from one shared pool, so two tenants
bursting together contend for real capacity instead of conjuring it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core.fabric import OUT


def ttft_attainment(samples: Sequence[float], slo: float) -> float:
    """Fraction of TTFT samples inside the SLO (1.0 for no samples —
    an idle tenant is not in violation)."""
    if not samples:
        return 1.0
    return sum(1 for x in samples if x <= slo) / len(samples)


@dataclass(frozen=True)
class AutoscaleConfig:
    """Policy knobs for one tenant's autoscaler.

    ``target_attainment``  scale out while the windowed attainment is
                           below this; scale in only at/above it.
    ``window_s``           how far back TTFT completions count.
    ``check_every``        controller sampling period.
    ``out_cooldown``       min seconds between scale-outs (one replica
                           per violation tick, rate-limited).
    ``in_cooldown``        min seconds after the *last scale event in
                           either direction* before a scale-in — the
                           hysteresis that prevents flapping.
    ``occupancy_low``      a replica is retirable only while its path's
                           outbound occupancy is at or below this.
    ``max_replicas``       cap on extra replicas (the pool may be
                           smaller still).
    ``min_samples``        violation verdicts need at least this many
                           samples in the window.
    """
    target_attainment: float = 0.95
    window_s: float = 2.0
    check_every: float = 0.25
    out_cooldown: float = 0.5
    in_cooldown: float = 4.0
    occupancy_low: float = 0.3
    max_replicas: int = 4
    min_samples: int = 4

    def __post_init__(self):
        if not 0.0 < self.target_attainment <= 1.0:
            raise ValueError(f"target_attainment must be in (0, 1], "
                             f"got {self.target_attainment}")
        for name in ("window_s", "check_every", "out_cooldown",
                     "in_cooldown"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        if self.max_replicas < 1:
            raise ValueError("max_replicas must be >= 1")


class ReplicaPool:
    """The fleet's shared inventory of pre-provisioned decode-replica
    paths. FIFO and deterministic: paths are handed out in declaration
    order and returned to the back of the queue."""

    def __init__(self, paths: Sequence[str]):
        self.capacity = len(list(paths))
        self._free: List[str] = list(paths)

    @property
    def free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[str]:
        return self._free.pop(0) if self._free else None

    def release(self, path: str) -> None:
        if path in self._free:
            raise ValueError(f"path {path!r} released twice")
        self._free.append(path)


class Autoscaler:
    """One tenant's decode-replica control loop (see module docstring).

    ``engine`` must be a ``StagedServeEngine`` built with
    ``decode_pool=True``; ``pool`` supplies replica paths (shared across
    the fleet's autoscalers)."""

    def __init__(self, runtime, engine, *, slo_ttft: float,
                 pool: ReplicaPool, config: AutoscaleConfig = AutoscaleConfig(),
                 name: str = "autoscaler"):
        if slo_ttft <= 0:
            raise ValueError(f"slo_ttft must be > 0, got {slo_ttft}")
        self.runtime = runtime
        self.engine = engine
        self.slo = slo_ttft
        self.pool = pool
        self.cfg = config
        self.name = name
        self.events: List[dict] = []
        self._held: List[str] = []           # acquired replica paths, LIFO
        self._last_out = -math.inf
        self._last_in = -math.inf
        self._proc = None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "Autoscaler":
        if self._proc is None or self._proc.done:
            self._proc = self.runtime.every(self.cfg.check_every, self._tick,
                                            name=self.name, start_delay=0.0)
        return self

    def stop(self) -> None:
        """Kill the watcher. Held replicas stay up — the fleet drains
        through them; ``release_all`` returns the paths afterwards."""
        if self._proc is not None:
            self._proc.kill()
            self._proc = None

    def release_all(self) -> None:
        while self.engine.n_decode_replicas > 0:
            rep = self.engine.retire_decode_replica()
            if rep is None:
                break
            if rep.path in self._held:
                self._held.remove(rep.path)
                self.pool.release(rep.path)

    @property
    def replicas(self) -> int:
        return self.engine.n_decode_replicas

    # -- the control loop ------------------------------------------------
    def _attainment(self, now: float):
        recent = [ttft for t, ttft in self.engine.ttft_log
                  if t > now - self.cfg.window_s]
        return recent, ttft_attainment(recent, self.slo)

    def _tick(self) -> None:
        cfg, eng = self.cfg, self.engine
        now = self.runtime.clock.now
        recent, att = self._attainment(now)
        n = eng.n_decode_replicas
        # -- scale out: attainment under target on real evidence --------
        if len(recent) >= cfg.min_samples and att < cfg.target_attainment \
                and n < cfg.max_replicas \
                and now - self._last_out >= cfg.out_cooldown:
            path = self.pool.acquire()
            if path is None:
                self.events.append({"t": now, "event": "pool_exhausted",
                                    "attainment": att})
                return
            eng.add_decode_replica(path)
            self._held.append(path)
            self._last_out = now
            self.events.append({"t": now, "event": "scale_out", "path": path,
                                "replicas": n + 1, "attainment": att})
            return
        # -- scale in: sustained health, idle tail, cold replica --------
        if n > 0 and att >= cfg.target_attainment \
                and eng.prefill_backlog == 0 \
                and now - self._last_out >= cfg.in_cooldown \
                and now - self._last_in >= cfg.in_cooldown:
            newest = self._held[-1] if self._held else None
            if newest is None:
                return
            if self.runtime.occupancy(newest, OUT) > cfg.occupancy_low:
                return
            rep = eng.retire_decode_replica()
            if rep is None:
                return
            if rep.path in self._held:
                self._held.remove(rep.path)
                self.pool.release(rep.path)
            self._last_in = now
            self.events.append({"t": now, "event": "scale_in",
                                "path": rep.path, "replicas": n - 1,
                                "attainment": att})
