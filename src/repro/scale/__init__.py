"""Million-user serving at simulation scale (PR 7).

Three pieces on top of the serve/tenancy stack: trace-driven open-loop
arrival generation (``arrivals``), an SLO tenant fleet sharing one
FabricRuntime (``fleet``), and TTFT-attainment-driven decode
autoscaling (``autoscale``).
"""
from repro.scale.arrivals import (ArrivalGenerator, Burst, LengthSpec,
                                  TraceSpec, burst_trace)
from repro.scale.autoscale import (AutoscaleConfig, Autoscaler, ReplicaPool,
                                   ttft_attainment)
from repro.scale.fleet import (FleetReport, FleetTenantSpec, ServeFleet,
                               TenantReport, fleet_fabric, headline_fleet,
                               headline_specs, replica_paths_of)

__all__ = [
    "ArrivalGenerator", "Burst", "LengthSpec", "TraceSpec", "burst_trace",
    "AutoscaleConfig", "Autoscaler", "ReplicaPool", "ttft_attainment",
    "FleetReport", "FleetTenantSpec", "ServeFleet", "TenantReport",
    "fleet_fabric", "headline_fleet", "headline_specs", "replica_paths_of",
]
