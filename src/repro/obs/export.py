"""Chrome-trace / Perfetto export and text summaries for a Tracer.

``chrome_trace`` renders a ``Tracer``'s spans into the Trace Event
JSON format (the ``{"traceEvents": [...]}`` flavor) that
``chrome://tracing`` and https://ui.perfetto.dev load directly:

- one *process* (pid) per tenant, named ``tenant:<name>`` (tenant-less
  runtime activity — barriers, processes — lands on pid 0,
  ``runtime``);
- within a tenant, one *thread* (tid) per ``path:direction`` track,
  plus a ``phases`` track for consumer-level spans and (on the runtime
  pid) ``barriers`` / ``processes`` tracks;
- every transfer/compute span is a complete (``ph: "X"``) event, and
  each rebalance that changed its rate is an instant (``ph: "i"``)
  annotation inside the span's track carrying the new rate — load the
  trace and the §4.1 discount is *visible* as simultaneous rate steps
  across co-resident flows.

Simulated seconds map to trace microseconds 1:1 (ts = t * 1e6), so a
1.5 s simulation reads as 1.5 s in the viewer.

``summary`` is the text counterpart: per (tenant, path:direction) busy
time, busy fraction, and span counts — the paper-style attribution
table. ``validate_chrome_trace`` is the schema check used by the test
suite and CI on exported files.
"""
from __future__ import annotations

import json
import numbers
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.trace import BARRIER, COMPUTE, PHASE, PROCESS, TRANSFER

_US = 1e6                      # simulated seconds -> trace microseconds
_RUNTIME_PID = 0


def _pid_map(tracer) -> Dict[Optional[str], int]:
    tenants = sorted({s.tenant for s in tracer.spans if s.tenant is not None}
                     | {s.tenant for s in tracer.open_spans()
                        if s.tenant is not None})
    return {tenant: i + 1 for i, tenant in enumerate(tenants)}


def chrome_trace(tracer, *, include_open: bool = True) -> Dict[str, Any]:
    """Render the tracer's spans as a Trace Event JSON document."""
    spans = list(tracer.spans)
    now = tracer.now() if tracer.enabled else 0.0
    if include_open and tracer.enabled:
        spans.extend(tracer.open_spans())
    pids = _pid_map(tracer)
    events: List[Dict[str, Any]] = []
    named_threads: set = set()

    def meta_event(pid: int, name: str, tid: Optional[int] = None,
                   label: str = "") -> None:
        ev: Dict[str, Any] = {"ph": "M", "name": name, "pid": pid,
                              "args": {"name": label}}
        if tid is not None:
            ev["tid"] = tid
        events.append(ev)

    meta_event(_RUNTIME_PID, "process_name", label="runtime")
    for tenant, pid in pids.items():
        meta_event(pid, "process_name", label=f"tenant:{tenant}")

    # stable tids: per pid, tracks are numbered in first-use order
    tids: Dict[Tuple[int, str], int] = {}

    def tid_for(pid: int, track: str) -> int:
        key = (pid, track)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = sum(1 for k in tids if k[0] == pid) + 1
            if (pid, tid) not in named_threads:
                named_threads.add((pid, tid))
                meta_event(pid, "thread_name", tid=tid, label=track)
        return tid

    for span in spans:
        pid = pids.get(span.tenant, _RUNTIME_PID)
        if span.kind in (TRANSFER, COMPUTE):
            track = f"{span.path}:{span.direction}"
        elif span.kind == BARRIER:
            track = "barriers"
        elif span.kind == PROCESS:
            track = "processes"
        else:
            track = "phases"
        tid = tid_for(pid, track)
        t_end = span.t_end if span.t_end is not None else now
        args: Dict[str, Any] = dict(span.meta)
        if span.flow is not None:
            args["flow"] = span.flow
        if span.t_end is None:
            args["open"] = True
        if span.kind == BARRIER:
            events.append({"ph": "i", "s": "t", "name": span.name,
                           "cat": span.kind, "pid": pid, "tid": tid,
                           "ts": span.t_start * _US, "args": args})
            continue
        events.append({"ph": "X", "name": span.name, "cat": span.kind,
                       "pid": pid, "tid": tid, "ts": span.t_start * _US,
                       "dur": max(t_end - span.t_start, 0.0) * _US,
                       "args": args})
        # rate-change annotations: skip the implicit opening 0 and the
        # closing 0 — only genuine rebalances of a live span
        for t, rate in span.rate_timeline[1:]:
            if t >= t_end and rate == 0.0:
                continue
            events.append({"ph": "i", "s": "t", "name": "rate",
                           "cat": "rebalance", "pid": pid, "tid": tid,
                           "ts": t * _US,
                           "args": {"flow": span.flow, "rate": rate}})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump(tracer, path: str, *, include_open: bool = True) -> str:
    """Write the Chrome-trace JSON to ``path`` and return it."""
    doc = chrome_trace(tracer, include_open=include_open)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema-check a Trace Event document; returns problems (empty ==
    valid). Covers what chrome://tracing actually requires: the
    traceEvents list, known phase codes, numeric timestamps, and
    non-negative durations on complete events."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"top level must be a dict with 'traceEvents', got "
                f"{type(doc).__name__}"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return [f"traceEvents must be a list, got {type(evs).__name__}"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not a dict")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "C", "M"):
            problems.append(f"event {i} has unknown ph {ph!r}")
            continue
        if "name" not in ev or not isinstance(ev["name"], str):
            problems.append(f"event {i} ({ph}) missing string name")
        if "pid" not in ev:
            problems.append(f"event {i} ({ph}) missing pid")
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), numbers.Real):
            problems.append(f"event {i} ({ph}) missing numeric ts")
        if "tid" not in ev:
            problems.append(f"event {i} ({ph}) missing tid")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, numbers.Real) or dur < 0:
                problems.append(f"event {i} (X) needs dur >= 0, got {dur!r}")
    return problems


def summary(tracer, *, fabric=None, elapsed: Optional[float] = None) -> str:
    """Text attribution table: busy seconds + busy fraction per
    (tenant, path:direction), plus span counts by kind."""
    fabric = fabric if fabric is not None else tracer.fabric
    if elapsed is None:
        elapsed = tracer.now()
    busy = tracer.busy_units()
    lines = [f"{'tenant':<12} {'track':<22} {'busy_s':>10} {'frac':>7}"]
    for (tenant, path, direction), units in sorted(
            busy.items(), key=lambda kv: (str(kv[0][0]), kv[0][1], kv[0][2])):
        cap = (fabric.direction_capacity(path, direction)
               if fabric is not None and path in fabric else 0.0)
        busy_s = units / cap if cap > 0 else 0.0
        frac = busy_s / elapsed if elapsed > 0 else 0.0
        lines.append(f"{str(tenant or '-'):<12} {path + ':' + direction:<22}"
                     f" {busy_s:>10.4f} {frac:>6.1%}")
    counts: Dict[str, int] = {}
    for s in tracer.spans:
        counts[s.kind] = counts.get(s.kind, 0) + 1
    lines.append("spans: " + ", ".join(
        f"{k}={counts.get(k, 0)}"
        for k in (TRANSFER, COMPUTE, BARRIER, PROCESS, PHASE)))
    return "\n".join(lines)
