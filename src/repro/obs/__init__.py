"""Observability subsystem: tracing, metrics, and trace export.

One shared attribution substrate for every tenant on the
``FabricRuntime`` — see ``obs.trace`` (typed spans from runtime
hooks), ``obs.metrics`` (counters/gauges/histograms + ledger-sampled
occupancy series), and ``obs.export`` (Chrome-trace JSON + text
summaries).
"""
from repro.obs.export import chrome_trace, dump, summary, validate_chrome_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               OccupancyTimeSeries)
from repro.obs.trace import (BARRIER, COMPUTE, NULL_TRACER, PHASE, PROCESS,
                             TRANSFER, NullTracer, Span, Tracer)

__all__ = [
    "BARRIER", "COMPUTE", "NULL_TRACER", "PHASE", "PROCESS", "TRANSFER",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "OccupancyTimeSeries", "NullTracer", "Span", "Tracer",
    "chrome_trace", "dump", "summary", "validate_chrome_trace",
]
