"""Counters, gauges, histograms, and ledger-sampled occupancy series.

The numeric half of the observability subsystem: where ``obs.trace``
attributes *intervals*, this module aggregates *values*. Two bespoke
telemetry paths are re-implemented on top of it with their public APIs
preserved: ``offload.program.OffloadStats`` (counters) and
``tenancy.colocation._OccupancySampler`` (the per-(path, direction,
tenant) occupancy sampler behind ``InterferenceReport``).

``OccupancyTimeSeries`` samples the runtime's active transfers every
``every`` simulated seconds and charges each one's *currently reserved
rate* × the tick to its ``(path, direction, tenant)`` — the ledger's
view of who holds capacity, the same attribution the paper builds by
instrumenting each communication path. ``averages()`` normalizes by
raw capacity × elapsed into busy fractions; with ``keep_series`` the
per-tick points are retained as a time series.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

from repro.core.fabric import OUT


class Counter:
    """A monotonically-growing value. Starts at int 0 so integer
    increments stay integers (callers print these raw)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0

    def inc(self, amount: float = 1) -> None:
        self.value += amount

    def __repr__(self) -> str:
        return f"Counter({self.name}={self.value})"


class Gauge:
    """A point-in-time value (last write wins)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Observed samples with summary stats and percentiles (exact —
    samples are kept; simulation runs are small enough)."""
    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: List[float] = []

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def total(self) -> float:
        return math.fsum(self.samples)

    @property
    def mean(self) -> float:
        return self.total / len(self.samples) if self.samples else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile, ``p`` in [0, 100]."""
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        idx = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[idx]

    def __repr__(self) -> str:
        return (f"Histogram({self.name}, n={self.count}, "
                f"mean={self.mean:.4g})")


class MetricsRegistry:
    """Get-or-create home for named metrics. Each consumer owns its own
    registry (no global state), so tests and tenants stay isolated."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
        return h

    def counter_values(self) -> Dict[str, float]:
        return {name: c.value for name, c in self._counters.items()}

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": self.counter_values(),
            "gauges": {name: g.value for name, g in self._gauges.items()},
            "histograms": {name: {"count": h.count, "mean": h.mean,
                                  "p50": h.percentile(50),
                                  "p99": h.percentile(99)}
                           for name, h in self._histograms.items()},
        }


class OccupancyTimeSeries:
    """Ledger-sampled per-(path, direction, tenant) occupancy.

    Every ``every`` simulated seconds, each active capacity-holding
    transfer is charged ``reserved_rate * every`` units against its
    (path, direction, tenant) — i.e. the sampler integrates the
    ledger's reservations, not wall activity, which is exactly what
    admission control and the paper's path attribution care about.
    Untagged transfers land under ``"untagged"``.

    ``busy`` exposes the legacy OUT-direction shape
    (``{path: {tenant: units}}``) that ``_OccupancySampler`` always
    had; ``finish()`` kills the sampling process and returns the OUT
    busy *fractions* (units / (capacity × elapsed)). ``averages()``
    gives the same for any direction, and with ``keep_series`` each
    tick's per-key reserved rates are retained in ``series``.
    """

    def __init__(self, runtime, every: float = 0.01, *,
                 directions: Tuple[str, ...] = (OUT,),
                 keep_series: bool = False):
        self.runtime = runtime
        self.every = every
        self.directions = directions
        self._busy: Dict[str, Dict[str, Dict[str, float]]] = {
            d: {} for d in directions}
        #: per-tick samples: (t, {(path, direction, tenant): rate})
        self.series: List[Tuple[float, Dict[Tuple[str, str, str],
                                            float]]] = []
        self._keep_series = keep_series
        self._t0 = runtime.clock.now
        self._proc = runtime.every(every, self._sample, start_delay=every,
                                   name="occupancy-sampler")

    @property
    def busy(self) -> Dict[str, Dict[str, float]]:
        return self._busy.get(OUT, {})

    def _sample(self) -> None:
        point: Optional[Dict[Tuple[str, str, str], float]] = (
            {} if self._keep_series else None)
        for t in self.runtime.active_transfers():
            if t.direction not in self._busy or t._res <= 0:
                continue
            tag = t.tenant or "untagged"
            per_path = self._busy[t.direction].setdefault(t.path, {})
            per_path[tag] = per_path.get(tag, 0.0) + t._res * self.every
            if point is not None:
                k = (t.path, t.direction, tag)
                point[k] = point.get(k, 0.0) + t._res
        if point is not None:
            self.series.append((self.runtime.clock.now, point))

    def averages(self, direction: str = OUT) -> Dict[str, Dict[str, float]]:
        elapsed = self.runtime.clock.now - self._t0
        if elapsed <= 0:
            return {}
        out: Dict[str, Dict[str, float]] = {}
        for path, per_tenant in self._busy.get(direction, {}).items():
            cap = self.runtime.fabric.direction_capacity(path, direction)
            if cap <= 0:
                continue
            out[path] = {tenant: units / (cap * elapsed)
                         for tenant, units in per_tenant.items()}
        return out

    def finish(self) -> Dict[str, Dict[str, float]]:
        self._proc.kill()
        return self.averages(OUT)
