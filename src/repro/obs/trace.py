"""Typed span tracing for the fabric runtime.

The paper's method is measurement: §4–§6 instrument every
client/SoC/host communication path and attribute time on each to the
flow that held it. This module is that instrumentation for the
simulated stack — one substrate that every tenant (serve, train,
offload, fleet) shares instead of the bespoke telemetry each layer
used to keep by hand.

``Span``       one attributed interval: kind (transfer / compute /
               barrier / process / phase), identity
               ``(tenant, flow, path, direction)``, ``t_start``/
               ``t_end`` in simulated seconds, and — for capacity-
               holding spans — a ``rate_timeline`` of ``(t, rate)``
               steps. Every fair-share rebalance that changes the
               member's rate appends a step, so a span *is* the
               paper-style time/rate attribution of its flow:
               ``busy_units()`` integrates the timeline.
``Tracer``     collects spans from hooks in ``core/runtime.py``
               (transfer begin / rate change / complete / cancel,
               ``Barrier`` release, ``Process`` start/finish) and
               offers ``phase()`` / ``begin_phase`` for consumer-level
               intervals (a DDP gradient bucket, an offload program).
``NullTracer`` the default: ``enabled = False``. The runtime guards
               every hook site on a cached boolean, so with tracing
               off the hot path pays one attribute load + branch —
               cheap enough that the ``scale/runtime_events_per_s``
               floor is unchanged (gated in scripts/ci.sh).

Tracing is record-only by construction: hooks never touch the clock,
the ledger, or any transfer state, so a traced run is bit-identical
to an untraced one (asserted in tests/test_obs.py).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

TRANSFER = "transfer"
COMPUTE = "compute"
BARRIER = "barrier"
PROCESS = "process"
PHASE = "phase"
KINDS = (TRANSFER, COMPUTE, BARRIER, PROCESS, PHASE)


class Span:
    """One attributed interval on the simulated timeline."""
    __slots__ = ("kind", "name", "tenant", "flow", "path", "direction",
                 "t_start", "t_end", "parent", "meta", "rate_timeline")

    def __init__(self, kind: str, name: str, t_start: float, *,
                 tenant: Optional[str] = None, flow: Optional[str] = None,
                 path: Optional[str] = None, direction: Optional[str] = None,
                 parent: Optional["Span"] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.kind = kind
        self.name = name
        self.tenant = tenant
        self.flow = flow
        self.path = path
        self.direction = direction
        self.t_start = t_start
        self.t_end: Optional[float] = None
        self.parent = parent
        self.meta: Dict[str, Any] = meta if meta is not None else {}
        #: (t, rate) steps; the rate holds from each step until the next
        self.rate_timeline: List[Tuple[float, float]] = []

    @property
    def closed(self) -> bool:
        return self.t_end is not None

    @property
    def duration(self) -> float:
        end = self.t_end if self.t_end is not None else self.t_start
        return end - self.t_start

    def rate_at(self, t: float) -> float:
        """The reserved rate in effect at simulated time ``t`` (the last
        timeline step at or before ``t``; 0 outside the span)."""
        rate = 0.0
        for ts, r in self.rate_timeline:
            if ts > t:
                break
            rate = r
        return rate

    def busy_units(self, until: Optional[float] = None) -> float:
        """Integral of the rate timeline — path units actually moved
        while this span held capacity. For an open span, integrates up
        to ``until`` (required then)."""
        end = self.t_end
        if end is None:
            if until is None:
                raise ValueError(f"open span {self.name!r} needs until=")
            end = until
        total = 0.0
        tl = self.rate_timeline
        for i, (ts, r) in enumerate(tl):
            nxt = tl[i + 1][0] if i + 1 < len(tl) else end
            if nxt > ts and r > 0:
                total += r * (nxt - ts)
        return total

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "name": self.name,
                             "t_start": self.t_start, "t_end": self.t_end}
        for k in ("tenant", "flow", "path", "direction"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.rate_timeline:
            d["rate_timeline"] = list(self.rate_timeline)
        if self.meta:
            d["meta"] = dict(self.meta)
        return d

    def __repr__(self) -> str:
        end = f"{self.t_end:.6g}" if self.t_end is not None else "open"
        return f"Span({self.kind}:{self.name}, {self.t_start:.6g}->{end})"


class NullTracer:
    """The default tracer: every hook is a no-op and ``enabled`` is
    False, so the runtime skips the calls entirely (one cached-bool
    branch per hook site). Also the base class of ``Tracer`` — the two
    share one surface, so call sites never check which one they hold."""

    enabled = False
    spans: Tuple[Span, ...] = ()
    fabric = None

    def _attach(self, runtime) -> None:
        pass

    def now(self) -> float:
        return 0.0

    def open_spans(self) -> List[Span]:
        return []

    def busy_units(self, **kw) -> Dict[Tuple[Optional[str], str, str], float]:
        return {}

    def busy_fraction(self, **kw) -> Dict[Tuple[Optional[str], str, str],
                                          float]:
        return {}

    # -- runtime hooks ---------------------------------------------------
    def on_transfer_start(self, t) -> None:
        pass

    def on_transfer_rate(self, t, now: float, rate: float) -> None:
        pass

    def on_transfer_end(self, t) -> None:
        pass

    def on_barrier_release(self, barrier, now: float) -> None:
        pass

    def on_process_start(self, proc, now: float) -> None:
        pass

    def on_process_end(self, proc, now: float) -> None:
        pass

    # -- consumer-level phases -------------------------------------------
    def begin_phase(self, name: str, *, tenant: Optional[str] = None,
                    parent: Optional[Span] = None, **meta) -> Optional[Span]:
        return None

    def end_phase(self, span: Optional[Span], **meta) -> None:
        pass

    @contextmanager
    def phase(self, name: str, *, tenant: Optional[str] = None,
              **meta) -> Iterator[Optional[Span]]:
        yield None


#: shared default instance — FabricRuntime(tracer=None) binds to this
NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Collects spans from an attached runtime (pass
    ``FabricRuntime(fabric, tracer=Tracer())``) and from consumer
    ``phase()`` calls. ``spans`` holds closed spans in closure order;
    ``open_spans()`` lists what is still in flight."""

    enabled = True

    def __init__(self, clock=None):
        self.clock = clock               # set on attach if not given
        self.fabric = None               # last attached runtime's fabric
        self.spans: List[Span] = []
        self._open_transfers: Dict[int, Span] = {}
        self._open_procs: Dict[int, Span] = {}
        self._open_phases: Dict[int, Span] = {}
        self._stack: List[Span] = []     # phase() context-manager nesting

    def _attach(self, runtime) -> None:
        if self.clock is None:
            self.clock = runtime.clock
        self.fabric = runtime.fabric

    def now(self) -> float:
        return self.clock.now if self.clock is not None else 0.0

    def open_spans(self) -> List[Span]:
        return (list(self._open_transfers.values())
                + list(self._open_procs.values())
                + list(self._open_phases.values()))

    def _close(self, span: Span, t_end: float) -> None:
        span.t_end = t_end
        self.spans.append(span)

    # -- runtime hooks ---------------------------------------------------
    def on_transfer_start(self, t) -> None:
        kind = COMPUTE if hasattr(t, "ops") else TRANSFER
        span = Span(kind, t.flow, t.started_at, tenant=t.tenant, flow=t.flow,
                    path=t.path, direction=t.direction,
                    meta={"amount": t.amount})
        span.rate_timeline.append((t.started_at, 0.0))
        self._open_transfers[id(t)] = span

    def on_transfer_rate(self, t, now: float, rate: float) -> None:
        span = self._open_transfers.get(id(t))
        if span is not None:
            tl = span.rate_timeline
            if tl and tl[-1][0] == now:
                tl[-1] = (now, rate)     # same-instant re-split: last wins
            else:
                tl.append((now, rate))

    def on_transfer_end(self, t) -> None:
        span = self._open_transfers.pop(id(t), None)
        if span is None:
            return
        end = t.finished_at
        tl = span.rate_timeline
        if tl and tl[-1][0] == end:
            tl[-1] = (end, 0.0)
        else:
            tl.append((end, 0.0))
        if t.canceled:
            span.meta["canceled"] = True
            span.meta["remaining"] = t.remaining
        self._close(span, end)

    def on_barrier_release(self, barrier, now: float) -> None:
        span = Span(BARRIER, barrier.name, now,
                    meta={"generation": barrier.generation,
                          "parties": barrier.parties})
        self._close(span, now)

    def on_process_start(self, proc, now: float) -> None:
        self._open_procs[id(proc)] = Span(PROCESS, proc.name, now)

    def on_process_end(self, proc, now: float) -> None:
        span = self._open_procs.pop(id(proc), None)
        if span is None:
            return
        if proc.killed:
            span.meta["killed"] = True
        self._close(span, now)

    # -- consumer-level phases -------------------------------------------
    def begin_phase(self, name: str, *, tenant: Optional[str] = None,
                    parent: Optional[Span] = None, **meta) -> Span:
        span = Span(PHASE, name, self.now(), tenant=tenant, parent=parent,
                    meta=meta)
        self._open_phases[id(span)] = span
        return span

    def end_phase(self, span: Optional[Span], **meta) -> None:
        if span is None:
            return
        self._open_phases.pop(id(span), None)
        if meta:
            span.meta.update(meta)
        self._close(span, self.now())

    @contextmanager
    def phase(self, name: str, *, tenant: Optional[str] = None,
              **meta) -> Iterator[Span]:
        parent = self._stack[-1] if self._stack else None
        span = self.begin_phase(name, tenant=tenant, parent=parent, **meta)
        self._stack.append(span)
        try:
            yield span
        finally:
            self._stack.pop()
            self.end_phase(span)

    # -- attribution -----------------------------------------------------
    def busy_units(self, *, kinds: Tuple[str, ...] = (TRANSFER, COMPUTE),
                   until: Optional[float] = None,
                   ) -> Dict[Tuple[Optional[str], str, str], float]:
        """Path units moved per ``(tenant, path, direction)`` — the
        integral of every span's rate timeline. Open spans are included
        up to ``until`` (default: the clock's now)."""
        if until is None:
            until = self.now()
        out: Dict[Tuple[Optional[str], str, str], float] = {}
        for span in list(self.spans) + list(self._open_transfers.values()):
            if span.kind not in kinds or span.path is None:
                continue
            key = (span.tenant, span.path, span.direction)
            out[key] = out.get(key, 0.0) + span.busy_units(until=until)
        return out

    def busy_fraction(self, *, fabric=None, elapsed: Optional[float] = None,
                      kinds: Tuple[str, ...] = (TRANSFER, COMPUTE),
                      ) -> Dict[Tuple[Optional[str], str, str], float]:
        """``busy_units`` normalized by raw path capacity × elapsed —
        directly comparable to ``InterferenceReport`` occupancy
        attribution (which samples the same quantity from the ledger)."""
        fabric = fabric if fabric is not None else self.fabric
        if fabric is None:
            raise ValueError("busy_fraction needs a fabric (attach a "
                             "runtime or pass fabric=)")
        if elapsed is None:
            elapsed = self.now()
        out: Dict[Tuple[Optional[str], str, str], float] = {}
        for (tenant, path, direction), units in self.busy_units(
                kinds=kinds).items():
            cap = fabric.direction_capacity(path, direction)
            if cap > 0 and elapsed > 0:
                out[(tenant, path, direction)] = units / (cap * elapsed)
        return out
