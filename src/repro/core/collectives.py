"""Multi-path collectives (the paper's §4 guideline, executable).

Implemented with ``shard_map`` + ``lax.ppermute`` so the schedule is
explicit rather than left to XLA:

- ``bidirectional_ring_all_gather`` / ``..._reduce_scatter``:
  two counter-rotating rings each carrying half the payload — paper
  Fig 5's "opposite-direction flows multiplex on a bidirectional link".
  On a TPU torus this doubles effective per-hop bandwidth vs a one-way
  ring.
- ``hierarchical_all_reduce``: reduce-scatter on the fast intra-pod axis,
  all-reduce of the 1/n_fast shard on the slow pod axis, all-gather back
  — the "selectively offload only a small fraction onto the slow path"
  rule (paper: traffic over ③ must stay <= P − N).
- ``compressed_ring_all_reduce``: int8-quantized ring with per-hop
  requantization + final broadcast — the LineFS "compress before the
  slow path" alternative (A1/A2) applied to gradient sync.
- ``chunked`` wrappers: segment a large payload into fixed-size chunks
  (paper Advice #2/#3: large transfers collapse; segment proactively).

Everything has a pure-XLA equivalent (lax.all_gather / psum) used as the
correctness oracle in tests/test_collectives.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import Mesh, PartitionSpec as P


# ----------------------------------------------------------------------
# in-shard primitives (must run inside shard_map)
# ----------------------------------------------------------------------

def ring_all_gather(x: jax.Array, axis: str, *, bidirectional: bool = True) -> jax.Array:
    """In-shard all-gather along `axis`. x: local shard (chunk, ...).
    Returns (n*chunk, ...) in axis-index order."""
    n = jax.lax.axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]

    if not bidirectional:
        def step(carry, _):
            recv = jax.lax.ppermute(carry, axis, perm=bwd)  # pull from right
            return recv, recv
        _, got = jax.lax.scan(step, x, None, length=n - 1)
        # got[j] = shard of rank idx+1+j
        parts = jnp.concatenate([x[None], got], axis=0)     # (n, chunk, ...)
        order = (idx + jnp.arange(n)) % n
        out = jnp.zeros_like(parts).at[order].set(parts)
        return out.reshape((-1,) + x.shape[1:])

    # two half-payload counter-rotating rings
    half = x.shape[0] // 2
    if half == 0 or x.shape[0] % 2:
        return ring_all_gather(x, axis, bidirectional=False)
    xa, xb = x[:half], x[half:]

    def step(carry, _):
        a, b = carry
        a2 = jax.lax.ppermute(a, axis, perm=bwd)   # ring direction 1
        b2 = jax.lax.ppermute(b, axis, perm=fwd)   # ring direction 2
        return (a2, b2), (a2, b2)

    _, (gota, gotb) = jax.lax.scan(step, (xa, xb), None, length=n - 1)
    parts_a = jnp.concatenate([xa[None], gota], axis=0)     # rank idx+j
    parts_b = jnp.concatenate([xb[None], gotb], axis=0)     # rank idx-j
    order_a = (idx + jnp.arange(n)) % n
    order_b = (idx - jnp.arange(n)) % n
    out_a = jnp.zeros_like(parts_a).at[order_a].set(parts_a)
    out_b = jnp.zeros_like(parts_b).at[order_b].set(parts_b)
    out = jnp.concatenate([out_a, out_b], axis=1)           # (n, chunk, ...)
    return out.reshape((-1,) + x.shape[1:])


def ring_reduce_scatter(x: jax.Array, axis: str) -> jax.Array:
    """In-shard reduce-scatter along `axis`. x: full local copy
    (n*chunk, ...); returns this rank's reduced chunk (chunk, ...)."""
    n = jax.lax.axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    chunk = x.shape[0] // n
    xr = x.reshape((n, chunk) + x.shape[1:])
    bwd = [((i + 1) % n, i) for i in range(n)]

    def step(carry, j):
        acc = carry                       # partial sum for chunk (idx+1+j)%n
        nxt = (idx + 1 + j) % n
        acc = acc + xr[nxt]
        acc2 = jax.lax.ppermute(acc, axis, perm=bwd)
        return acc2, None

    # start: send partial of chunk (idx+1); after n-1 hops each rank holds
    # the full sum of its own chunk.
    acc0 = jnp.zeros_like(xr[0])
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(n - 1))
    return acc + xr[idx]


def hierarchical_all_reduce_inner(x: jax.Array, fast_axis: str,
                                  slow_axis: str) -> jax.Array:
    """psum via RS(fast) -> AR(slow, 1/n_fast of bytes) -> AG(fast)."""
    n = jax.lax.axis_size(fast_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat.reshape(n, -1), fast_axis,
                                 scatter_dimension=0, tiled=False)
    shard = jax.lax.psum(shard, slow_axis)
    full = jax.lax.all_gather(shard, fast_axis, axis=0, tiled=False)
    out = full.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape)


# ----------------------------------------------------------------------
# quantized ring all-reduce (gradient compression over the slow path)
# ----------------------------------------------------------------------

def _quant_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_ring_all_reduce_inner(x: jax.Array, axis: str) -> jax.Array:
    """int8 ring all-reduce: RS phase with per-hop quantize/dequant, then
    quantized AG phase. Wire traffic is ~1/4 of fp32 (visible in HLO as
    s8 collective-permutes). Lossy — pair with error feedback upstream."""
    n = jax.lax.axis_size(axis)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis)
    orig_shape, orig_dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    xr = flat.reshape(n, -1)
    bwd = [((i + 1) % n, i) for i in range(n)]

    def rs_step(carry, j):
        acc = carry
        nxt = (idx + 1 + j) % n
        acc = acc + xr[nxt]
        q, s = _quant_int8(acc)
        q = jax.lax.ppermute(q, axis, perm=bwd)
        s = jax.lax.ppermute(s, axis, perm=bwd)
        return _dequant_int8(q, s), None

    acc, _ = jax.lax.scan(rs_step, jnp.zeros_like(xr[0]), jnp.arange(n - 1))
    mine = acc + xr[idx]                     # reduced chunk for rank idx

    # AG phase, also quantized
    q, s = _quant_int8(mine)

    def ag_step(carry, _):
        q, s = carry
        q2 = jax.lax.ppermute(q, axis, perm=bwd)
        s2 = jax.lax.ppermute(s, axis, perm=bwd)
        return (q2, s2), (q2, s2)

    _, (qs, ss) = jax.lax.scan(ag_step, (q, s), None, length=n - 1)
    parts = jnp.concatenate([_dequant_int8(q, s)[None],
                             jax.vmap(_dequant_int8)(qs, ss)], axis=0)
    order = (idx + jnp.arange(n)) % n
    out = jnp.zeros_like(parts).at[order].set(parts).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape).astype(orig_dtype)


# ----------------------------------------------------------------------
# host-callable wrappers (build the shard_map)
# ----------------------------------------------------------------------

def _wrap(fn, mesh: Mesh, in_spec: P, out_spec: P):
    return shard_map(fn, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                     check_vma=False)


def all_gather_bidirectional(x: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    """x sharded P(axis) on dim 0 -> fully replicated gathered array."""
    fn = functools.partial(ring_all_gather, axis=axis, bidirectional=True)
    return _wrap(fn, mesh, P(axis), P())(x)


def all_reduce_hierarchical(x: jax.Array, mesh: Mesh, fast_axis: str,
                            slow_axis: str) -> jax.Array:
    """x replicated per (fast,slow)-shard -> psum over both axes."""
    fn = functools.partial(hierarchical_all_reduce_inner,
                           fast_axis=fast_axis, slow_axis=slow_axis)
    spec = P(*(None for _ in x.shape))
    other = tuple(a for a in mesh.axis_names if a not in (fast_axis, slow_axis))
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(x)


def all_reduce_compressed(x: jax.Array, mesh: Mesh, axis: str) -> jax.Array:
    fn = functools.partial(compressed_ring_all_reduce_inner, axis=axis)
    spec = P(*(None for _ in x.shape))
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(x)


def chunked(fn, x: jax.Array, chunk_bytes: int):
    """Apply collective `fn` to fixed-size segments of dim 0 (paper
    Advice #2/#3: segment large transfers). fn must be shape-preserving."""
    if chunk_bytes <= 0:
        return fn(x)
    itemsize = x.dtype.itemsize
    rows = max(1, chunk_bytes // max(itemsize * int(jnp.prod(jnp.array(x.shape[1:]))), 1))
    if rows >= x.shape[0]:
        return fn(x)
    nchunks = -(-x.shape[0] // rows)
    parts = []
    for i in range(nchunks):
        parts.append(fn(x[i * rows:(i + 1) * rows]))
    return jnp.concatenate(parts, axis=0)
