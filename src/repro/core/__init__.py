"""core: the paper's contribution — multi-path characterization,
planning and collectives for TPU meshes."""
from repro.core import hw
from repro.core.paths import PathSpec, enumerate_paths, collective_bytes_per_chip
from repro.core.planner import Alternative, PathPlanner, PathUse
from repro.core.charz import parse_collectives, summarize_traffic
from repro.core.roofline import RooflineReport, build_report, model_flops_for

__all__ = [
    "hw", "PathSpec", "enumerate_paths", "collective_bytes_per_chip",
    "Alternative", "PathPlanner", "PathUse",
    "parse_collectives", "summarize_traffic",
    "RooflineReport", "build_report", "model_flops_for",
]
