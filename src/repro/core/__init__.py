"""core: the paper's contribution — a unified multi-path fabric,
an event-driven runtime, routing/planning and collectives for TPU
meshes."""
from repro.core import hw
from repro.core.fabric import (Allocation, Alternative, BudgetLedger,
                               Fabric, MultipathRouter, Path, Use,
                               BYTES_PER_S, OPS_PER_S)
from repro.core.runtime import (Event, FabricRuntime, Process, Signal,
                                SimClock, Transfer)
from repro.core.paths import PathSpec, enumerate_paths, collective_bytes_per_chip
from repro.core.charz import parse_collectives, replay, summarize_traffic
from repro.core.roofline import RooflineReport, build_report, model_flops_for

__all__ = [
    "hw",
    # fabric API (canonical)
    "Fabric", "Path", "Use", "Alternative", "Allocation",
    "BudgetLedger", "MultipathRouter", "BYTES_PER_S", "OPS_PER_S",
    # event-driven runtime
    "SimClock", "Event", "Signal", "Transfer", "Process", "FabricRuntime",
    # TPU fabric + traffic model
    "PathSpec", "enumerate_paths", "collective_bytes_per_chip",
    "parse_collectives", "summarize_traffic", "replay",
    "RooflineReport", "build_report", "model_flops_for",
]
