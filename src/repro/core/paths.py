"""TPU rendition of the paper's path model (§2.3/§3, Figure 1).

A mesh exposes several *paths*, each with its own bandwidth, latency,
directionality and sharing group — the TPU mapping of the paper's
①/②/③/③*:

  ici:<axis>   — intra-pod ICI ring on mesh axis `axis`   (paper ①/②)
  dcn:pod      — inter-pod data-center network             (paper ③:
                 slow, shared, interferes with everything crossing it)
  pcie:host    — host<->device staging (checkpoint/offload) (paper ③*:
                 bypasses ICI/DCN but has a weak engine)

`enumerate_paths(mesh)` builds the **Fabric** (core/fabric.py) that the
router/roofline/charz layers consume. Bandwidths are per chip, per
direction; `bidirectional=True` means opposite-direction flows multiplex
(paper Fig 5: READ+WRITE reaching 2x the one-way limit).

``PathSpec`` survives as a compatibility constructor with the historical
positional signature; it returns a fabric ``Path``.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core import hw
from repro.core.fabric import BYTES_PER_S, Fabric, Path


def PathSpec(name: str, kind: str = "generic", axis: Optional[str] = None,
             size: int = 2, bw: float = 1.0, latency: float = 0.0,
             bidirectional: bool = True,
             shared_group: Optional[str] = None) -> Path:
    """Deprecated constructor kept for the pre-Fabric call sites
    (positional order: name, kind, axis, size, bw, latency,
    bidirectional, shared_group). Returns a ``fabric.Path``."""
    return Path(name=name, capacity=bw, units=BYTES_PER_S, latency=latency,
                bidirectional=bidirectional, shared_group=shared_group,
                kind=kind, axis=axis, size=size)


def enumerate_paths(mesh_shape: Dict[str, int]) -> Fabric:
    """mesh_shape: {"pod": 2, "data": 16, "model": 16} (or without pod).
    Returns the TPU Fabric (a Mapping[str, Path], so existing dict-style
    consumers keep working)."""
    fabric = Fabric()
    for axis, size in mesh_shape.items():
        if size <= 1:
            continue
        if axis == "pod":
            fabric.add(Path("dcn:pod", hw.DCN_BW_PER_CHIP,
                            latency=hw.DCN_LAT, kind="dcn", axis="pod",
                            size=size, shared_group="dcn"))
        else:
            fabric.add(Path(f"ici:{axis}",
                            hw.ICI_BW_PER_LINK * hw.ICI_LINKS_PER_AXIS,
                            latency=hw.ICI_LAT, kind="ici", axis=axis,
                            size=size, shared_group="ici"))
    fabric.add(Path("pcie:host", hw.PCIE_BW, latency=hw.PCIE_LAT,
                    kind="pcie", size=1, shared_group="pcie"))
    return fabric


# ----------------------------------------------------------------------
# per-collective traffic model (bytes crossing the path per chip)
# ----------------------------------------------------------------------

def collective_bytes_per_chip(op: str, payload_bytes: float, n: int) -> float:
    """Ring-algorithm traffic for one chip, payload = full (unsharded)
    logical tensor size for all-reduce, the *output* size for all-gather
    and the *input* size for reduce-scatter."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if op == "all-reduce":
        return 2.0 * payload_bytes * frac
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return payload_bytes * frac
    if op == "collective-permute":
        return payload_bytes
    raise ValueError(op)


def collective_time(op: str, payload_bytes: float, path: Path) -> float:
    b = collective_bytes_per_chip(op, payload_bytes, path.size)
    steps = {"all-reduce": 2 * (path.size - 1),
             "all-gather": path.size - 1,
             "reduce-scatter": path.size - 1,
             "all-to-all": path.size - 1,
             "collective-permute": 1}[op]
    return steps * path.latency + b / path.capacity
