"""Communication-path model (paper §2.3/§3, Figure 1 for TPU).

A mesh exposes several *paths*, each with its own bandwidth, latency,
directionality and sharing group — the TPU rendition of the paper's
①/②/③/③*:

  ici:<axis>   — intra-pod ICI ring on mesh axis `axis`   (paper ①/②)
  dcn:pod      — inter-pod data-center network             (paper ③:
                 slow, shared, interferes with everything crossing it)
  pcie:host    — host<->device staging (checkpoint/offload) (paper ③*:
                 bypasses ICI/DCN but has a weak engine)

`enumerate_paths(mesh)` builds the PathSpec table; planner/interference
consume it. Bandwidths are per chip, per direction; `bidirectional=True`
means opposite-direction flows multiplex (paper Fig 5: READ+WRITE
reaching 2x the one-way limit).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import hw


@dataclass(frozen=True)
class PathSpec:
    name: str                 # "ici:data", "dcn:pod", "pcie:host"
    kind: str                 # ici | dcn | pcie
    axis: Optional[str]       # mesh axis this path runs over (None for pcie)
    size: int                 # number of participants along the path
    bw: float                 # bytes/s per chip per direction
    latency: float            # seconds, one hop
    bidirectional: bool       # opposite flows multiplex (2x aggregate)
    shared_group: str         # interference group (paths sharing media)

    def time_for(self, bytes_per_chip: float, *, both_directions: bool = False) -> float:
        """Transfer time. If traffic uses both directions of a
        bidirectional path it still completes in bytes/bw (multiplexed);
        same-direction traffic from two flows halves each flow's share —
        that logic lives in the InterferenceModel."""
        if bytes_per_chip <= 0:
            return 0.0
        return self.latency + bytes_per_chip / self.bw


def enumerate_paths(mesh_shape: Dict[str, int]) -> Dict[str, PathSpec]:
    """mesh_shape: {"pod": 2, "data": 16, "model": 16} (or without pod)."""
    paths: Dict[str, PathSpec] = {}
    for axis, size in mesh_shape.items():
        if size <= 1:
            continue
        if axis == "pod":
            paths["dcn:pod"] = PathSpec(
                name="dcn:pod", kind="dcn", axis="pod", size=size,
                bw=hw.DCN_BW_PER_CHIP, latency=hw.DCN_LAT,
                bidirectional=True, shared_group="dcn")
        else:
            paths[f"ici:{axis}"] = PathSpec(
                name=f"ici:{axis}", kind="ici", axis=axis, size=size,
                bw=hw.ICI_BW_PER_LINK * hw.ICI_LINKS_PER_AXIS,
                latency=hw.ICI_LAT, bidirectional=True,
                shared_group="ici")
    paths["pcie:host"] = PathSpec(
        name="pcie:host", kind="pcie", axis=None, size=1,
        bw=hw.PCIE_BW, latency=hw.PCIE_LAT,
        bidirectional=True, shared_group="pcie")
    return paths


# ----------------------------------------------------------------------
# per-collective traffic model (bytes crossing the path per chip)
# ----------------------------------------------------------------------

def collective_bytes_per_chip(op: str, payload_bytes: float, n: int) -> float:
    """Ring-algorithm traffic for one chip, payload = full (unsharded)
    logical tensor size for all-reduce, the *output* size for all-gather
    and the *input* size for reduce-scatter."""
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if op == "all-reduce":
        return 2.0 * payload_bytes * frac
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return payload_bytes * frac
    if op == "collective-permute":
        return payload_bytes
    raise ValueError(op)


def collective_time(op: str, payload_bytes: float, path: PathSpec) -> float:
    b = collective_bytes_per_chip(op, payload_bytes, path.size)
    steps = {"all-reduce": 2 * (path.size - 1),
             "all-gather": path.size - 1,
             "reduce-scatter": path.size - 1,
             "all-to-all": path.size - 1,
             "collective-permute": 1}[op]
    return steps * path.latency + b / path.bw
