"""Compression substrate (paper LineFS A1/A2: compress before the slow
path; DrTM-KV: small payloads win).

- blockwise int8 quantization (pure-JAX reference; the Pallas kernel in
  kernels/quant is the TPU hot-spot version) used for: gradient sync over
  DCN, checkpoint replication, optimizer-moment storage, KV-cache spill.
- error feedback (residual carry) so lossy gradient sync stays unbiased
  over time.
- the analytic "when does compression win" model from §5.1.
"""
from __future__ import annotations

import math
import zlib
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

try:  # optional: fall back to zlib when the wheel is absent
    import zstandard as zstd
except ImportError:
    zstd = None


# ----------------------------------------------------------------------
# byte codecs — the checkpoint payload compressors. Canonical registry:
# ckpt/checkpoint.py records the codec name in every manifest, and
# offload/compression.py runs these *same* callables as SoC/DCA tenants
# (placement moves the simulated cycles, never the bytes — compressed
# output is bit-identical wherever it runs).
# ----------------------------------------------------------------------

#: codec name -> (extension, compress fn, decompress fn)
BYTE_CODECS: Dict[str, Tuple[str, Callable[[bytes], bytes],
                             Callable[[bytes], bytes]]] = {
    "zstd": (".zst",
             lambda b: zstd.ZstdCompressor(level=3).compress(b),
             lambda b: zstd.ZstdDecompressor().decompress(b)),
    "zlib": (".zz",
             lambda b: zlib.compress(b, 6),
             lambda b: zlib.decompress(b)),
    "none": ("", lambda b: b, lambda b: b),
}


def byte_codec(name: str) -> Tuple[str, Callable[[bytes], bytes],
                                   Callable[[bytes], bytes]]:
    """Look up a byte codec, failing early when the backing wheel is
    absent (a zstd-written checkpoint cannot restore without it)."""
    if name not in BYTE_CODECS:
        raise KeyError(f"unknown codec {name!r} (have {sorted(BYTE_CODECS)})")
    if name == "zstd" and zstd is None:
        raise IOError("codec 'zstd' needs the zstandard module")
    return BYTE_CODECS[name]


def default_codec(compress: bool) -> str:
    if not compress:
        return "none"
    return "zstd" if zstd is not None else "zlib"


class Quantized(NamedTuple):
    q: jax.Array        # int8 payload, same shape as input
    scale: jax.Array    # f32 per-block scales (leading blocks dim)


def quantize_int8_blockwise(x: jax.Array, block: int = 256) -> Quantized:
    """Symmetric per-block int8. Pads to a block multiple internally."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blk = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blk), axis=1) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(blk / scale[:, None]), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=scale)


def dequantize_int8_blockwise(qt: Quantized, shape, dtype=jnp.float32) -> jax.Array:
    flat = (qt.q.astype(jnp.float32) * qt.scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantized_nbytes(qt: Quantized) -> int:
    return qt.q.size + qt.scale.size * 4


class ErrorFeedback(NamedTuple):
    """Residual state for unbiased lossy gradient sync."""
    residual: jax.Array

    @staticmethod
    def init(shape, dtype=jnp.float32):
        return ErrorFeedback(residual=jnp.zeros(shape, dtype))


def compress_with_feedback(g: jax.Array, ef: ErrorFeedback,
                           block: int = 256) -> Tuple[Quantized, ErrorFeedback]:
    """q = Q(g + residual); residual' = (g + residual) - deq(q)."""
    corrected = g.astype(jnp.float32) + ef.residual
    qt = quantize_int8_blockwise(corrected, block)
    deq = dequantize_int8_blockwise(qt, g.shape)
    return qt, ErrorFeedback(residual=corrected - deq)


# ----------------------------------------------------------------------
# §5.1 analytic model: when does compress-then-send win?
# ----------------------------------------------------------------------

def offload_path_bandwidth(P: float, ratio: float) -> float:
    """Paper: A1 file bandwidth over the double-crossed internal link is
    P / (1 + ratio)."""
    return P / (1.0 + ratio)


def compression_wins(N: float, P: float, ratio: float,
                     compress_rate: Optional[float] = None) -> bool:
    """Is compress-and-offload (A1) faster than direct send (A3)?
    Paper threshold: ratio < P/N − 1 (equals 28% on their testbed).
    An optional compressor-throughput cap (wimpy SoC) tightens it."""
    a1 = min(offload_path_bandwidth(P, ratio), N / max(ratio, 1e-12))
    if compress_rate is not None:
        a1 = min(a1, compress_rate)
    return a1 > N


def grad_sync_seconds(nbytes: float, n: int, bw: float, *,
                      ratio: float = 1.0, compress_rate: float = math.inf) -> float:
    """Ring all-reduce time for nbytes with optional compression: wire
    bytes scale by `ratio`, plus quantize/dequantize at `compress_rate`."""
    wire = 2.0 * nbytes * ratio * (n - 1) / n / bw
    comp = 0.0 if math.isinf(compress_rate) else 2.0 * nbytes / compress_rate
    return wire + comp
