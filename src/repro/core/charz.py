"""Characterizer: extract per-path communication traffic from compiled HLO.

This is the paper's measurement apparatus (§3) rebuilt for the dry-run
world: instead of hardware counters (Fig 8/9's PCIe pps), we parse the
compiled module's collective ops, attribute each to the mesh axis it runs
over (ICI vs DCN), and apply the ring-traffic model from core/paths.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*(?:\},\{[^}]*)*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


@dataclass
class CollectiveOp:
    op: str                      # canonical op kind
    result_bytes: int            # size of the result (sum over tuple parts)
    group_size: int              # participants
    axes: Tuple[str, ...]        # mesh axes attributed
    traffic_per_chip: float      # ring-model bytes crossing the path per chip
    line: str = ""


def _parse_shapes(prefix: str) -> int:
    """Sum byte sizes of all typed arrays in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(prefix):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _iota_groups(g: int, s: int, dims: Sequence[int],
                 perm: Optional[Sequence[int]]) -> List[List[int]]:
    import numpy as np
    arr = np.arange(int(np.prod(dims))).reshape(dims)
    if perm:
        arr = arr.transpose(perm)
    return arr.reshape(g, s).tolist()


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) else None
        return _iota_groups(g, s, dims, perm)
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return [[int(x) for x in grp.split(",") if x]
                for grp in m.group(1).split("},{")]
    m = _SRC_TGT_RE.search(line)
    if m:  # collective-permute: each pair is a 2-group for attribution
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(0))
        return [[int(a), int(b)] for a, b in pairs]
    return None


def _axis_strides(mesh_axes: Sequence[Tuple[str, int]]) -> Dict[str, Tuple[int, int]]:
    """row-major device numbering: axis -> (stride, size)."""
    strides = {}
    stride = 1
    for name, size in reversed(mesh_axes):
        strides[name] = (stride, size)
        stride *= size
    return strides


def attribute_axes(group: List[int],
                   mesh_axes: Sequence[Tuple[str, int]]) -> Tuple[str, ...]:
    """Which mesh axes does a replica group span? Detects single axes and
    contiguous axis combinations (uniform-stride groups)."""
    if len(group) <= 1:
        return ()
    g = sorted(group)
    strides = _axis_strides(mesh_axes)
    diffs = {g[i + 1] - g[i] for i in range(len(g) - 1)}
    # exact single-axis match
    for name, (stride, size) in strides.items():
        if diffs == {stride} and len(g) == size:
            return (name,)
    # contiguous multi-axis run (e.g. ("pod","data") fused groups)
    names = [n for n, _ in mesh_axes]
    for i in range(len(names)):
        for j in range(i + 1, len(names) + 1):
            run = names[i:j]
            size = 1
            for n in run:
                size *= strides[n][1]
            inner_stride = strides[run[-1]][0]
            if len(g) == size and diffs and min(diffs) == inner_stride:
                return tuple(run)
    # fallback: attribute by smallest stride observed
    best = None
    for name, (stride, size) in strides.items():
        if any(d % stride == 0 and d // stride < size for d in diffs):
            if best is None or stride < strides[best][0]:
                best = name
    return (best,) if best else tuple(names)


def parse_collectives(hlo_text: str,
                      mesh_axes: Sequence[Tuple[str, int]]) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*((?:\([^)]*\)|\S+))\s+([\w-]+)\(", stripped)
        if not m:
            continue
        opname = m.group(2)
        base = opname.removesuffix("-start")
        if base not in _COLLECTIVES or opname.endswith("-done"):
            continue
        groups = _parse_groups(stripped)
        if groups is None:
            continue
        result_bytes = _parse_shapes(m.group(1))
        n = max(len(g) for g in groups)
        axes = attribute_axes(groups[0] if groups else [], mesh_axes)
        frac = (n - 1) / n if n > 1 else 0.0
        if base == "all-reduce":
            traffic = 2.0 * result_bytes * frac
        elif base == "all-gather":
            traffic = result_bytes * frac            # result is full
        elif base == "reduce-scatter":
            traffic = result_bytes * (n - 1)         # result is 1/n of input
        elif base in ("all-to-all", "ragged-all-to-all"):
            traffic = result_bytes * frac
        else:  # collective-permute
            traffic = result_bytes
            n = 2
        ops.append(CollectiveOp(op=base, result_bytes=result_bytes,
                                group_size=n, axes=axes,
                                traffic_per_chip=traffic, line=stripped[:200]))
    return ops


@dataclass
class TrafficSummary:
    per_path: Dict[str, float]            # path name -> bytes/chip
    per_op: Dict[str, float]              # op kind -> bytes/chip
    op_counts: Dict[str, int]
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total(self) -> float:
        return sum(self.per_path.values())


def summarize_traffic(hlo_text: str,
                      mesh_axes: Sequence[Tuple[str, int]],
                      fabric=None) -> TrafficSummary:
    """Attribute every collective's traffic to its (slowest) path.

    Attribution targets are the path names of `fabric` (a
    ``core.fabric.Fabric``); when omitted, the TPU fabric for
    `mesh_axes` is enumerated (so the names are "dcn:pod"/"ici:<axis>").
    """
    if fabric is None:
        from repro.core.paths import enumerate_paths
        fabric = enumerate_paths(dict(mesh_axes))
    by_axis = {p.axis: p.name for p in fabric.values() if p.axis}
    ops = parse_collectives(hlo_text, mesh_axes)
    per_path: Dict[str, float] = defaultdict(float)
    per_op: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for op in ops:
        # slowest constituent: dcn (pod) dominates ici
        if "pod" in op.axes:
            path = by_axis.get("pod", "dcn:pod")
        elif op.axes:
            axis = op.axes[-1]            # innermost listed axis
            path = by_axis.get(axis, f"ici:{axis}")
        else:
            path = "ici:?"
        per_path[path] += op.traffic_per_chip
        per_op[op.op] += op.traffic_per_chip
        counts[op.op] += 1
    return TrafficSummary(per_path=dict(per_path), per_op=dict(per_op),
                          op_counts=dict(counts), ops=ops)


def replay(summary: TrafficSummary, fabric, clock=None) -> float:
    """Execute a TrafficSummary on the event-driven fabric runtime:
    every path's per-chip bytes become one concurrent transfer, and the
    simulated step time is when the last of them drains.

    Unlike the static per-path division (`bytes / bw` summed per path in
    the roofline), overlap and the §4.1 concurrency discount are
    *emergent*: paths in one ``shared_group`` (e.g. all ICI axes)
    interfere, independent groups (ICI vs DCN vs PCIe) overlap freely.
    Path names not present in `fabric` (e.g. the "ici:?" attribution
    fallback) are skipped. Returns simulated seconds; 0.0 for an empty
    summary. Pass a shared ``clock`` to embed the replay in a larger
    timeline (the elapsed time is still returned)."""
    from repro.core.runtime import FabricRuntime
    rt = FabricRuntime(fabric, clock=clock)
    t0 = rt.clock.now
    transfers = [rt.transfer(name, summary.per_path[name],
                             flow=f"replay:{name}")
                 for name in sorted(summary.per_path)
                 if summary.per_path[name] > 0 and name in fabric]
    if not transfers:
        return 0.0
    # stop at our own completion: a shared clock's later events stay put
    rt.clock.run(stop=lambda: all(t.done for t in transfers))
    return rt.clock.now - t0
