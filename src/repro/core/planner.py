"""The paper's §4.2 optimization guideline, executable.

Given a *functionality* (gradient sync, checkpoint replication, KV get),
the designer:

  1. devises Alternatives — each a bundle of PathUses (bytes crossing
     each path, per direction, per unit of useful work) plus an optional
     endpoint compute limit (the "wimpy SoC" premise);
  2. evaluates and ranks them against system criteria;
  3. greedily combines them until a shared resource saturates.

The per-direction budget model reproduces the paper's findings natively:
  * opposite-direction flows multiplex on a bidirectional link (Fig 5:
    READ+WRITE -> ~2x one-way bandwidth) because they draw from
    different direction budgets;
  * a path that crosses the same link twice (paper path-③) consumes both
    direction budgets at once — the "hidden bottleneck", and the reason
    its traffic must stay <= P − N when sharing with primary traffic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.paths import PathSpec


@dataclass(frozen=True)
class PathUse:
    """Traffic one unit of work places on one path."""
    path: str
    out_bytes: float = 0.0
    in_bytes: float = 0.0


@dataclass
class Alternative:
    """One way to implement the functionality (paper Figure 14/16)."""
    name: str
    uses: List[PathUse]
    compute_rate: float = math.inf     # units/s the endpoint can process
    criteria: Dict[str, float] = field(default_factory=dict)
    # e.g. {"host_cpu": 0.2, "latency_us": 4.6, "net_utilization": 1.0}

    def solo_rate(self, paths: Dict[str, PathSpec]) -> float:
        """Peak units/s using this alternative alone."""
        rate = self.compute_rate
        for u in self.uses:
            bw = paths[u.path].bw
            if u.out_bytes > 0:
                rate = min(rate, bw / u.out_bytes)
            if u.in_bytes > 0:
                rate = min(rate, bw / u.in_bytes)
        return rate


@dataclass
class Allocation:
    alternative: str
    rate: float                        # units/s granted
    bottleneck: str                    # what stopped further allocation


class PathPlanner:
    """Greedy §4.2 combiner over per-direction path budgets."""

    def __init__(self, paths: Dict[str, PathSpec]):
        self.paths = paths

    def _budgets(self) -> Dict[Tuple[str, str], float]:
        b: Dict[Tuple[str, str], float] = {}
        for name, p in self.paths.items():
            b[(name, "out")] = p.bw
            b[(name, "in")] = p.bw if p.bidirectional else 0.0
        return b

    def rank(self, alts: Sequence[Alternative],
             key: str = "rate",
             prefer: Optional[Sequence[str]] = None) -> List[Alternative]:
        """Step 2: rank by solo rate (default) or an explicit criterion
        (lower-is-better for latency_us/host_cpu, higher for the rest)."""
        if prefer:
            order = {n: i for i, n in enumerate(prefer)}
            return sorted(alts, key=lambda a: order.get(a.name, len(order)))
        if key == "rate":
            return sorted(alts, key=lambda a: -a.solo_rate(self.paths))
        sign = 1.0 if key in ("latency_us", "host_cpu") else -1.0
        return sorted(alts, key=lambda a: sign * a.criteria.get(key, math.inf))

    def combine_greedy(self, alts_ranked: Sequence[Alternative],
                       demand: float = math.inf) -> Tuple[List[Allocation], float]:
        """Step 3: give each alternative in order as much rate as the
        remaining budgets allow; stop when demand is met or everything
        saturates. Returns (allocations, total_rate)."""
        budgets = self._budgets()
        allocs: List[Allocation] = []
        total = 0.0
        for alt in alts_ranked:
            if total >= demand:
                break
            rate = min(alt.compute_rate, demand - total)
            bottleneck = "compute" if rate == alt.compute_rate else "demand"
            for u in alt.uses:
                if u.out_bytes > 0:
                    r = budgets[(u.path, "out")] / u.out_bytes
                    if r < rate:
                        rate, bottleneck = r, f"{u.path}:out"
                if u.in_bytes > 0:
                    r = budgets[(u.path, "in")] / u.in_bytes
                    if r < rate:
                        rate, bottleneck = r, f"{u.path}:in"
            if rate <= 0:
                allocs.append(Allocation(alt.name, 0.0, bottleneck))
                continue
            for u in alt.uses:
                budgets[(u.path, "out")] -= rate * u.out_bytes
                budgets[(u.path, "in")] -= rate * u.in_bytes
            total += rate
            allocs.append(Allocation(alt.name, rate, bottleneck))
        return allocs, total

    def slack(self, primary: Alternative, path: str) -> float:
        """The paper's B_slow <= P − N rule: bandwidth left on `path`
        after the primary functionality saturates its own bottleneck."""
        budgets = self._budgets()
        rate = primary.solo_rate(self.paths)
        for u in primary.uses:
            budgets[(u.path, "out")] -= rate * u.out_bytes
            budgets[(u.path, "in")] -= rate * u.in_bytes
        return max(0.0, min(budgets[(path, "out")], budgets[(path, "in")]))


# ----------------------------------------------------------------------
# LineFS §5.1 analytic alternatives (used by ckpt/ and benchmarks)
# ----------------------------------------------------------------------

def linefs_alternatives(N: float, P: float, ratio: float,
                        soc_rate: float = math.inf) -> List[Alternative]:
    """File replication of 1 byte of file data.

    A1: offload via ③  — file crosses the shared internal link twice
        (1x raw in, ratio x compressed out) and the network (ratio).
    A2: offload via ③* — DMA path, bypasses the internal link.
    A3: direct host WRITE via ① — no compression, full network bytes.
    N/P: network / internal-link (PCIe) bandwidth, bytes/s.
    """
    return [
        Alternative("A1", uses=[
            PathUse("internal", out_bytes=1.0 + ratio),   # double crossing
            PathUse("net", out_bytes=ratio),
        ], compute_rate=soc_rate, criteria={"host_cpu": 0.1, "net_utilization": 1.0}),
        Alternative("A2", uses=[
            PathUse("dma", out_bytes=1.0),
            PathUse("net", out_bytes=ratio),
        ], compute_rate=soc_rate, criteria={"host_cpu": 0.1, "net_utilization": 1.0}),
        Alternative("A3", uses=[
            PathUse("net", out_bytes=1.0),
        ], criteria={"host_cpu": 1.0, "net_utilization": ratio}),
    ]


def linefs_paths(N: float, P: float, dma_bw: Optional[float] = None) -> Dict[str, PathSpec]:
    dma = dma_bw if dma_bw is not None else 0.7 * P   # weak DMA engine (§3.3)
    return {
        "net": PathSpec("net", "ici", None, 2, N, 1e-6, True, "net"),
        "internal": PathSpec("internal", "pcie", None, 2, P, 3e-7, True, "pcie"),
        "dma": PathSpec("dma", "pcie", None, 2, dma, 3e-7, True, "pcie"),
    }
