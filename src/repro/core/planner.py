"""DEPRECATED shim — the §4.2 planner now lives in ``core/fabric.py``.

``PathPlanner`` delegated to ``fabric.MultipathRouter`` over a
``Fabric`` built from the path table it is given; ``PathUse`` maps onto
``fabric.Use`` and the LineFS helpers forward to the calibrated fabric
constructors. New code should use the Fabric API directly:

    from repro.core.fabric import Fabric, MultipathRouter, Use
    router = fabric.router()
    allocs, total = router.route(alternatives, demand)

This module keeps the historical import surface so pre-Fabric call
sites (and the paper-calibrated tests) keep working unchanged.
"""
from __future__ import annotations

import math
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fabric import (Allocation, Alternative, Fabric,
                               MultipathRouter, Use, linefs_fabric,
                               linefs_replication_alternatives)


def PathUse(path: str, out_bytes: float = 0.0, in_bytes: float = 0.0) -> Use:
    """Deprecated alias for ``fabric.Use`` (legacy field names)."""
    return Use(path=path, out=out_bytes, in_=in_bytes)


class PathPlanner:
    """Deprecated: a thin wrapper around ``fabric.MultipathRouter``.

    Accepts any ``Mapping[str, Path]`` (including a ``Fabric``); the old
    greedy semantics are preserved exactly — no concurrency discount is
    applied unless the mapping is a Fabric carrying one.
    """

    def __init__(self, paths):
        warnings.warn("PathPlanner is deprecated; use "
                      "repro.core.fabric.MultipathRouter", DeprecationWarning,
                      stacklevel=2)
        fabric = paths if isinstance(paths, Fabric) else Fabric(dict(paths))
        self.fabric = fabric
        self.paths = fabric                  # legacy attribute
        self._router = MultipathRouter(fabric)

    def rank(self, alts: Sequence[Alternative], key: str = "rate",
             prefer: Optional[Sequence[str]] = None) -> List[Alternative]:
        return self._router.rank(alts, key=key, prefer=prefer)

    def combine_greedy(self, alts_ranked: Sequence[Alternative],
                       demand: float = math.inf,
                       ) -> Tuple[List[Allocation], float]:
        return self._router.allocate(alts_ranked, demand)

    def slack(self, primary: Alternative, path: str) -> float:
        return self._router.slack(primary, path)


# ----------------------------------------------------------------------
# LineFS §5.1 helpers (deprecated names; canonical in core/fabric.py)
# ----------------------------------------------------------------------

def linefs_alternatives(N: float, P: float, ratio: float,
                        soc_rate: float = math.inf) -> List[Alternative]:
    return linefs_replication_alternatives(N, P, ratio, soc_rate=soc_rate)


def linefs_paths(N: float, P: float, dma_bw: Optional[float] = None) -> Fabric:
    return linefs_fabric(N, P, dma_bw)
