"""Event-driven fabric runtime: simulated-time transfers over the Fabric.

PR 1 made the path model *static*: ``MultipathRouter.blend`` returns a
closed-form rate and every consumer asks "what is the steady-state
bandwidth?" once. The paper's wins, however, are *temporal* — a
transfer on one path overlapping compute, or a transfer on another
path, under load. This module is the discrete-event timeline that
captures exactly that:

``SimClock``      a monotonically-advancing simulated clock with an
                  event heap (``schedule``/``at``/``cancel``/``run``).
``Transfer``      an in-flight amount (path units) on one ``Path``
                  direction. It reserves its *current rate* in the
                  ``BudgetLedger`` and occupies the path for
                  ``amount / effective_rate`` simulated seconds.
                  Concurrent transfers on a path (or on a path in the
                  same ``shared_group``) fair-share the capacity, and
                  the §4.1 concurrency discount *emerges* — two
                  overlapping flows each see
                  ``capacity * (1 - discount) / 2``, not a constant
                  factor applied by a call site.
``Compute``       the ops/s analog of ``Transfer`` on a *compute*
                  resource (SoC ARM cores, a DCA engine — see
                  fabric.compute_path): total ops fair-share the
                  device roofline in the same ledger, so compute
                  occupancy, QoS weighting and conservation follow the
                  exact rules wires do.
``Process``       a generator-driven coroutine. Yield a ``Transfer``
                  (resume on completion), a number (resume after that
                  many simulated seconds), a ``Signal`` (resume when
                  fired) or another ``Process`` (resume when it
                  returns). Completion callbacks and Processes are how
                  dependent work is driven. ``kill()`` stops a process
                  and cancels the transfer it is waiting on.
``Barrier``       an N-party collective rendezvous: each party yields
                  ``barrier.arrive()``; everyone resumes when the last
                  party arrives (the allreduce synchronization point of
                  a data-parallel step). ``remove_party`` shrinks the
                  membership mid-generation (elastic resize).
``FabricRuntime`` ties a ``Fabric`` + ``BudgetLedger`` + ``SimClock``
                  together and owns rate rebalancing. ``every()`` spawns
                  a periodic process (heartbeats); ``cancel()`` aborts
                  an in-flight transfer, releasing its reservation.

Rebalancing model: active transfers are indexed per interference group
into per-(path, direction) *buckets* (insertion-ordered sets with O(1)
membership). When a transfer joins or leaves, only its own bucket is
recomputed — the group's per-direction capacity (discounted iff more
than one distinct flow is active on the group, counting non-transfer
ledger holders via an O(1)-maintained counter) is split among the
bucket's members by *weighted* max-min fairness. A member whose rate
comes out exactly unchanged is left alone: its progress anchor,
reservation and scheduled completion event all stay — progress is
settled lazily, only when the rate actually changes, which makes the
recomputation idempotent and lets untouched buckets keep their state
bit-identically. If the group's discount flag flips (the holder count
crosses 1), every bucket of the group is recomputed, since the flip
changes every bucket's capacity. ``FabricRuntime(rebalance="global")``
keeps the pre-indexed behavior — recompute all buckets of the group on
any mutation — as a debug oracle; both modes produce bit-identical
(time, rate, remaining) traces by construction (asserted by a property
test in tests/test_simcore.py).

Weights come from the runtime's QoS policy (any object with
``weight(tenant) -> float``; see tenancy/qos.QoSPolicy) applied to each
transfer's ``tenant`` tag — with no policy, or all weights equal, the
split degenerates to the equal shares of the untenanted runtime. Path
``latency`` is served as a pure delay before the transfer starts
occupying capacity. External ledger reservations (e.g. a primary
functionality's pre-reserved traffic) are respected: transfers only
share what the ledger has left — note that after an *external* ledger
change (or a QoS weight change), rates are stale until ``rebalance()``
is called for the affected path, in either mode.

Conservation: every reservation a transfer makes is released when it
finishes, so after a quiescent run the ledger is back to its external
reservations only — asserted in tests/test_runtime.py.
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import (Any, Callable, Dict, Generator, List, Optional, Tuple)

from repro.core.fabric import (BudgetLedger, Fabric, FabricError, IN, OUT,
                               OPS_PER_S)
from repro.obs.trace import NULL_TRACER

#: relative tolerance for "this rebalance did not change your rate":
#: recomputing an untouched bucket reproduces its shares only up to the
#: ledger's accumulated float rounding, and an ulp-level delta must not
#: cancel/reschedule completion events (it would make the global oracle
#: drift from the incremental mode)
_RATE_RTOL = 1e-9


class Event:
    """One scheduled callback. Cancel via ``SimClock.cancel``."""
    __slots__ = ("time", "seq", "fn", "args", "canceled")

    def __init__(self, time: float, seq: int, fn: Callable, args: tuple):
        self.time, self.seq, self.fn, self.args = time, seq, fn, args
        self.canceled = False

    def __repr__(self) -> str:
        return f"Event(t={self.time:.6g}, fn={getattr(self.fn, '__name__', self.fn)})"


class SimClock:
    """Discrete-event clock. Deterministic: ties break by schedule order.

    ``processed`` counts executed (non-canceled) events over the clock's
    lifetime — the numerator of the simulator's own throughput metric
    (events/s of *wall* time, benchmarks/bench_scale.py and
    bench_simcore.py), which is what bounds how much simulated traffic a
    scale experiment can afford.

    Cancellation is lazy (a tombstone flag on the Event; the heap entry
    stays), so a rebalance-heavy run used to grow the heap without
    bound. The clock now counts live tombstones and *compacts* — filters
    the canceled entries out and re-heapifies — once they are both
    numerous (>= ``COMPACT_MIN``) and the majority of the heap.
    Compaction preserves (time, seq) order exactly, so it is invisible
    to the simulation; ``compactions`` counts how often it ran."""

    COMPACT_MIN = 256

    def __init__(self, start: float = 0.0):
        self.now = float(start)
        self.processed = 0
        self.compactions = 0
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._tombstones = 0               # canceled events still heaped

    def schedule(self, delay: float, fn: Callable, *args) -> Event:
        """Schedule ``fn(*args)`` ``delay`` simulated seconds from now."""
        return self.at(self.now + delay, fn, *args)

    def at(self, time: float, fn: Callable, *args) -> Event:
        if time < self.now - 1e-12:
            raise ValueError(f"cannot schedule in the past ({time} < {self.now})")
        ev = Event(max(time, self.now), next(self._seq), fn, args)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def cancel(self, ev: Optional[Event]) -> None:
        if ev is not None and not ev.canceled:
            ev.canceled = True
            self._tombstones += 1
            if (self._tombstones >= self.COMPACT_MIN
                    and self._tombstones * 2 >= len(self._heap)):
                self._compact()

    def _compact(self) -> None:
        self._heap = [e for e in self._heap if not e[2].canceled]
        heapq.heapify(self._heap)
        self._tombstones = 0
        self.compactions += 1

    @property
    def pending(self) -> int:
        return len(self._heap) - self._tombstones

    def run(self, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None) -> float:
        """Process events in time order until the heap drains, ``until``
        is reached, or ``stop()`` returns True (checked after each
        event). With ``until``, the clock always lands on it (even when
        the heap drains early) unless ``stop`` fired first. Returns the
        clock time."""
        stopped = False
        while self._heap:
            time, _, ev = self._heap[0]
            if until is not None and time > until:
                break
            heapq.heappop(self._heap)
            if ev.canceled:
                self._tombstones -= 1
                continue
            # mark executed so a later cancel() of this event is a no-op
            # (it is no longer in the heap — it must not count as a
            # tombstone)
            ev.canceled = True
            self.now = time
            self.processed += 1
            ev.fn(*ev.args)
            if stop is not None and stop():
                stopped = True
                break
        if until is not None and not stopped and self.now < until:
            self.now = until
        return self.now


class Signal:
    """A broadcast condition: processes wait on it, someone fires it.
    Firing wakes every current waiter at the current simulated time."""

    def __init__(self, clock: SimClock):
        self.clock = clock
        self._waiters: List[Callable[[Any], None]] = []

    def wait(self, fn: Callable[[Any], None]) -> None:
        self._waiters.append(fn)

    def fire(self, value: Any = None) -> None:
        waiters, self._waiters = self._waiters, []
        for w in waiters:
            self.clock.schedule(0.0, w, value)


class Transfer:
    """An in-flight amount on one path direction.

    ``rate`` is the current fair share (path units/s); it changes as
    transfers join/leave the interference group. ``max_rate`` caps the
    share (a slow endpoint); the surplus is water-filled back to the
    uncapped flows. ``done`` flips exactly once; callbacks added after
    completion run immediately (same simulated time). A transfer
    aborted via ``FabricRuntime.cancel`` is ``done`` with
    ``canceled=True`` and ``remaining > 0``."""
    _ids = itertools.count()

    def __init__(self, runtime: "FabricRuntime", path: str, amount: float,
                 *, direction: str = OUT, flow: Optional[str] = None,
                 max_rate: float = math.inf, tenant: Optional[str] = None):
        if amount <= 0:
            raise FabricError("transfer amount must be > 0")
        if direction not in (OUT, IN):
            raise FabricError(f"unknown direction {direction!r}")
        self.runtime = runtime
        self.path = path
        self.direction = direction
        self.tenant = tenant
        self.amount = float(amount)
        self.remaining = float(amount)
        self.flow = flow if flow is not None else f"xfer-{next(self._ids)}"
        self.max_rate = max_rate
        self.rate = 0.0
        self.created_at = runtime.clock.now
        self.started_at: Optional[float] = None   # after the latency phase
        self.finished_at: Optional[float] = None
        self.done = False
        self.canceled = False
        self._last_update = runtime.clock.now
        self._event: Optional[Event] = None        # pending completion
        self._res = 0.0                            # currently reserved rate
        self._callbacks: List[Callable[["Transfer"], None]] = []

    # -- observability --------------------------------------------------
    @property
    def elapsed(self) -> float:
        end = self.finished_at if self.done else self.runtime.clock.now
        return end - self.created_at

    def add_callback(self, fn: Callable[["Transfer"], None]) -> None:
        if self.done:
            self.runtime.clock.schedule(0.0, fn, self)
        else:
            self._callbacks.append(fn)

    def __repr__(self) -> str:
        state = ("canceled" if self.canceled else "done") if self.done \
            else f"{self.remaining:.3g} left @ {self.rate:.3g}/s"
        return f"Transfer({self.path}:{self.direction}, {self.amount:.3g}, {state})"


class Compute(Transfer):
    """An in-flight batch of work on one *compute* resource — the ops/s
    analog of ``Transfer`` (paper premise: the off-path SoC computes,
    it does not just move bytes).

    The resource is an ops/s ``Path`` (see fabric.compute_path /
    dca_path): ``amount`` is total ops, ``rate`` the current fair share
    of the device's roofline, and the reservation lives in the same
    ``BudgetLedger`` as every wire — so compute occupancy shows up in
    ``FabricRuntime.occupancy()``, QoS weights apply per tenant, the
    §4.1 discount emerges on a ``shared_group`` (e.g. SoC cores sharing
    a memory system with the DMA engine), and conservation is the same
    invariant (asserted in tests/test_offload.py). ``ops``/``ops_done``
    are the domain-named views of amount/progress."""
    _ids = itertools.count()

    def __init__(self, runtime: "FabricRuntime", resource: str, ops: float,
                 *, flow: Optional[str] = None, max_rate: float = math.inf,
                 tenant: Optional[str] = None):
        flow = flow if flow is not None else f"comp-{next(self._ids)}"
        super().__init__(runtime, resource, ops, direction=OUT, flow=flow,
                         max_rate=max_rate, tenant=tenant)

    @property
    def ops(self) -> float:
        return self.amount

    @property
    def ops_done(self) -> float:
        return self.amount - self.remaining

    def __repr__(self) -> str:
        state = ("canceled" if self.canceled else "done") if self.done \
            else f"{self.remaining:.3g} ops left @ {self.rate:.3g}/s"
        return f"Compute({self.path}, {self.amount:.3g} ops, {state})"


class Process:
    """Generator-driven coroutine on a runtime (see module docstring for
    the yield protocol). ``result`` is the generator's return value."""

    def __init__(self, runtime: "FabricRuntime",
                 gen: Generator[Any, Any, Any], name: str = "proc"):
        self.runtime = runtime
        self.gen = gen
        self.name = name
        self.done = False
        self.killed = False
        self.result: Any = None
        self._waiting: Any = None           # what the process is blocked on
        self._waiters: List[Callable[[Any], None]] = []
        if runtime._trace:
            runtime.tracer.on_process_start(self, runtime.clock.now)
        runtime.clock.schedule(0.0, self._advance, None)

    def kill(self) -> None:
        """Stop the process. The transfer it is waiting on (if any) is
        canceled — its reservation goes back to the ledger — and
        processes joined on this one resume with ``result=None``."""
        if self.done:
            return
        self.done = True
        self.killed = True
        waiting, self._waiting = self._waiting, None
        if isinstance(waiting, Transfer) and not waiting.done:
            self.runtime.cancel(waiting)
        self.gen.close()
        if self.runtime._trace:
            self.runtime.tracer.on_process_end(self, self.runtime.clock.now)
        waiters, self._waiters = self._waiters, []
        for w in waiters:
            self.runtime.clock.schedule(0.0, w, None)

    def _advance(self, send_value: Any) -> None:
        if self.done:
            return
        self._waiting = None
        try:
            item = self.gen.send(send_value)
        except StopIteration as e:
            self.done = True
            self.result = e.value
            if self.runtime._trace:
                self.runtime.tracer.on_process_end(
                    self, self.runtime.clock.now)
            waiters, self._waiters = self._waiters, []
            for w in waiters:
                self.runtime.clock.schedule(0.0, w, self.result)
            return
        self._wait_on(item)

    def _wait_on(self, item: Any) -> None:
        clock = self.runtime.clock
        self._waiting = item
        if isinstance(item, Transfer):
            item.add_callback(lambda t: self._advance(t))
        elif isinstance(item, Process):
            if item.done:
                clock.schedule(0.0, self._advance, item.result)
            else:
                item._waiters.append(self._advance)
        elif isinstance(item, Signal):
            item.wait(self._advance)
        elif isinstance(item, (int, float)):
            if item < 0:
                raise ValueError(f"process {self.name}: negative delay {item}")
            clock.schedule(float(item), self._advance, None)
        else:
            raise TypeError(
                f"process {self.name} yielded {type(item).__name__}; expected "
                "Transfer, Process, Signal, or a delay in seconds")

    def __repr__(self) -> str:
        state = ("killed" if self.killed else "done") if self.done else "running"
        return f"Process({self.name}, {state})"


class Barrier:
    """An N-party collective rendezvous on simulated time.

    Each party yields ``barrier.arrive()``; when the last party arrives
    the barrier *releases*: ``on_release(generation)`` runs first
    (synchronously — the place for the step's bookkeeping), then every
    waiter resumes at the same simulated instant. The barrier is
    cyclic: after a release it is immediately reusable for the next
    generation. ``remove_party`` shrinks the membership mid-generation
    (a node died); if the survivors are all already waiting, the
    barrier releases so they are not stranded behind the dead party.
    """

    def __init__(self, runtime: "FabricRuntime", parties: int, *,
                 on_release: Optional[Callable[[int], None]] = None,
                 name: str = "barrier"):
        if parties < 1:
            raise ValueError(f"barrier {name}: parties must be >= 1")
        self.runtime = runtime
        self.parties = parties
        self.name = name
        self.generation = 0
        self._count = 0
        self._signal = runtime.signal()
        self._on_release = on_release

    @property
    def waiting(self) -> int:
        return self._count

    def arrive(self):
        """Register one arrival. Returns a yieldable: the last arriver
        resumes immediately (after releasing everyone), earlier
        arrivers resume when the barrier releases."""
        self._count += 1
        if self._count >= self.parties:
            self._release()
            return 0.0
        return self._signal

    def remove_party(self, n: int = 1) -> None:
        if n > self.parties:
            raise ValueError(
                f"barrier {self.name}: removing {n} of {self.parties} parties")
        self.parties -= n
        if 0 < self.parties <= self._count:
            self._release()

    def _release(self) -> None:
        self._count = 0
        self.generation += 1
        rt = self.runtime
        if rt._trace:
            rt.tracer.on_barrier_release(self, rt.clock.now)
        if self._on_release is not None:
            self._on_release(self.generation)
        sig, self._signal = self._signal, self.runtime.signal()
        sig.fire(self.generation)

    def __repr__(self) -> str:
        return (f"Barrier({self.name}, {self._count}/{self.parties} waiting, "
                f"gen={self.generation})")


class FabricRuntime:
    """A Fabric executing in simulated time.

    Owns a ``SimClock`` and a ``BudgetLedger``; ``transfer()`` starts a
    flow, ``process()`` spawns a coroutine, ``signal()`` makes a wait
    condition. The ledger may carry external (non-transfer)
    reservations — transfers share only the remaining budget, and an
    external holder counts toward the §4.1 discount.

    ``qos`` is an optional tenancy policy: any object exposing
    ``weight(tenant) -> float`` (see tenancy/qos.QoSPolicy). Transfers
    tagged with a ``tenant`` then fair-share each (path, direction) in
    proportion to their tenant's weight — a latency-class serve tenant
    can be promised most of a path a throughput-class train tenant is
    also using. Untagged transfers weigh 1.0.

    ``rebalance`` selects the fair-share recomputation strategy:
    ``"incremental"`` (default) touches only the mutated
    (path, direction) bucket; ``"global"`` recomputes every bucket of
    the mutated group on every mutation — the old behavior, kept as a
    bit-identical debug oracle (see the module docstring).

    ``tracer`` is an optional ``obs.trace.Tracer``: when attached, the
    runtime emits typed spans at transfer begin / rate change /
    complete / cancel, at ``Barrier`` release, and around ``Process``
    lifetimes (see src/repro/obs/). The default is the no-op
    ``NULL_TRACER`` and the hook sites are guarded on a cached bool.
    """

    def __init__(self, fabric: Fabric, *, clock: Optional[SimClock] = None,
                 ledger: Optional[BudgetLedger] = None, qos=None,
                 rebalance: str = "incremental", tracer=None):
        if rebalance not in ("incremental", "global"):
            raise ValueError(
                f"rebalance must be 'incremental' or 'global', got "
                f"{rebalance!r}")
        self.fabric = fabric
        self.clock = clock if clock is not None else SimClock()
        self.ledger = ledger if ledger is not None else fabric.ledger()
        self.qos = qos
        self.rebalance_mode = rebalance
        # observability: hook sites below fire only when a real (enabled)
        # tracer is attached — _trace caches the flag so the hot path
        # pays one attribute load + branch with tracing off (the
        # scale/runtime_events_per_s floor is gated on this in ci.sh).
        # Tracing is record-only: hooks never touch clock/ledger state,
        # so traced runs are bit-identical to untraced ones.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._trace = bool(self.tracer.enabled)
        if self._trace:
            self.tracer._attach(self)
        # group -> (path, direction) -> insertion-ordered set of active
        # (capacity-holding) transfers: the bucket index. Dict-as-set
        # gives O(1) add/remove/contains with deterministic order.
        self._buckets: Dict[str, Dict[Tuple[str, str],
                                      Dict[Transfer, None]]] = {}
        # group -> flow -> active-member count (distinct-flow counter
        # for the discount check, no set rebuilds)
        self._member_flows: Dict[str, Dict[str, int]] = {}
        # group -> discount flag applied at the last rebalance; a flip
        # dirties every bucket of the group
        self._discounted: Dict[str, bool] = {}
        # group -> buckets mutated since the group's queued rebalance
        self._dirty: Dict[str, set] = {}
        # groups with a same-instant rebalance event already queued
        self._rebalance_pending: set = set()
        # path -> group cache (lazily extended if the fabric grows)
        self._group_of: Dict[str, str] = {}

    # -- API ------------------------------------------------------------
    def transfer(self, path: str, amount: float, *, direction: str = OUT,
                 flow: Optional[str] = None, max_rate: float = math.inf,
                 delay: float = 0.0, tenant: Optional[str] = None,
                 on_complete: Optional[Callable[[Transfer], None]] = None,
                 ) -> Transfer:
        """Start moving ``amount`` (path units) over ``path``. The
        path's ``latency`` (plus ``delay``) is served first without
        holding capacity; then the transfer joins the fair-share pool
        (weighted by ``tenant`` under a QoS policy).
        """
        if path not in self.fabric:
            raise FabricError(f"unknown path {path!r} "
                              f"(fabric has {sorted(self.fabric)})")
        p = self.fabric[path]
        if direction == IN and not p.bidirectional:
            raise FabricError(f"path {path} has no {IN} budget")
        t = Transfer(self, path, amount, direction=direction, flow=flow,
                     max_rate=max_rate, tenant=tenant)
        return self._dispatch(t, delay + p.latency, on_complete)

    def compute(self, resource: str, ops: float, *,
                flow: Optional[str] = None, max_rate: float = math.inf,
                delay: float = 0.0, tenant: Optional[str] = None,
                on_complete: Optional[Callable[[Transfer], None]] = None,
                ) -> Compute:
        """Execute ``ops`` operations on a compute resource (an ops/s
        path — fabric.compute_path / dca_path). The resource's
        ``latency`` models dispatch cost (doorbell/DMA descriptor for a
        DCA engine, IPI for the ARM cores); then the work joins the
        per-resource fair-share pool like any flow: concurrent programs
        on one SoC split its roofline by QoS weight, and the
        reservation is conserving in the shared ledger."""
        if resource not in self.fabric:
            raise FabricError(f"unknown compute resource {resource!r} "
                              f"(fabric has {sorted(self.fabric)})")
        p = self.fabric[resource]
        if p.units != OPS_PER_S:
            raise FabricError(
                f"{resource} is a {p.units} path, not a compute resource "
                f"(expected {OPS_PER_S}; see fabric.compute_path)")
        c = Compute(self, resource, ops, flow=flow, max_rate=max_rate,
                    tenant=tenant)
        return self._dispatch(c, delay + p.latency, on_complete)

    def _dispatch(self, t: Transfer, lead: float,
                  on_complete: Optional[Callable[[Transfer], None]]):
        if on_complete is not None:
            t.add_callback(on_complete)
        if lead > 0:
            self.clock.schedule(lead, self._begin, t)
        else:
            self._begin(t)
        return t

    def process(self, gen: Generator, name: str = "proc") -> Process:
        return Process(self, gen, name=name)

    def signal(self) -> Signal:
        return Signal(self.clock)

    def barrier(self, parties: int, *,
                on_release: Optional[Callable[[int], None]] = None,
                name: str = "barrier") -> Barrier:
        return Barrier(self, parties, on_release=on_release, name=name)

    def barrier_pool(self, count: int, parties: int, *,
                     name: str = "barrier",
                     on_release: Optional[Callable[[int, int], None]] = None,
                     ) -> List[Barrier]:
        """``count`` independent cyclic barriers over the same ``parties``
        membership — the rendezvous set of a staggered collective, where
        each slice of the work (a DDP gradient bucket, a pipeline stage)
        closes on its own barrier so slices can be in flight
        concurrently while each still synchronizes all parties. The
        pool's barriers are reused generation after generation like any
        cyclic Barrier; ``on_release(index, generation)`` identifies
        which slice just closed."""
        if count < 1:
            raise ValueError(f"barrier pool {name}: count must be >= 1")
        pool: List[Barrier] = []
        for i in range(count):
            hook = None if on_release is None else \
                (lambda gen, i=i: on_release(i, gen))
            pool.append(Barrier(self, parties, on_release=hook,
                                name=f"{name}{i}"))
        return pool

    def every(self, interval: float, fn: Callable[[], None], *,
              name: str = "periodic",
              start_delay: Optional[float] = None) -> Process:
        """Spawn a process calling ``fn()`` every ``interval`` simulated
        seconds (first call after ``start_delay``, default ``interval``)
        until killed — heartbeats, samplers, watchdogs. Remember to
        ``kill()`` it (or run the clock with a ``stop``/``until``), or
        the event heap never drains."""
        if interval <= 0:
            raise ValueError(f"periodic {name}: interval must be > 0")

        def _loop():
            yield interval if start_delay is None else start_delay
            while True:
                fn()
                yield interval

        return self.process(_loop(), name=name)

    def cancel(self, t: Transfer) -> None:
        """Abort an in-flight transfer: settle its progress, release its
        reservation back to the ledger, rebalance the survivors. The
        transfer ends ``done`` with ``canceled=True`` and whatever
        ``remaining`` it had; completion callbacks still fire (waiters
        must not hang) and can inspect ``canceled``."""
        if t.done:
            return
        group = self._group(t.path)
        key = (t.path, t.direction)
        now = self.clock.now
        members = self._buckets.get(group, {}).get(key)
        if members is not None and t in members:
            dt = now - t._last_update
            if dt > 0 and t.rate > 0:
                t.remaining = max(0.0, t.remaining - t.rate * dt)
            t._last_update = now
            self._release(t)
            self._drop_member(group, key, t)
        t.canceled = True
        t.done = True
        t.finished_at = now
        self.clock.cancel(t._event)
        t._event = None
        if self._trace:
            self.tracer.on_transfer_end(t)
        callbacks, t._callbacks = t._callbacks, []
        for fn in callbacks:
            fn(t)
        self._queue_rebalance(group, key)

    def active_transfers(self, path: Optional[str] = None) -> List[Transfer]:
        """In-flight capacity-holding transfers, straight off the bucket
        index (no scans): all of them, or those on one ``path`` (its OUT
        bucket then its IN bucket)."""
        if path is None:
            return [t for buckets in self._buckets.values()
                    for members in buckets.values() for t in members]
        buckets = self._buckets.get(self._group(path))
        if not buckets:
            return []
        out: List[Transfer] = []
        for key in ((path, OUT), (path, IN)):
            members = buckets.get(key)
            if members:
                out.extend(members)
        return out

    def weight_of(self, tenant: Optional[str]) -> float:
        """A tenant's QoS weight under the runtime's policy (1.0 with no
        policy; the policy's default for unregistered tenants)."""
        if self.qos is None:
            return 1.0
        return float(self.qos.weight(tenant))

    def occupancy(self, path: str, direction: str = OUT,
                  *, by_tenant: bool = False):
        """Fraction of a path direction's raw capacity currently held in
        the ledger by in-flight transfers — live occupancy, the input to
        admission control and ledger-aware staging choices. With
        ``by_tenant``, a dict attributing the fraction per tenant tag
        (untagged transfers land under ``None``)."""
        cap = self.fabric.direction_capacity(path, direction)
        if cap <= 0:
            return {} if by_tenant else 0.0
        held: Dict[Optional[str], float] = {}
        members = self._buckets.get(self._group(path), {}).get(
            (path, direction))
        if members:
            for t in members:
                if t._res > 0:
                    held[t.tenant] = held.get(t.tenant, 0.0) + t._res
        if by_tenant:
            return {k: v / cap for k, v in held.items()}
        return sum(held.values()) / cap

    def rebalance(self, path: Optional[str] = None) -> None:
        """Re-split capacity after an *external* ledger change (e.g. a
        primary functionality released its reservation). Transfer
        completions rebalance automatically; the ledger has no way to
        notify the runtime about non-transfer releases, so a transfer
        stalled behind an external reservation stays at rate 0 until
        this is called for its path (or for all groups, with no
        argument). Recomputes every bucket of the group, in either
        rebalance mode."""
        if path is not None:
            self._rebalance(self._group(path))
        else:
            for group in list(self._buckets):
                self._rebalance(group)

    # -- mechanics ------------------------------------------------------
    def _group(self, path: str) -> str:
        g = self._group_of.get(path)
        if g is None:
            g = self._group_of[path] = self.fabric[path].group
        return g

    def _begin(self, t: Transfer) -> None:
        if t.done:          # canceled during the latency phase
            return
        now = self.clock.now
        t.started_at = now
        t._last_update = now
        group = self._group(t.path)
        key = (t.path, t.direction)
        self._buckets.setdefault(group, {}).setdefault(key, {})[t] = None
        mf = self._member_flows.setdefault(group, {})
        mf[t.flow] = mf.get(t.flow, 0) + 1
        if self._trace:
            self.tracer.on_transfer_start(t)
        self._queue_rebalance(group, key)

    def _complete(self, t: Transfer) -> None:
        if t.done:
            return
        group = self._group(t.path)
        key = (t.path, t.direction)
        t.remaining = 0.0
        t.done = True
        t.finished_at = self.clock.now
        self.clock.cancel(t._event)
        t._event = None
        self._release(t)
        self._drop_member(group, key, t)
        if self._trace:
            self.tracer.on_transfer_end(t)
        callbacks, t._callbacks = t._callbacks, []
        for fn in callbacks:
            fn(t)
        self._queue_rebalance(group, key)

    def _drop_member(self, group: str, key: Tuple[str, str],
                     t: Transfer) -> None:
        """O(1) removal from the bucket index + flow counter. Empty
        buckets are deleted eagerly so bucket iteration order stays
        'creation order among currently-populated buckets'."""
        buckets = self._buckets[group]
        members = buckets[key]
        del members[t]
        if not members:
            del buckets[key]
            if not buckets:
                del self._buckets[group]
        mf = self._member_flows[group]
        c = mf[t.flow] - 1
        if c <= 0:
            del mf[t.flow]
            if not mf:
                del self._member_flows[group]
        else:
            mf[t.flow] = c

    def _queue_rebalance(self, group: str, key: Tuple[str, str]) -> None:
        """Coalesce fair-share recomputation to one event per group per
        simulated instant: a fleet issuing hundreds of same-timestamp
        transfers (or a decode step sharding across a replica pool)
        triggers one rebalance instead of one per mutation. Deferral is
        invisible in simulated time — the event runs at the same
        timestamp, after every same-instant join/leave, before the
        clock advances. The mutated (path, direction) is recorded so
        the incremental mode recomputes only the dirty buckets."""
        self._dirty.setdefault(group, set()).add(key)
        if group in self._rebalance_pending:
            return
        self._rebalance_pending.add(group)
        self.clock.schedule(0.0, self._run_queued_rebalance, group)

    def _run_queued_rebalance(self, group: str) -> None:
        self._rebalance_pending.discard(group)
        dirty = self._dirty.pop(group, None)
        if self.rebalance_mode == "global":
            self._rebalance(group)
        else:
            self._rebalance(group, only=dirty)

    def _release(self, t: Transfer) -> None:
        if t._res > 0:
            kw = {"out": t._res} if t.direction == OUT else {"in_": t._res}
            self.ledger.release(t.path, flow=t.flow, **kw)
            t._res = 0.0

    def _group_discounted(self, group: str) -> bool:
        """The §4.1 discount applies iff more than one distinct flow is
        on the group: active member flows (counted incrementally in
        ``_member_flows``) united with external ledger holders (the
        ledger's O(1) holder index — which also contains the members'
        own reservations, so the union needs no set build). Early-exits
        after at most two comparisons."""
        if self.fabric.concurrency_discount <= 0.0:
            return False
        mf = self._member_flows.get(group)
        lh = self.ledger.group_holders(group)
        if mf:
            if len(mf) > 1:
                return True
            only = next(iter(mf))
            for f in lh:               # holder flows are distinct keys,
                if f != only:          # so this breaks within 2 steps
                    return True
            return False
        return len(lh) > 1

    def _rebalance(self, group: str, only: Optional[set] = None) -> None:
        """Recompute fair shares for the group's buckets — all of them
        (``only=None``: the public ``rebalance()``, the global mode,
        and any rebalance where the discount flag flips) or just the
        dirty ones. Buckets whose inputs did not change recompute to
        exactly the same rates and are skipped member-by-member, so
        processing a clean bucket is a no-op — which is what makes the
        global mode a bit-identical oracle for the incremental mode."""
        buckets = self._buckets.get(group)
        if not buckets:
            return
        discounted = self._group_discounted(group)
        if discounted != self._discounted.get(group):
            only = None                # capacity changed for every bucket
        self._discounted[group] = discounted
        for key in list(buckets):
            if only is not None and key not in only:
                continue
            members = buckets.get(key)
            if members:
                self._rebalance_bucket(key, members, discounted)

    def _rebalance_bucket(self, key: Tuple[str, str],
                          members: Dict[Transfer, None],
                          discounted: bool) -> None:
        """Weighted max-min fair split of one (path, direction) bucket:
        each flow's share is proportional to its tenant's QoS weight,
        and a max_rate-capped flow's surplus is water-filled back to the
        unsaturated flows. All weights 1 (or no policy) reduces to the
        equal split. Members whose recomputed rate is unchanged (to a
        relative epsilon — recomputing a clean bucket can reproduce the
        same shares only up to the ledger's accumulated rounding, and
        an ulp-level "change" must not reschedule events) keep their
        reservation, progress anchor and completion event; changed
        members are settled at the old rate and rescheduled, and their
        reservation deltas are applied to the ledger in one per-flow
        aggregated pass."""
        path, direction = key
        fabric = self.fabric
        clock = self.clock
        now = clock.now
        cap = fabric.direction_capacity(path, direction)
        if discounted:
            cap *= 1.0 - fabric.concurrency_discount
        ts = list(members)
        held = 0.0
        for t in ts:
            held += t._res
        # what the bucket may split: capacity minus everyone else's
        # reservations (external holders + other buckets never share a
        # (path, direction) key, so subtracting our own holdings back
        # out isolates them)
        avail = max(0.0, cap - (self.ledger.reserved(path, direction) - held))
        weights = {id(t): self.weight_of(t.tenant) for t in ts}
        remaining_w = sum(weights.values())
        # ascending max_rate-per-weight: a flow that saturates its cap
        # below its proportional share frees surplus for all flows
        # still unassigned
        new_rate: Dict[int, float] = {}
        for t in sorted(ts, key=lambda t: t.max_rate / weights[id(t)]):
            w = weights[id(t)]
            share = avail * w / remaining_w if remaining_w > 0 else 0.0
            r = max(0.0, min(share, t.max_rate))
            new_rate[id(t)] = r
            avail -= r
            remaining_w -= w
        deltas: Dict[str, float] = {}
        for t in ts:
            r = new_rate[id(t)]
            if abs(r - t.rate) <= _RATE_RTOL * max(1.0, t.rate):
                continue               # rate-stable: keep event + anchor
            dt = now - t._last_update
            if dt > 0 and t.rate > 0:
                t.remaining = max(0.0, t.remaining - t.rate * dt)
            t._last_update = now
            if r != t._res:
                deltas[t.flow] = deltas.get(t.flow, 0.0) + (r - t._res)
                t._res = r
            t.rate = r
            if self._trace:
                self.tracer.on_transfer_rate(t, now, r)
            clock.cancel(t._event)
            if t.remaining <= 1e-12:
                t._event = clock.schedule(0.0, self._complete, t)
            elif r > 0:
                t._event = clock.schedule(t.remaining / r, self._complete, t)
            else:
                t._event = None        # stalled until capacity frees up
        if deltas:
            self.ledger.shift(path, direction, deltas)
