"""Hardware constants for the TPU v5e target (per chip).

These play the role of the paper's Table 1/2 testbed description: fixed,
vendor-published numbers from which every roofline/interference model in
core/ derives. The CPU container never executes at these speeds — they
parameterize the analytic backend of the characterization, exactly as the
paper's P (PCIe) and N (network) constants parameterize its §4/§5 models.
"""
from __future__ import annotations

# compute / memory (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
HBM_BYTES = 16 * 2**30          # 16 GiB
VMEM_BYTES = 128 * 2**20        # ~128 MiB vector memory

# interconnect
ICI_BW_PER_LINK = 50e9          # bytes/s per link per direction
ICI_LINKS_PER_AXIS = 1          # links serving one mesh-axis ring direction
DCN_BW_PER_CHIP = 6.25e9        # bytes/s per chip across the pod boundary
PCIE_BW = 16e9                  # bytes/s host<->device, per direction
PCIE_LAT = 3e-6                 # seconds, host<->device one way
ICI_LAT = 1e-6                  # seconds per hop
DCN_LAT = 10e-6                 # seconds

# the paper's P and N, reborn: for a path that crosses a shared link
# twice (paper path-3), the usable budget is the *unidirectional* limit
# and it interferes with the primary traffic (B_slow <= P - N rule).
