"""The unified communication-fabric API (paper §2.3–§4.2).

One abstraction for every rendition of the paper's path model — the TPU
mesh (①/②/③/③* as ICI/DCN/PCIe, core/paths.py), the LineFS §5.1
replication fabric, and the DrTM-KV §5.2 RDMA fabric — instead of three
incompatible ad-hoc copies.

Concepts
--------
``Path``       one directed-capacity resource: bandwidth in *typed*
               units (``bytes/s`` or ``ops/s``), per direction; a
               bidirectional path multiplexes opposite flows (paper
               Fig 5: READ+WRITE ≈ 2x one-way).
``Fabric``     the set of paths plus the fabric-wide §4.1 concurrency
               discount; behaves as a ``Mapping[str, Path]``.
``Use``        traffic one unit of work places on one path (amounts in
               the path's units, per direction).
``Alternative``one way to implement a functionality: a bundle of Uses,
               an optional endpoint compute cap (the "wimpy SoC"
               premise), and ranking criteria.
``BudgetLedger``per-direction budget accounting with reserve / release /
               checkpoint-restore semantics. The §4.1 concurrency
               discount — shared resources lose 7–15% when more than
               one flow is concurrently active on them (or on a path in
               the same ``shared_group``) — is applied *here, once*,
               never at call sites.
``MultipathRouter``the §4.2 guideline, executable: rank alternatives,
               greedily combine them against a ledger until a shared
               resource saturates, blend a fixed mix (e.g. the DrTM-KV
               A4+A5 hit/miss split), and the B_slow <= P − N slack
               rule.

The per-direction budget model reproduces the paper's findings natively:
opposite-direction flows draw from different direction budgets (Fig 5),
and a path that crosses one link twice (paper path-③) consumes both
budgets at once — the "hidden bottleneck".
"""
from __future__ import annotations

import math
import warnings
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple, Union

# typed path units
BYTES_PER_S = "bytes/s"
OPS_PER_S = "ops/s"

OUT, IN = "out", "in"
_DIRS = (OUT, IN)

#: path kinds with first-class meaning to the offload tier: a compute
#: resource (host cores / SoC ARM complex) and a DCA-style datapath
#: accelerator ("Demystifying Datapath Accelerator Enhanced Off-path
#: SmartNIC", PAPERS.md) — a fixed-function engine that is neither a
#: wire nor a general core, with its own ops/s budget.
COMPUTE = "compute"
DCA = "dca"


class FabricError(ValueError):
    """Unknown path, unit mismatch, or malformed alternative."""


class InsufficientBudget(RuntimeError):
    """A strict reserve() asked for more than the remaining budget."""


# ----------------------------------------------------------------------
# paths and fabrics
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Path:
    """One communication path. ``capacity`` is per direction, in
    ``units``; ``bidirectional`` means the opposite direction has its
    own equal budget (multiplexing), otherwise the IN budget is 0."""
    name: str
    capacity: float
    units: str = BYTES_PER_S
    latency: float = 0.0               # seconds, one hop
    bidirectional: bool = True
    shared_group: Optional[str] = None # interference group (§4.1)
    kind: str = "generic"              # ici | dcn | pcie | rdma | ...
    axis: Optional[str] = None         # mesh axis (TPU fabrics)
    size: int = 2                      # participants along the path

    def __post_init__(self):
        if self.capacity <= 0:
            raise FabricError(f"path {self.name}: capacity must be > 0")
        if self.units not in (BYTES_PER_S, OPS_PER_S):
            raise FabricError(f"path {self.name}: unknown units {self.units!r}")

    @property
    def bw(self) -> float:
        """Legacy alias for ``capacity`` (bytes/s paths)."""
        return self.capacity

    @property
    def group(self) -> str:
        return self.shared_group or self.name

    def time_for(self, amount: float, *, both_directions: bool = False) -> float:
        """Transfer/service time for `amount` (path units x seconds).
        Opposite-direction traffic multiplexes, so both_directions does
        not slow a bidirectional path down."""
        if amount <= 0:
            return 0.0
        return self.latency + amount / self.capacity

    @property
    def is_compute(self) -> bool:
        """True for compute-tier resources (SoC cores, DCA engines):
        ops/s paths with no opposite direction — work is executed, not
        echoed back."""
        return self.units == OPS_PER_S and not self.bidirectional


def compute_path(name: str, ops_per_s: float, *, latency: float = 0.0,
                 shared_group: Optional[str] = None,
                 kind: str = COMPUTE) -> Path:
    """A compute resource as a fabric Path: ``ops_per_s`` is the
    device's roofline (for byte-granular work like compression, one op
    == one byte processed). Unidirectional — a ``Compute`` reservation
    draws on the OUT budget only — so the same ledger/fair-share/QoS
    machinery that governs wires governs cores."""
    return Path(name, ops_per_s, OPS_PER_S, latency=latency,
                bidirectional=False, shared_group=shared_group, kind=kind)


def dca_path(name: str, ops_per_s: float, *, latency: float = 0.0,
             shared_group: Optional[str] = None) -> Path:
    """A DCA-style datapath-accelerator path (kind=``dca``): the
    fixed-function engine class of "Demystifying Datapath Accelerator
    Enhanced Off-path SmartNIC" — much higher ops/s than the SoC's
    wimpy cores, lower dispatch latency, but only for the operations it
    implements (the caller decides eligibility)."""
    return compute_path(name, ops_per_s, latency=latency,
                        shared_group=shared_group, kind=DCA)


class Fabric(Mapping):
    """A set of named paths + the fabric-wide concurrency discount.

    Mapping protocol gives ``fabric["pcie:host"]``, iteration and
    ``len`` — drop-in for the old ``Dict[str, PathSpec]`` tables.
    """

    def __init__(self, paths: Union[Iterable[Path], Mapping[str, Path]] = (),
                 *, concurrency_discount: float = 0.0):
        if isinstance(paths, Mapping):
            paths = paths.values()
        self._paths: Dict[str, Path] = {}
        for p in paths:
            self.add(p)
        if not 0.0 <= concurrency_discount < 1.0:
            raise FabricError("concurrency_discount must be in [0, 1)")
        self.concurrency_discount = float(concurrency_discount)

    # -- construction ---------------------------------------------------
    @classmethod
    def of(cls, *paths: Path, concurrency_discount: float = 0.0) -> "Fabric":
        return cls(paths, concurrency_discount=concurrency_discount)

    def add(self, path: Path) -> "Fabric":
        if path.name in self._paths:
            raise FabricError(f"duplicate path {path.name}")
        self._paths[path.name] = path
        return self

    # -- Mapping protocol ----------------------------------------------
    def __getitem__(self, name: str) -> Path:
        return self._paths[name]

    def __iter__(self):
        return iter(self._paths)

    def __len__(self) -> int:
        return len(self._paths)

    def __repr__(self) -> str:
        names = ", ".join(self._paths)
        return f"Fabric({names}; discount={self.concurrency_discount})"

    # -- semantics ------------------------------------------------------
    def direction_capacity(self, name: str, direction: str) -> float:
        p = self[name]
        if direction == IN and not p.bidirectional:
            return 0.0
        return p.capacity

    def validate(self, alt: "Alternative") -> None:
        """Check every Use references a known path in matching units."""
        for u in alt.uses:
            if u.path not in self._paths:
                raise FabricError(
                    f"alternative {alt.name}: unknown path {u.path!r} "
                    f"(fabric has {sorted(self._paths)})")
            if u.units is not None and u.units != self[u.path].units:
                raise FabricError(
                    f"alternative {alt.name}: use on {u.path} declared in "
                    f"{u.units} but the path is {self[u.path].units}")

    def ledger(self) -> "BudgetLedger":
        return BudgetLedger(self)

    def router(self) -> "MultipathRouter":
        return MultipathRouter(self)

    # -- composition (multi-tenant fabrics) -----------------------------
    def namespaced(self, prefix: str, *, sep: str = "/") -> "Fabric":
        """A copy with every path (and explicit shared_group) renamed
        ``<prefix><sep><name>`` — so two structurally identical fabrics
        can coexist in one merged fabric without colliding. Implicit
        groups (``shared_group=None``) stay implicit: they follow the
        renamed path automatically."""
        import dataclasses
        renamed = [
            dataclasses.replace(
                p, name=f"{prefix}{sep}{p.name}",
                shared_group=(f"{prefix}{sep}{p.shared_group}"
                              if p.shared_group is not None else None))
            for p in self._paths.values()]
        return Fabric(renamed,
                      concurrency_discount=self.concurrency_discount)


def merge_fabrics(*fabrics: Fabric,
                  concurrency_discount: Optional[float] = None) -> "Fabric":
    """One fabric from many — the multi-tenant substrate: tenants that
    should *share* a path (and its budgets) reference the same path name
    in each input; a duplicate name is tolerated only when the Path
    definitions are identical (then it merges into one shared path), and
    a conflicting redefinition raises. Namespace an input first
    (``Fabric.namespaced``) when its paths must stay private. The merged
    discount defaults to the max of the inputs (the shared medium is at
    least as contended as its worst constituent)."""
    merged: Dict[str, Path] = {}
    for fab in fabrics:
        for p in fab.values():
            have = merged.get(p.name)
            if have is None:
                merged[p.name] = p
            elif have != p:
                raise FabricError(
                    f"merge conflict on path {p.name!r}: {have} != {p}")
    disc = (concurrency_discount if concurrency_discount is not None
            else max((f.concurrency_discount for f in fabrics), default=0.0))
    return Fabric(merged.values(), concurrency_discount=disc)


# ----------------------------------------------------------------------
# work descriptions
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Use:
    """Traffic one unit of work places on one path, per direction, in
    the path's units. ``units`` is an optional declaration checked
    against the path (bytes/s vs ops/s)."""
    path: str
    out: float = 0.0
    in_: float = 0.0
    units: Optional[str] = None

    # legacy field names (planner.PathUse)
    @property
    def out_bytes(self) -> float:
        return self.out

    @property
    def in_bytes(self) -> float:
        return self.in_


@dataclass
class Alternative:
    """One way to implement the functionality (paper Figure 14/16)."""
    name: str
    uses: List[Use]
    compute_rate: float = math.inf     # units of work/s the endpoint sustains
    criteria: Dict[str, float] = field(default_factory=dict)
    # e.g. {"host_cpu": 0.2, "latency_us": 4.6, "net_utilization": 1.0}
    tenant: Optional[str] = None       # QoS tag for weighted allocation

    def solo_rate(self, fabric: Mapping,
                  ledger: Optional["BudgetLedger"] = None) -> float:
        """Peak work units/s using this alternative alone (no sharing,
        no discount — a single flow). With a ``ledger``, the rate is
        computed against the *remaining* budgets (live occupancy,
        discount included via the ledger's holder count)."""
        rate = self.compute_rate
        for u in self.uses:
            if ledger is not None:
                cap_out = ledger.available(u.path, OUT, joining=self.name)
                cap_in = ledger.available(u.path, IN, joining=self.name)
            else:
                cap_out = cap_in = fabric[u.path].capacity
            if u.out > 0:
                rate = min(rate, cap_out / u.out)
            if u.in_ > 0:
                rate = min(rate, cap_in / u.in_)
        return rate


@dataclass
class Allocation:
    alternative: str
    rate: float                        # work units/s granted
    bottleneck: str                    # what stopped further allocation


# ----------------------------------------------------------------------
# the budget ledger
# ----------------------------------------------------------------------

class BudgetLedger:
    """Per-direction budget accounting over a Fabric.

    Every (path, direction) starts with the path's direction capacity.
    Flows reserve rate against it and may release it back. The §4.1
    concurrency discount lives here and only here: a path's *effective*
    capacity drops to ``capacity * (1 - discount)`` while more than one
    distinct flow holds it or any path in its ``shared_group``.

    ``checkpoint()`` / ``restore()`` snapshot the whole ledger, so a
    router can explore an allocation and roll it back.

    Holder accounting is *indexed*: a per-interference-group
    ``flow -> live (flow, path) entry count`` map is maintained on every
    reserve/release, so ``holders()`` (and the runtime's discount check)
    is O(group flows) instead of a scan over every ledger entry — the
    scan was the dominant cost of rebalancing at O(1k) concurrent
    transfers.
    """

    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        # (path, dir) -> total reserved rate (path units)
        self._reserved: Dict[Tuple[str, str], float] = {
            (name, d): 0.0 for name in fabric for d in _DIRS}
        # (flow, path) -> reserved (out, in) — release bookkeeping
        self._by_flow: Dict[Tuple[str, str], Tuple[float, float]] = {}
        # path -> interference group (cached; lazily extended)
        self._group_of: Dict[str, str] = {
            name: fabric[name].group for name in fabric}
        # group -> flow -> number of live (flow, path) entries: the
        # holder index. Every _by_flow entry has a positive component
        # (reserve never creates an all-zero entry; release pops them),
        # so entry count == holder-ship.
        self._holders: Dict[str, Dict[str, int]] = {}

    def _group(self, name: str) -> str:
        g = self._group_of.get(name)
        if g is None:
            g = self._group_of[name] = self.fabric[name].group
        return g

    def _holder_add(self, name: str, flow: str) -> None:
        g = self._group(name)
        d = self._holders.setdefault(g, {})
        d[flow] = d.get(flow, 0) + 1

    def _holder_del(self, name: str, flow: str) -> None:
        g = self._group(name)
        d = self._holders.get(g)
        if d is None:
            return
        c = d.get(flow, 0) - 1
        if c <= 0:
            d.pop(flow, None)
            if not d:
                del self._holders[g]
        else:
            d[flow] = c

    def _rebuild_holders(self) -> None:
        self._holders = {}
        for (flow, name) in self._by_flow:
            self._holder_add(name, flow)

    # -- holders / discount --------------------------------------------
    def holders(self, name: str) -> Set[str]:
        """Distinct flows active on this path's interference group."""
        return set(self._holders.get(self._group(name), ()))

    def group_holders(self, group: str) -> Dict[str, int]:
        """The live ``flow -> entry count`` index for one interference
        group — the O(1)-maintained structure behind ``holders()``; the
        runtime's discount check reads it directly (counting distinct
        flows without building a set)."""
        return self._holders.get(group, {})

    def effective_capacity(self, name: str, direction: str,
                           *, joining: Optional[str] = None) -> float:
        """Direction capacity after the concurrency discount, assuming
        `joining` (if given) becomes an additional holder."""
        base = self.fabric.direction_capacity(name, direction)
        holders = self.holders(name)
        if joining is not None:
            holders = holders | {joining}
        if len(holders) > 1 and self.fabric.concurrency_discount > 0.0:
            base *= 1.0 - self.fabric.concurrency_discount
        return base

    def available(self, name: str, direction: str,
                  *, joining: Optional[str] = None) -> float:
        cap = self.effective_capacity(name, direction, joining=joining)
        return max(0.0, cap - self._reserved[(name, direction)])

    def headroom(self, name: str) -> float:
        """min over directions of what is still free on `name`."""
        return min(self.available(name, OUT), self.available(name, IN))

    # -- reserve / release ---------------------------------------------
    def reserve(self, name: str, *, out: float = 0.0, in_: float = 0.0,
                flow: str = "default", strict: bool = True) -> bool:
        """Reserve rate on a path. Strict mode raises InsufficientBudget
        (and reserves nothing) when a direction would be over-committed;
        non-strict returns False instead."""
        if name not in self.fabric:
            raise FabricError(f"unknown path {name!r}")
        if out < 0 or in_ < 0:
            raise FabricError("reservations must be non-negative")
        if out == 0.0 and in_ == 0.0:
            return True
        eps = 1e-9
        for direction, amt in ((OUT, out), (IN, in_)):
            if amt <= 0:
                continue
            avail = self.available(name, direction, joining=flow)
            if amt > avail * (1 + eps) + eps:
                if strict:
                    raise InsufficientBudget(
                        f"{name}:{direction}: requested {amt:.6g}, "
                        f"available {avail:.6g} (flow={flow})")
                return False
        self._reserved[(name, OUT)] += out
        self._reserved[(name, IN)] += in_
        fkey = (flow, name)
        cur = self._by_flow.get(fkey)
        if cur is None:
            self._by_flow[fkey] = (out, in_)
            self._holder_add(name, flow)
        else:
            self._by_flow[fkey] = (cur[0] + out, cur[1] + in_)
        return True

    def release(self, name: str, *, out: float = 0.0, in_: float = 0.0,
                flow: str = "default") -> None:
        """Release previously reserved rate; releasing more than the
        flow holds is an error (conservation)."""
        po, pi = self._by_flow.get((flow, name), (0.0, 0.0))
        eps = 1e-9 * max(1.0, po, pi)
        if out > po + eps or in_ > pi + eps:
            raise InsufficientBudget(
                f"{name}: flow {flow} releasing ({out:.6g},{in_:.6g}) "
                f"but holds ({po:.6g},{pi:.6g})")
        self._reserved[(name, OUT)] = max(0.0, self._reserved[(name, OUT)] - out)
        self._reserved[(name, IN)] = max(0.0, self._reserved[(name, IN)] - in_)
        no, ni = max(0.0, po - out), max(0.0, pi - in_)
        if no <= 0.0 and ni <= 0.0:
            if self._by_flow.pop((flow, name), None) is not None:
                self._holder_del(name, flow)
        else:
            self._by_flow[(flow, name)] = (no, ni)

    def shift(self, name: str, direction: str, deltas: Dict[str, float]) -> None:
        """Runtime fast path: apply per-flow reservation *deltas* on one
        (path, direction) without the strict availability scan — the
        caller (``FabricRuntime``'s rebalancer) constructs fair shares
        that fit the budget by construction, and has already aggregated
        one delta per flow. Bookkeeping (``_reserved`` clamping,
        ``_by_flow`` entry lifecycle, the holder index) matches a
        reserve()/release() sequence exactly, so conservation
        invariants and ``holders()`` are unaffected."""
        key = (name, direction)
        total = self._reserved[key]
        out_dir = direction == OUT
        for flow, d in deltas.items():
            if d == 0.0:
                continue
            total = total + d if d > 0 else max(0.0, total + d)
            fkey = (flow, name)
            po, pi = self._by_flow.get(fkey, (0.0, 0.0))
            if out_dir:
                po = po + d if d > 0 else max(0.0, po + d)
            else:
                pi = pi + d if d > 0 else max(0.0, pi + d)
            if po <= 0.0 and pi <= 0.0:
                if self._by_flow.pop(fkey, None) is not None:
                    self._holder_del(name, flow)
            elif fkey in self._by_flow:
                self._by_flow[fkey] = (po, pi)
            else:
                self._by_flow[fkey] = (po, pi)
                self._holder_add(name, flow)
        self._reserved[key] = total

    def release_flow(self, flow: str) -> None:
        """Release everything a flow holds, across all paths."""
        for (f, name), (o, i) in list(self._by_flow.items()):
            if f == flow:
                self.release(name, out=o, in_=i, flow=flow)

    def reserve_alternative(self, alt: Alternative, rate: float,
                            *, flow: Optional[str] = None,
                            strict: bool = True) -> bool:
        """Reserve `rate` work units/s worth of an alternative's uses,
        atomically (all uses or none — also when a strict reserve
        raises mid-way)."""
        flow = flow if flow is not None else alt.name
        token = self.checkpoint()
        try:
            for u in alt.uses:
                ok = self.reserve(u.path, out=rate * u.out, in_=rate * u.in_,
                                  flow=flow, strict=strict)
                if not ok:
                    self.restore(token)
                    return False
        except InsufficientBudget:
            self.restore(token)
            raise
        return True

    # -- snapshot -------------------------------------------------------
    def checkpoint(self):
        return dict(self._reserved), dict(self._by_flow)

    def restore(self, token) -> None:
        reserved, by_flow = token
        self._reserved = dict(reserved)
        self._by_flow = dict(by_flow)
        self._rebuild_holders()

    def reserved(self, name: str, direction: str) -> float:
        return self._reserved[(name, direction)]


# ----------------------------------------------------------------------
# the router (§4.2, executable)
# ----------------------------------------------------------------------

class MultipathRouter:
    """Turns Alternatives + a demand/criteria spec into rate allocations."""

    def __init__(self, fabric: Fabric):
        self.fabric = fabric

    # -- step 2: rank ---------------------------------------------------
    def rank(self, alts: Sequence[Alternative], key: str = "rate",
             prefer: Optional[Sequence[str]] = None) -> List[Alternative]:
        """Rank by solo rate (default) or an explicit criterion
        (lower-is-better for latency_us/host_cpu, higher for the rest)."""
        if prefer:
            order = {n: i for i, n in enumerate(prefer)}
            return sorted(alts, key=lambda a: order.get(a.name, len(order)))
        if key == "rate":
            return sorted(alts, key=lambda a: -a.solo_rate(self.fabric))
        sign = 1.0 if key in ("latency_us", "host_cpu") else -1.0
        return sorted(alts, key=lambda a: sign * a.criteria.get(key, math.inf))

    # -- step 3: greedy combine ----------------------------------------
    def allocate(self, alts_ranked: Sequence[Alternative],
                 demand: float = math.inf,
                 *, ledger: Optional[BudgetLedger] = None,
                 qos=None) -> Tuple[List[Allocation], float]:
        """Give each alternative in order as much rate as the remaining
        budgets allow; stop when demand is met or everything saturates.
        Mutates `ledger` if given (so callers can pre-reserve primary
        traffic); returns (allocations, total_rate).

        With ``qos`` (any object exposing ``weight(tenant) -> float``,
        see tenancy/qos.QoSPolicy), the allocation switches from
        in-order greedy to *weighted max-min* over the alternatives'
        ``tenant`` tags — the same progressive-filling split the
        FabricRuntime applies to live transfers, so a static plan and
        the converged runtime shares agree under tenancy (asserted in
        tests/test_offload.py)."""
        led = ledger if ledger is not None else self.fabric.ledger()
        if qos is not None:
            return self._allocate_weighted(alts_ranked, demand, led, qos)
        allocs: List[Allocation] = []
        total = 0.0
        for alt in alts_ranked:
            self.fabric.validate(alt)
            if total >= demand:
                break
            rate = min(alt.compute_rate, demand - total)
            bottleneck = "compute" if rate == alt.compute_rate else "demand"
            demand_per_dir: Dict[Tuple[str, str], float] = {}
            for u in alt.uses:     # aggregate: two Uses of one path add up
                if u.out > 0:
                    demand_per_dir[(u.path, OUT)] = \
                        demand_per_dir.get((u.path, OUT), 0.0) + u.out
                if u.in_ > 0:
                    demand_per_dir[(u.path, IN)] = \
                        demand_per_dir.get((u.path, IN), 0.0) + u.in_
            for (pname, direction), amt in demand_per_dir.items():
                r = led.available(pname, direction, joining=alt.name) / amt
                if r < rate:
                    rate, bottleneck = r, f"{pname}:{direction}"
            if rate <= 0:
                allocs.append(Allocation(alt.name, 0.0, bottleneck))
                continue
            led.reserve_alternative(alt, rate)
            total += rate
            allocs.append(Allocation(alt.name, rate, bottleneck))
        return allocs, total

    def _allocate_weighted(self, alts: Sequence[Alternative], demand: float,
                           led: BudgetLedger, qos,
                           ) -> Tuple[List[Allocation], float]:
        """Progressive filling: every unfrozen alternative's rate rises
        in proportion to its tenant's QoS weight until a shared resource
        saturates (its users freeze with that bottleneck), a compute cap
        binds, or the aggregate demand is met — the static-plan twin of
        ``FabricRuntime._rebalance``'s weighted max-min. The §4.1
        discount applies per interference group iff the group ends up
        with more than one distinct flow (allocated alternatives plus
        live ledger holders), exactly as the runtime counts it."""
        alts = list(alts)
        for alt in alts:
            self.fabric.validate(alt)
            if not alt.uses and not math.isfinite(alt.compute_rate):
                raise FabricError(
                    f"alternative {alt.name} is unbounded: no use and no "
                    "compute cap")
        weights = [float(qos.weight(alt.tenant)) for alt in alts]
        # per-(path, dir) demand of one work unit of each alternative
        unit: List[Dict[Tuple[str, str], float]] = []
        for alt in alts:
            d: Dict[Tuple[str, str], float] = {}
            for u in alt.uses:
                if u.out > 0:
                    d[(u.path, OUT)] = d.get((u.path, OUT), 0.0) + u.out
                if u.in_ > 0:
                    d[(u.path, IN)] = d.get((u.path, IN), 0.0) + u.in_
            unit.append(d)
        # group -> flows that will be on it: allocated alts + ledger holders
        flows_on: Dict[str, Set[str]] = {}
        for alt, d in zip(alts, unit):
            for (pname, _dir) in d:
                flows_on.setdefault(self.fabric[pname].group, set()).add(alt.name)
        avail: Dict[Tuple[str, str], float] = {}
        for d in unit:
            for (pname, direction) in d:
                if (pname, direction) in avail:
                    continue
                cap = self.fabric.direction_capacity(pname, direction)
                group = self.fabric[pname].group
                flows = flows_on.get(group, set()) | led.holders(pname)
                if len(flows) > 1 and self.fabric.concurrency_discount > 0.0:
                    cap *= 1.0 - self.fabric.concurrency_discount
                avail[(pname, direction)] = \
                    max(0.0, cap - led.reserved(pname, direction))
        rates = [0.0] * len(alts)
        bottleneck = [""] * len(alts)
        active = [i for i in range(len(alts)) if weights[i] > 0]
        for i in range(len(alts)):
            if weights[i] <= 0:
                bottleneck[i] = "weight"
        total = 0.0
        eps = 1e-12
        while active:
            # largest uniform step theta: rate_i += theta * w_i for all
            # active i, bounded by every touched resource, each compute
            # cap, and the remaining aggregate demand
            theta = math.inf
            binder: Optional[str] = None
            for (pname, direction), cap_left in avail.items():
                usage = sum(weights[i] * unit[i].get((pname, direction), 0.0)
                            for i in active)
                if usage > eps:
                    t = cap_left / usage
                    if t < theta:
                        theta, binder = t, f"{pname}:{direction}"
            for i in active:
                if math.isfinite(alts[i].compute_rate):
                    t = (alts[i].compute_rate - rates[i]) / weights[i]
                    if t < theta:
                        theta, binder = t, "compute"
            if math.isfinite(demand):
                wsum = sum(weights[i] for i in active)
                t = (demand - total) / wsum if wsum > 0 else 0.0
                if t < theta:
                    theta, binder = t, "demand"
            if not math.isfinite(theta):
                raise FabricError("weighted allocation is unbounded: active "
                                  "alternatives have no binding resource")
            theta = max(theta, 0.0)
            for i in active:
                step = theta * weights[i]
                rates[i] += step
                total += step
                for key, amt in unit[i].items():
                    avail[key] = max(0.0, avail[key] - step * amt)
            # freeze: saturated resources stop their users; compute caps
            # and demand stop whoever they bind
            still = []
            for i in active:
                stop = None
                if binder == "demand":
                    stop = "demand"
                elif binder == "compute" \
                        and rates[i] >= alts[i].compute_rate - eps:
                    stop = "compute"
                else:
                    for key in unit[i]:
                        if avail[key] <= eps:
                            stop = f"{key[0]}:{key[1]}"
                            break
                if stop is None:
                    still.append(i)
                else:
                    bottleneck[i] = stop
            if len(still) == len(active):   # theta made no one freeze
                break
            active = still
        for alt, rate in zip(alts, rates):
            if rate > 0:
                led.reserve_alternative(alt, rate)
        return [Allocation(alt.name, rate, bn)
                for alt, rate, bn in zip(alts, rates, bottleneck)], total

    def route(self, alts: Sequence[Alternative], demand: float = math.inf,
              *, key: str = "rate", prefer: Optional[Sequence[str]] = None,
              ledger: Optional[BudgetLedger] = None,
              ) -> Tuple[List[Allocation], float]:
        """rank + allocate in one call."""
        return self.allocate(self.rank(alts, key=key, prefer=prefer),
                             demand, ledger=ledger)

    # -- fixed-ratio mixing (DrTM-KV A4+A5) ----------------------------
    def blend(self, weighted: Sequence[Tuple[Alternative, float]],
              *, ledger: Optional[BudgetLedger] = None,
              ) -> Tuple[float, List[Allocation]]:
        """Scale a fixed mix of alternatives (weights = fraction of work
        each serves, e.g. cache hit/miss masses) up to the first
        saturated resource. The §4.1 discount applies to every path
        whose interference group is touched by more than one member of
        the mix. With a ``ledger``, the mix is scaled against the
        *remaining* budgets: live holders count toward the discount and
        their reservations shrink the capacity — so re-planning under
        load sees the fabric as it is, not as it was at startup.
        Returns (total work units/s, per-member allocations)."""
        usage: Dict[Tuple[str, str], float] = {}
        touchers: Dict[str, Set[str]] = {}
        total = math.inf
        for alt, w in weighted:
            self.fabric.validate(alt)
            if w < 0:
                raise FabricError(f"negative weight for {alt.name}")
            if w == 0:
                continue            # inactive member: no usage, no discount
            if math.isfinite(alt.compute_rate):
                total = min(total, alt.compute_rate / w)
            for u in alt.uses:
                usage[(u.path, OUT)] = usage.get((u.path, OUT), 0.0) + w * u.out
                usage[(u.path, IN)] = usage.get((u.path, IN), 0.0) + w * u.in_
                group = self.fabric[u.path].group
                touchers.setdefault(group, set()).add(alt.name)
        bottleneck = "compute" if math.isfinite(total) else "unbounded"
        for (name, direction), amt in usage.items():
            if amt <= 0:
                continue
            cap = self.fabric.direction_capacity(name, direction)
            mixers: Set[str] = set(touchers[self.fabric[name].group])
            if ledger is not None:
                mixers |= ledger.holders(name)
            if len(mixers) > 1:
                cap *= 1.0 - self.fabric.concurrency_discount
            if ledger is not None:
                cap = max(0.0, cap - ledger.reserved(name, direction))
            r = cap / amt
            if r < total:
                total, bottleneck = r, f"{name}:{direction}"
        if not math.isfinite(total):
            raise FabricError("blend is unbounded: no use and no compute cap")
        return total, [Allocation(alt.name, w * total, bottleneck)
                       for alt, w in weighted]

    # -- the B_slow <= P − N rule --------------------------------------
    def slack(self, primary: Alternative, path: str) -> float:
        """Bandwidth left on `path` after the primary functionality
        saturates its own bottleneck. The primary's demand is clamped
        per direction (a direction it over-commits contributes zero
        slack, never a negative ledger)."""
        led = self.fabric.ledger()
        rate = primary.solo_rate(self.fabric)
        for u in primary.uses:
            led.reserve(u.path,
                        out=min(rate * u.out, led.available(u.path, OUT)),
                        in_=min(rate * u.in_, led.available(u.path, IN)),
                        flow="primary")
        return led.headroom(path)


# ----------------------------------------------------------------------
# calibrated case-study fabrics (paper §5.1)
# ----------------------------------------------------------------------

def linefs_fabric(N: float, P: float, dma_bw: Optional[float] = None) -> Fabric:
    """LineFS §5.1 testbed: network N, internal link P, weak DMA engine
    (§3.3, ~0.7 P). `internal` and `dma` share physical PCIe media."""
    dma = dma_bw if dma_bw is not None else 0.7 * P
    return Fabric.of(
        Path("net", N, BYTES_PER_S, latency=1e-6, kind="ici",
             shared_group="net"),
        Path("internal", P, BYTES_PER_S, latency=3e-7, kind="pcie",
             shared_group="pcie"),
        Path("dma", dma, BYTES_PER_S, latency=3e-7, kind="pcie",
             shared_group="pcie"),
    )


def linefs_replication_alternatives(N: float, P: float, ratio: float,
                                    soc_rate: float = math.inf,
                                    ) -> List[Alternative]:
    """File replication of 1 byte of file data (paper Figure 14).

    A1: offload via ③  — the file crosses the shared internal link twice
        (1x raw in, ratio x compressed out) and the network (ratio);
    A2: offload via ③* — DMA path, bypasses the internal link;
    A3: direct host WRITE via ① — no compression, full network bytes.
    """
    return [
        Alternative("A1", uses=[
            Use("internal", out=1.0 + ratio),     # double crossing
            Use("net", out=ratio),
        ], compute_rate=soc_rate,
            criteria={"host_cpu": 0.1, "net_utilization": 1.0}),
        Alternative("A2", uses=[
            Use("dma", out=1.0),
            Use("net", out=ratio),
        ], compute_rate=soc_rate,
            criteria={"host_cpu": 0.1, "net_utilization": 1.0}),
        Alternative("A3", uses=[
            Use("net", out=1.0),
        ], criteria={"host_cpu": 1.0, "net_utilization": ratio}),
    ]
