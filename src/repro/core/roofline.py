"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs(per chip) / peak_FLOP/s
    memory term     = HLO_bytes(per chip) / HBM_bw
    collective term = sum_path collective_bytes(per chip, path) / path_bw

XLA's ``cost_analysis()`` reports *per-device* flops / bytes for the SPMD
module, so no division by chip count is needed. The collective bytes come
from the HLO parse in core/charz.py with the ring-traffic model of
core/paths.py. The collective term assumes no overlap between paths —
the conservative baseline the §Perf overlap work then attacks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import hw
from repro.core.charz import TrafficSummary, summarize_traffic
from repro.core.paths import enumerate_paths


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_path: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    collective_s_per_path: Dict[str, float]
    dominant: str
    model_flops: float               # 6*N*D global
    useful_flops_ratio: float        # MODEL_FLOPS / (HLO_FLOPs * chips)
    step_time_s: float               # max of the three terms
    roofline_frac: float             # compute_s / step_time_s ("MFU-like")
    memory_bytes_per_chip: Optional[float] = None   # live buffers (fits check)
    note: str = ""

    def row(self) -> str:
        coll = ", ".join(f"{k}={v * 1e3:.2f}ms" for k, v in
                         sorted(self.collective_s_per_path.items()))
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.compute_s * 1e3:.2f} | {self.memory_s * 1e3:.2f} "
                f"| {self.collective_s * 1e3:.2f} | {self.dominant} "
                f"| {self.useful_flops_ratio:.2f} | {self.roofline_frac:.2f} "
                f"| {coll} |")


def build_report(*, arch: str, shape: str, mesh_name: str,
                 mesh_axes, cost: dict, hlo_text: str,
                 model_flops: float, chips: int,
                 memory_bytes_per_chip: Optional[float] = None,
                 note: str = "") -> RooflineReport:
    if isinstance(cost, (list, tuple)):   # old jax: per-device list of dicts
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    traffic: TrafficSummary = summarize_traffic(hlo_text, mesh_axes)
    paths = enumerate_paths(dict(mesh_axes))

    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = hbm_bytes / hw.HBM_BW
    coll_per_path_s: Dict[str, float] = {}
    for pname, nbytes in traffic.per_path.items():
        bw = paths[pname].bw if pname in paths else hw.ICI_BW_PER_LINK
        coll_per_path_s[pname] = nbytes / bw
    collective_s = sum(coll_per_path_s.values())

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step = max(terms.values()) if terms else 0.0
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_chip=flops, hbm_bytes_per_chip=hbm_bytes,
        collective_bytes_per_path=dict(traffic.per_path),
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, collective_s_per_path=coll_per_path_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=useful,
        step_time_s=step,
        roofline_frac=compute_s / step if step > 0 else 0.0,
        memory_bytes_per_chip=memory_bytes_per_chip,
        note=note,
    )


def model_flops_for(param_count_active: int, tokens: int, kind: str = "train") -> float:
    """6*N*D (train fwd+bwd) or 2*N*D (inference fwd)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count_active * tokens
