"""Million-user serving fleet launcher (sim compute, no model).

Runs the SLO tenant fleet — N ``StagedServeEngine``s as tenants of one
``FabricRuntime`` and one budget ledger — under seeded open-loop
traces, and prints the per-tenant TTFT-attainment table. The default
is the headline experiment: ``premium`` (tight SLO, heavy QoS weight)
rides a 10x diurnal burst trace while ``standard`` offers steady load;
``--mode both`` contrasts the static fleet (attainment collapses
during the burst) against TTFT-driven decode autoscaling (replicas
spawn onto private paths, the shared host path drains, attainment
holds). Token streams are bit-identical across the two modes — scaling
moves traffic, it never changes bytes.

Usage:
  PYTHONPATH=src python -m repro.launch.fleet                  # headline, both
  PYTHONPATH=src python -m repro.launch.fleet --mode autoscaled \
      --duration 60 --arbitration
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.scale import AutoscaleConfig, ServeFleet, headline_specs


def _build(args, tracer=None) -> ServeFleet:
    cfg = AutoscaleConfig(max_replicas=args.max_replicas)
    specs = headline_specs(duration=args.duration, autoscale=cfg)
    if args.premium_rate or args.standard_rate:
        by_name = {"premium": args.premium_rate, "standard": args.standard_rate}
        specs = [dataclasses.replace(
                     s, trace=dataclasses.replace(
                         s.trace, base_rate=by_name[s.name]))
                 if by_name.get(s.name) else s
                 for s in specs]
    return ServeFleet(specs, host_bw=args.host_bw,
                      replica_bw=args.replica_bw, replicas=args.replicas,
                      arbitration=args.arbitration, tracer=tracer)


def _show(tag: str, rep) -> None:
    print(f"[{tag}] {rep.sim_seconds:.1f}s simulated, "
          f"{rep.events_processed:,} events")
    print(f"  {'tenant':<10} {'slo':>7} {'attain':>7} {'p50':>8} {'p99':>8} "
          f"{'reqs':>6} {'peak_rep':>8} {'scales':>6}")
    for name, tr in sorted(rep.tenants.items()):
        m = tr.metrics
        print(f"  {name:<10} {tr.slo_ttft:>6.2f}s {tr.attainment:>7.1%} "
              f"{m['p50_ttft']:>7.3f}s {m['p99_ttft']:>7.3f}s "
              f"{m['requests']:>6.0f} {tr.peak_replicas:>8d} "
              f"{len(tr.scale_events):>6d}")
    for e in rep.admission_events:
        print(f"  [admission] t={e['t']:.2f}s {e['event']} "
              f"offender={e.get('offender', '?')} "
              f"victim={e.get('victim', '?')}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="both",
                    choices=["both", "static", "autoscaled"])
    ap.add_argument("--duration", type=float, default=120.0,
                    help="trace length in simulated seconds")
    ap.add_argument("--premium-rate", type=float, default=None,
                    help="override premium base arrival rate (req/s)")
    ap.add_argument("--standard-rate", type=float, default=None,
                    help="override standard base arrival rate (req/s)")
    ap.add_argument("--host-bw", type=float, default=1400.0,
                    help="shared host path units/s")
    ap.add_argument("--replica-bw", type=float, default=400.0,
                    help="units/s of each private decode-replica path")
    ap.add_argument("--replicas", type=int, default=3,
                    help="decode-replica paths provisioned in the fabric")
    ap.add_argument("--max-replicas", type=int, default=4,
                    help="autoscaler ceiling per tenant (incl. fallback)")
    ap.add_argument("--arbitration", action="store_true",
                    help="K-tenant admission arbitration (priority-ordered "
                         "intake pause/resume)")
    ap.add_argument("--max-sim-seconds", type=float, default=2000.0)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="write the autoscaled run's span timeline (or the "
                         "static run's, with --mode static) as Chrome-trace "
                         "JSON")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    out = {}
    if args.mode in ("both", "static"):
        out["static"] = _build(
            args, tracer=tracer if args.mode == "static" else None).run(
            autoscale=False, max_sim_seconds=args.max_sim_seconds)
        _show("static    ", out["static"])
    if args.mode in ("both", "autoscaled"):
        out["autoscaled"] = _build(args, tracer=tracer).run(
            autoscale=True, max_sim_seconds=args.max_sim_seconds)
        _show("autoscaled", out["autoscaled"])
    if tracer is not None:
        from repro.obs.export import dump
        dump(tracer, args.trace)
        print(f"[trace] {len(tracer.spans)} spans -> {args.trace}")
    if len(out) == 2:
        s = out["static"].attainment("premium")
        a = out["autoscaled"].attainment("premium")
        print(f"[fleet] premium attainment: static {s:.1%} -> "
              f"autoscaled {a:.1%}")
    return out


if __name__ == "__main__":
    main()
