"""Training launcher.

Two modes:
- production: the assigned mesh (16x16 / 2x16x16); on real TPU hardware
  this is the entry point a cluster scheduler invokes per host.
- local: reduced config + small mesh on whatever devices exist (CPU
  container: set JAX_PLATFORMS=cpu and --devices N with the host-device
  override) — the end-to-end example drivers use this.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --shape train_4k --steps 100 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, RunConfig, get_config
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import CheckpointManager
from repro.launch.inputs import batch_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.params import init_params, num_groups
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import tree_shardings
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer


def build(cfg, run: RunConfig, shape: ShapeConfig, mesh, *, impl="auto"):
    """Init sharded state + jitted step for (cfg, mesh)."""
    from repro.launch.dryrun import _opt_logical  # reuse
    with jax.set_mesh(mesh):
        _, logical, psh = param_shardings(cfg, mesh)
        params, _ = init_params(cfg, jax.random.PRNGKey(run.seed))
        params = jax.device_put(params, psh)
        opt = adamw_init(params, moments="int8" if run.moments_int8 else "f32")
        opt_sh = tree_shardings(_opt_logical(logical, run.moments_int8),
                                jax.eval_shape(lambda: opt), mesh)
        opt = jax.device_put(opt, opt_sh)
        bsh = batch_shardings(cfg, shape, mesh)
        step = jax.jit(make_train_step(cfg, run, impl=impl, mesh=mesh),
                       in_shardings=(psh, opt_sh, bsh, None),
                       out_shardings=(psh, opt_sh, None),
                       donate_argnums=(0, 1))

        def put_batch(b):
            return {k: jax.device_put(jnp.asarray(v), bsh[k]) for k, v in b.items()}

    return params, opt, step, put_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU example mode)")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--pod-sync", default="auto", choices=["auto", "compressed"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-replicas", type=int, default=0)
    ap.add_argument("--log", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        shape = ShapeConfig("custom", args.seq or shape.seq_len,
                            args.batch or shape.global_batch, "train")

    n_dev = len(jax.devices())
    if n_dev >= 512 and args.multi_pod:
        mesh = make_production_mesh(multi_pod=True)
    elif n_dev >= 256:
        mesh = make_production_mesh()
    else:  # local mode: best small mesh
        from repro.ft.elastic import best_mesh_for, make_mesh
        shp, names = best_mesh_for(n_dev, model=min(2, n_dev),
                                   prefer_pods=2 if args.multi_pod else 1)
        mesh = make_mesh(shp, names)
    print(f"[train] mesh={dict(mesh.shape)} devices={n_dev}")

    run = RunConfig(learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(2, args.steps // 10),
                    microbatch=args.microbatch, pod_sync=args.pod_sync,
                    ckpt_every=args.ckpt_every)
    params, opt, step, put_batch = build(cfg, run, shape, mesh)

    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every,
                                 replicas=args.ckpt_replicas)
    with jax.set_mesh(mesh):
        tr = Trainer(cfg, run, shape, step_fn=step, params=params,
                     opt_state=opt, put_batch=put_batch, ckpt=ckpt,
                     log_path=args.log or None)
        tr.run_steps(args.steps - tr.start_step)
    last = tr.history[-1]
    print(f"[train] done: step={last['step']} loss={last['loss']:.4f} "
          f"({last['seconds']*1e3:.0f} ms/step)")
    return tr


if __name__ == "__main__":
    main()
