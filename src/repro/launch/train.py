"""Training launcher.

Three modes:
- production: the assigned mesh (16x16 / 2x16x16); on real TPU hardware
  this is the entry point a cluster scheduler invokes per host.
- local: reduced config + small mesh on whatever devices exist (CPU
  container: set JAX_PLATFORMS=cpu and --devices N with the host-device
  override) — the end-to-end example drivers use this.
- simulate: ``--simulate N`` dry-runs the config as N trainer nodes on
  a named fabric (``--fabric``, see train/cluster.TRAIN_FABRICS) — no
  real training, just the FabricRuntime timeline: roofline compute,
  path-aware allreduce, contention-scheduled checkpoint staging.
  Prints simulated tokens/s and the step breakdown. ``--buckets K``
  turns on bucketed-DDP overlap (per-layer-group gradient transfers
  issued during backward) and reports the measured win over a
  single-shot reference run plus the first step's bucket timeline.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --shape train_4k --steps 100 --reduced --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --shape train_4k --steps 20 --simulate 4 --fabric v5e \
      --ckpt-staging soc --ckpt-every 5
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, RunConfig, get_config
from repro.configs.base import ShapeConfig
from repro.ckpt.checkpoint import CheckpointManager
from repro.launch.inputs import batch_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.models.params import init_params, num_groups
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import tree_shardings
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer


def build(cfg, run: RunConfig, shape: ShapeConfig, mesh, *, impl="auto"):
    """Init sharded state + jitted step for (cfg, mesh)."""
    from repro.launch.dryrun import _opt_logical  # reuse
    with jax.set_mesh(mesh):
        _, logical, psh = param_shardings(cfg, mesh)
        params, _ = init_params(cfg, jax.random.PRNGKey(run.seed))
        params = jax.device_put(params, psh)
        opt = adamw_init(params, moments="int8" if run.moments_int8 else "f32")
        opt_sh = tree_shardings(_opt_logical(logical, run.moments_int8),
                                jax.eval_shape(lambda: opt), mesh)
        opt = jax.device_put(opt, opt_sh)
        bsh = batch_shardings(cfg, shape, mesh)
        step = jax.jit(make_train_step(cfg, run, impl=impl, mesh=mesh),
                       in_shardings=(psh, opt_sh, bsh, None),
                       out_shardings=(psh, opt_sh, None),
                       donate_argnums=(0, 1))

        def put_batch(b):
            return {k: jax.device_put(jnp.asarray(v), bsh[k]) for k, v in b.items()}

    return params, opt, step, put_batch


def simulate(cfg, shape, args):
    """--simulate: dry-run the config on a named fabric (no jax work).
    With ``--pods P``, runs P pods of ``--simulate N`` nodes each —
    per-pod fabrics merged over the shared ``dcn:pod`` trunk
    (train/pods.py) — and ``--pod-sync`` selects the inter-pod gradient
    sync (raw vs int8-compressed trunk ring, the simulated twin of
    RunConfig.pod_sync)."""
    from repro.train.cluster import (ClusterTimeModel, TRAIN_FABRICS,
                                     TrainCluster)
    if args.fabric not in TRAIN_FABRICS:
        raise SystemExit(f"unknown fabric {args.fabric!r} "
                         f"(have {sorted(TRAIN_FABRICS)})")

    def parse_pair(spec, cast):
        name, _, val = spec.partition(":")
        return name, cast(val)

    topo = None
    fabric = None
    if args.pods > 1:
        from repro.train.pods import PodTopology, pod_fabric
        topo = PodTopology(args.pods, args.simulate, sync=args.pod_sync)
        fabric = pod_fabric(args.pods, args.simulate,
                            trunk_bw=args.trunk_bw or None,
                            pod_fabric_fn=TRAIN_FABRICS[args.fabric])
        nodes = topo.total_nodes
    else:
        nodes = args.simulate
        fabric = TRAIN_FABRICS[args.fabric](nodes)

    tm = ClusterTimeModel.from_config(cfg, shape, nodes=nodes,
                                      ckpt_path=args.ckpt_staging,
                                      buckets=args.buckets,
                                      weighted_buckets=args.weighted_buckets)

    def fresh_fabric():
        if args.pods > 1:
            from repro.train.pods import pod_fabric
            return pod_fabric(args.pods, args.simulate,
                              trunk_bw=args.trunk_bw or None,
                              pod_fabric_fn=TRAIN_FABRICS[args.fabric])
        return TRAIN_FABRICS[args.fabric](nodes)

    def make(time_model, fab):
        return TrainCluster(
            nodes, time_model, fabric=fab, topology=topo,
            ckpt_every=args.ckpt_every,
            host_load=dict([parse_pair(args.host_load, float)])
            if args.host_load else None,
            fail_at=parse_pair(args.fail, int) if args.fail else None,
            mitigate_stragglers=True)

    ref = None
    if args.buckets > 1:
        # single-shot reference on an identical fresh fabric: the
        # overlap win is reported as measured, not predicted
        ref = make(dataclasses.replace(tm, buckets=1, bucket_weights=None),
                   fresh_fabric()).run(args.steps)
    cluster = make(tm, fabric)
    summary = cluster.run(args.steps)
    pods_msg = (f" pods={topo.pods}x{topo.nodes_per_pod} "
                f"pod_sync={topo.sync}" if topo is not None else "")
    print(f"[simulate] fabric={args.fabric} nodes={nodes}{pods_msg} "
          f"arch={cfg.name} shape={shape.name}")
    print(f"[simulate] compute={tm.compute_s * 1e3:.2f}ms/step "
          f"grad={tm.grad_bytes / 1e9:.2f}GB ckpt={tm.ckpt_bytes / 1e9:.2f}GB "
          f"via {tm.ckpt_path}")
    for e in summary["events"]:
        print(f"[simulate] t={e['t']:.3f}s {e['event']} "
              f"{ {k: v for k, v in e.items() if k not in ('t', 'event')} }")
    print(f"[simulate] {summary['steps']} steps in "
          f"{summary['sim_seconds']:.3f}s simulated "
          f"-> {summary.get('tokens_per_s', 0.0):,.0f} tokens/s "
          f"({len(cluster.straggler.stragglers())} stragglers flagged)")
    if ref is not None and ref["steps"] and summary["steps"]:
        t1 = ref["sim_seconds"] / ref["steps"]
        tk = summary["sim_seconds"] / summary["steps"]
        win = 100.0 * (1.0 - tk / t1) if t1 > 0 else 0.0
        print(f"[simulate] buckets={tm.buckets}: {tk * 1e3:.1f}ms/step vs "
              f"{t1 * 1e3:.1f}ms single-shot -> overlap win {win:.1f}%")
        # first step's overlap timeline, straight off the tracer's
        # bucket phase spans (the cluster's own runtime traces them)
        from repro.obs.trace import PHASE
        spans = [s for s in cluster.runtime.tracer.spans
                 if s.kind == PHASE and s.name == "bucket"
                 and not s.meta.get("aborted")]
        s0 = min((s.meta["step"] for s in spans), default=0)
        for s in sorted((s for s in spans if s.meta["step"] == s0),
                        key=lambda s: s.meta["bucket"]):
            print(f"[simulate]   bucket {s.meta['bucket']}: closed "
                  f"t={s.t_end * 1e3:.1f}ms issued t={s.t_start * 1e3:.1f}ms,"
                  f" in flight {(s.t_end - s.t_start) * 1e3:.1f}ms")
    if topo is not None:
        from repro.core.fabric import OUT
        left = cluster.runtime.ledger.reserved(topo.trunk, OUT)
        print(f"[simulate] trunk {topo.trunk}: reserved after run = "
              f"{left:.3g} (0 = every pod-sync reservation conserved)")
    off = cluster.offload.get_performance_stats()
    if off["compression_bytes_in"]:
        print(f"[simulate] offload: "
              f"{off['compression_operations_offloaded']} saves compressed "
              f"off-host, cycles_saved={off['cpu_cycles_saved']:.3g}, "
              f"ratio={off['compression_ratio']:.2f}")
    if args.trace:
        from repro.obs.export import dump
        dump(cluster.runtime.tracer, args.trace)
        print(f"[simulate] wrote Chrome trace "
              f"({len(cluster.runtime.tracer.spans)} spans) to {args.trace}")
    return cluster


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU example mode)")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--pod-sync", default="auto", choices=["auto", "compressed"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-replicas", type=int, default=0)
    ap.add_argument("--log", default="")
    ap.add_argument("--simulate", type=int, default=0, metavar="NODES",
                    help="dry-run NODES simulated trainer nodes on a "
                         "named fabric instead of training")
    ap.add_argument("--pods", type=int, default=1,
                    help="--simulate: run PODS pods of NODES nodes each, "
                         "per-pod fabrics merged over the shared dcn:pod "
                         "trunk (--pod-sync picks the inter-pod sync)")
    ap.add_argument("--trunk-bw", type=float, default=0.0,
                    help="--simulate --pods: inter-pod trunk bytes/s "
                         "(default pods * DCN_BW_PER_CHIP)")
    ap.add_argument("--buckets", type=int, default=1, metavar="K",
                    help="--simulate: split the gradient into K "
                         "per-layer-group buckets, each allreduce "
                         "issued as its backward slice completes "
                         "(bucketed-DDP overlap; K>1 also runs a "
                         "single-shot reference and prints the "
                         "measured overlap win)")
    ap.add_argument("--weighted-buckets", action="store_true",
                    help="--simulate --buckets K: size each gradient "
                         "bucket from the model's real per-layer-group "
                         "parameter counts instead of splitting "
                         "uniformly (train/cluster.layer_group_weights)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="--simulate: write the run's span timeline as "
                         "Chrome-trace JSON (load in chrome://tracing "
                         "or ui.perfetto.dev)")
    ap.add_argument("--fabric", default="v5e",
                    help="named fabric for --simulate "
                         "(v5e | weak-soc | fast-net | linefs)")
    ap.add_argument("--ckpt-staging", default="soc",
                    choices=["soc", "host", "auto", "soc-compress",
                             "host-compress"],
                    help="--simulate: checkpoint staging mode (auto = "
                         "per-save ledger-occupancy choice over wires "
                         "AND compress-then-stage; *-compress = run the "
                         "codec on that side's device, stage only the "
                         "compressed bytes)")
    ap.add_argument("--host-load", default="",
                    help="--simulate: NODE:FRAC background host-path load, "
                         "e.g. node0:0.6")
    ap.add_argument("--fail", default="",
                    help="--simulate: NODE:STEP silences a node mid-run, "
                         "e.g. node1:8")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        shape = ShapeConfig("custom", args.seq or shape.seq_len,
                            args.batch or shape.global_batch, "train")

    if args.simulate:
        return simulate(cfg, shape, args)

    n_dev = len(jax.devices())
    if n_dev >= 512 and args.multi_pod:
        mesh = make_production_mesh(multi_pod=True)
    elif n_dev >= 256:
        mesh = make_production_mesh()
    else:  # local mode: best small mesh
        from repro.ft.elastic import best_mesh_for, make_mesh
        shp, names = best_mesh_for(n_dev, model=min(2, n_dev),
                                   prefer_pods=2 if args.multi_pod else 1)
        mesh = make_mesh(shp, names)
    print(f"[train] mesh={dict(mesh.shape)} devices={n_dev}")

    run = RunConfig(learning_rate=args.lr, total_steps=args.steps,
                    warmup_steps=max(2, args.steps // 10),
                    microbatch=args.microbatch, pod_sync=args.pod_sync,
                    ckpt_every=args.ckpt_every)
    params, opt, step, put_batch = build(cfg, run, shape, mesh)

    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every,
                                 replicas=args.ckpt_replicas)
    with jax.set_mesh(mesh):
        tr = Trainer(cfg, run, shape, step_fn=step, params=params,
                     opt_state=opt, put_batch=put_batch, ckpt=ckpt,
                     log_path=args.log or None)
        tr.run_steps(args.steps - tr.start_step)
    last = tr.history[-1]
    print(f"[train] done: step={last['step']} loss={last['loss']:.4f} "
          f"({last['seconds']*1e3:.0f} ms/step)")
    return tr


if __name__ == "__main__":
    main()
