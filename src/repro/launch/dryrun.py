import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import gzip
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, RunConfig, get_config, list_archs, shape_applicable
from repro.core.charz import replay, summarize_traffic
from repro.core.paths import enumerate_paths
from repro.core.roofline import build_report, model_flops_for
from repro.launch.inputs import (batch_shardings, batch_specs, decode_shardings,
                                 decode_specs, param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.params import abstract_params, num_groups
from repro.optim.adamw import adamw_init
from repro.parallel.sharding import named_sharding, tree_shardings
from repro.train.train_step import make_train_step
from repro.core.compression import Quantized

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "runs", "dryrun")


def _opt_logical(params_logical, int8: bool):
    def leaf(lg):
        if int8:
            return Quantized(q=("flat_shard", None), scale=("flat_shard",))
        return lg
    is_lg = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    m = jax.tree.map(leaf, params_logical, is_leaf=is_lg)
    from repro.optim.adamw import AdamWState
    return AdamWState(step=(), m=m, v=jax.tree.map(leaf, params_logical, is_leaf=is_lg))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               run: Optional[RunConfig] = None, verbose: bool = True,
               save: bool = True, tag: str = "", opts: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    big = cfg.param_count() > 100e9
    opt_list = opts.split(",") if opts else []
    remat = "none" if "remat_none" in opt_list else (
        "full" if "remat_full" in opt_list else "minimal")
    run = run or RunConfig(
        remat_policy=remat, moments_int8=big,
        microbatch=4 if "microbatch" in opt_list else 0,
        pod_sync="compressed" if "podint8" in opt_list else "auto")
    donate_ok = "nodonate" not in opt_list

    import contextlib
    from repro.models import precision
    stack = contextlib.ExitStack()
    if "bf16" in opts.split(","):
        stack.enter_context(precision.bf16_collectives())

    t0 = time.monotonic()
    with stack, jax.set_mesh(mesh):
        params_abs, logical, psh = param_shardings(cfg, mesh)

        if shape.kind == "train":
            opt_abs = jax.eval_shape(
                lambda: adamw_init(params_abs,
                                   moments="int8" if run.moments_int8 else "f32"))
            opt_sh = tree_shardings(_opt_logical(logical, run.moments_int8),
                                    opt_abs, mesh)
            bspecs = batch_specs(cfg, shape)
            bsh = batch_shardings(cfg, shape, mesh)
            cf = 1.0 if "cf1" in opt_list else 1.25
            lchunk = 2048 if "losschunk2048" in opt_list else 512
            step_fn = make_train_step(cfg, run, impl="auto", mesh=mesh,
                                      unroll=num_groups(cfg),
                                      capacity_factor=cf, loss_chunk=lchunk)
            jitted = jax.jit(step_fn,
                             in_shardings=(psh, opt_sh, bsh, None),
                             out_shardings=(psh, opt_sh, None),
                             donate_argnums=(0, 1) if donate_ok else ())
            lowered = jitted.lower(params_abs, opt_abs, bspecs,
                                   jax.ShapeDtypeStruct((), jnp.int32))
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_for(cfg.active_param_count(), tokens, "train")
        elif shape.kind == "prefill":
            bspecs = batch_specs(cfg, shape)
            bsh = batch_shardings(cfg, shape, mesh)
            _, cache_sh = decode_shardings(cfg, shape, mesh)

            def prefill_step(params, tokens, frontend_embeds=None):
                return M.prefill(cfg, params, tokens, shape.seq_len,
                                 frontend_embeds=frontend_embeds, impl="auto",
                                 unroll=num_groups(cfg))

            args = [params_abs, bspecs["tokens"]]
            in_sh = [psh, bsh["tokens"]]
            if cfg.frontend:
                args.append(bspecs["frontend_embeds"])
                in_sh.append(bsh["frontend_embeds"])
            jitted = jax.jit(prefill_step, in_shardings=tuple(in_sh),
                             out_shardings=(None, cache_sh, None))
            lowered = jitted.lower(*args)
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_for(cfg.active_param_count(), tokens, "serve")
        else:  # decode
            cp = shape.name == "long_500k"
            tok_specs, cache_abs, pos_spec = decode_specs(cfg, shape)
            tok_sh, cache_sh = decode_shardings(cfg, shape, mesh,
                                                context_parallel=cp)
            if cp:
                cp_axis = "data"
            elif cfg.num_kv_heads and cfg.num_kv_heads % mesh.shape["model"]:
                cp_axis = "model"   # cache seq-sharded over TP (inputs.py)
            else:
                cp_axis = None

            def serve_step(params, tokens, cache, pos):
                return M.decode_step(cfg, params, tokens, cache, pos,
                                     cp_axis=cp_axis, mesh=mesh,
                                     impl="auto", unroll=num_groups(cfg))

            jitted = jax.jit(serve_step,
                             in_shardings=(psh, tok_sh["tokens"], cache_sh, None),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, tok_specs["tokens"], cache_abs,
                                   pos_spec)
            tokens = shape.global_batch
            mf = model_flops_for(cfg.active_param_count(), tokens, "serve")

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    memstats = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mesh_axes = [(n, int(s)) for n, s in mesh.shape.items()]
    report = build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, mesh_axes=mesh_axes,
        cost=cost, hlo_text=hlo, model_flops=mf, chips=chips,
        memory_bytes_per_chip=(memstats.argument_size_in_bytes
                               + memstats.temp_size_in_bytes
                               + memstats.generated_code_size_in_bytes))
    traffic = summarize_traffic(hlo, mesh_axes)
    # event-driven replay: per-path transfers overlap across groups, so
    # this is <= the static sum the roofline reports (collective_s)
    replay_collective_s = replay(traffic, enumerate_paths(dict(mesh.shape)))
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": shape.kind,
        "params_b": cfg.param_count() / 1e9,
        "active_params_b": cfg.active_param_count() / 1e9,
        "flops_per_chip": report.flops_per_chip,
        "hbm_bytes_per_chip": report.hbm_bytes_per_chip,
        "collective_bytes_per_path": report.collective_bytes_per_path,
        "collective_op_counts": traffic.op_counts,
        "compute_s": report.compute_s,
        "memory_s": report.memory_s,
        "collective_s": report.collective_s,
        "collective_s_per_path": report.collective_s_per_path,
        "replay_collective_s": replay_collective_s,
        "dominant": report.dominant,
        "model_flops": mf,
        "useful_flops_ratio": report.useful_flops_ratio,
        "roofline_frac": report.roofline_frac,
        "step_time_s": report.step_time_s,
        "memory": {
            "argument_bytes": memstats.argument_size_in_bytes,
            "output_bytes": memstats.output_size_in_bytes,
            "temp_bytes": memstats.temp_size_in_bytes,
            "alias_bytes": memstats.alias_size_in_bytes,
        },
        "lower_s": t_lower, "compile_s": t_compile,
        "opts": opts,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"compile={t_compile:.1f}s dominant={report.dominant} "
              f"compute={report.compute_s*1e3:.1f}ms "
              f"memory={report.memory_s*1e3:.1f}ms "
              f"collective={report.collective_s*1e3:.1f}ms "
              f"replay={replay_collective_s*1e3:.1f}ms "
              f"useful={report.useful_flops_ratio:.2f} "
              f"frac={report.roofline_frac:.2f}")
        print(f"  memory_analysis: args={memstats.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={memstats.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={memstats.output_size_in_bytes/2**30:.2f}GiB "
              f"alias={memstats.alias_size_in_bytes/2**30:.2f}GiB")
        print(f"  collectives: {traffic.op_counts} per-path-bytes="
              f"{ {k: f'{v/2**20:.1f}MiB' for k, v in traffic.per_path.items()} }")
    if save:
        os.makedirs(RUNS_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = os.path.join(RUNS_DIR, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(fname, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list_archs())
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="every (arch x shape) cell")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--opts", default="", help="comma list: bf16")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            if args.skip_existing:
                mesh_name = "2x16x16" if mp else "16x16"
                suffix = f"_{args.tag}" if args.tag else ""
                fname = os.path.join(RUNS_DIR, f"{arch}_{shape}_{mesh_name}{suffix}.json")
                if os.path.exists(fname):
                    print(f"[dryrun] skip existing {arch} x {shape} x {mesh_name}")
                    continue
            try:
                r = lower_cell(arch, shape, multi_pod=mp, tag=args.tag,
                               opts=args.opts)
                if "skipped" in r:
                    print(f"[dryrun] SKIP {arch} x {shape}: {r['skipped']}")
            except Exception as e:  # noqa: BLE001 — report every failing cell
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAIL {arch} x {shape} multi_pod={mp}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}/{s}/mp={m}" for a, s, m, _ in failures))
    print("[dryrun] all requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
