"""Production meshes.

Importing this module never touches jax device state — meshes are built
by functions, so the dry-run's XLA_FLAGS device-count override (set
before any jax import) is the only device configuration in play.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
