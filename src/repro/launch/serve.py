"""Serving launcher: batched request serving against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --requests 8 --max-new 16

``--staged`` runs the event-driven pipeline on the §5.2 KV fabric
instead of the synchronous engine: prefill transfers (DMA path) overlap
decode cache reads, the decode placement is re-planned per admitted
request from live ledger occupancy, and the report includes simulated
p50/p99 time-to-first-token. ``--arrival-spacing`` spaces arrivals out
(seconds); 0 = one burst.

``--trace steady|burst`` replaces the fixed request list with a seeded
``repro.scale.TraceSpec`` replay — Poisson arrivals (diurnal + burst
modulated) with heavy-tailed prompt/decode lengths, the same generator
the fleet harness uses. Requires ``--staged``; ``--requests``,
``--prompt-len``, ``--max-new`` and ``--arrival-spacing`` are ignored
in trace mode (counts and lengths come from the trace).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine, StagedServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-fabric", action="store_true",
                    help="plan decode cache placement on the §5.2 fabric")
    ap.add_argument("--staged", action="store_true",
                    help="event-driven pipeline (per-request placement)")
    ap.add_argument("--arrival-spacing", type=float, default=0.0,
                    help="seconds between simulated arrivals (staged)")
    ap.add_argument("--trace", choices=("steady", "burst"), default=None,
                    help="replay a seeded repro.scale trace instead of "
                         "the fixed request list (requires --staged)")
    ap.add_argument("--trace-rate", type=float, default=2.0,
                    help="trace base arrival rate, requests/s")
    ap.add_argument("--trace-duration", type=float, default=20.0,
                    help="trace length in simulated seconds")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="arrival-generator seed (deterministic replay)")
    ap.add_argument("--trace-json", default="", metavar="OUT.json",
                    help="write the staged run's span timeline as "
                         "Chrome-trace JSON (requires --staged; distinct "
                         "from --trace, which replays an arrival trace)")
    args = ap.parse_args(argv)
    if args.trace and not args.staged:
        ap.error("--trace requires --staged")
    if args.trace_json and not args.staged:
        ap.error("--trace-json requires --staged")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    from repro.serve.disagg import kv_fabric, kv_serve_time_model
    if args.staged:
        tracer = None
        if args.trace_json:
            from repro.obs.trace import Tracer
            tracer = Tracer()
        eng = StagedServeEngine(cfg, params, slots=args.slots,
                                max_len=args.max_len, fabric=kv_fabric(),
                                time_model=kv_serve_time_model(),
                                plan_placement=True, tracer=tracer)
    else:
        fabric = kv_fabric() if args.kv_fabric else None
        eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                          fabric=fabric)
        if eng.placement is not None:
            p = eng.placement
            print(f"[serve] decode cache placement: {p.location} "
                  f"({p.rate / 1e6:.1f}M gets/s, "
                  f"+{(p.rate / p.baseline_rate - 1) * 100:.0f}% vs baseline)")

    if args.arrival_spacing and not args.staged:
        print("[serve] note: --arrival-spacing only shapes the simulated "
              "timeline of --staged; the synchronous engine admits a burst")
    if args.trace:
        import dataclasses

        from repro.scale import ArrivalGenerator, TraceSpec, burst_trace
        if args.trace == "burst":
            trace = burst_trace(base_rate=args.trace_rate,
                                duration=args.trace_duration,
                                burst_start=args.trace_duration * 0.25,
                                burst_duration=args.trace_duration * 0.375)
        else:
            trace = TraceSpec("steady", args.trace_rate, args.trace_duration,
                              diurnal_amplitude=0.25,
                              diurnal_period=args.trace_duration)
        # clamp sampled lengths to the engine's slot budget
        trace = dataclasses.replace(trace, prompt=dataclasses.replace(
            trace.prompt,
            high=max(trace.prompt.low,
                     min(trace.prompt.high, args.max_len - trace.decode.high))))
        reqs = ArrivalGenerator(trace, seed=args.trace_seed,
                                vocab=cfg.vocab_size).requests()
        for r in reqs:
            if cfg.num_codebooks > 1:
                r.prompt = np.tile(r.prompt[:, None], (1, cfg.num_codebooks))
            r.temperature = args.temperature
            eng.submit(r)
        print(f"[serve] trace {trace.name!r}: {len(reqs)} arrivals over "
              f"{trace.duration:.0f}s (mean {trace.mean_rate:.1f} req/s, "
              f"peak {trace.peak_rate:.1f} req/s, seed {args.trace_seed})")
    else:
        rng = np.random.default_rng(0)
        reqs = []
        for i in range(args.requests):
            shape = ((args.prompt_len, cfg.num_codebooks)
                     if cfg.num_codebooks > 1 else (args.prompt_len,))
            prompt = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
            r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new,
                        temperature=args.temperature,
                        arrival=i * args.arrival_spacing if args.staged else 0.0)
            reqs.append(r)
            eng.submit(r)

    t0 = time.monotonic()
    eng.run()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s); decode_steps={eng.stats['decode_steps']} "
          f"prefill_compilations={eng.stats['prefill_compilations']}")
    if args.staged:
        p50, p99 = np.percentile([r.ttft for r in reqs], [50, 99])
        print(f"[serve] simulated TTFT p50={p50 * 1e3:.3f}ms "
              f"p99={p99 * 1e3:.3f}ms makespan="
              f"{eng.clock.now * 1e3:.3f}ms placements={eng.placements}")
        if args.trace_json:
            from repro.obs.export import dump
            dump(eng.runtime.tracer, args.trace_json)
            print(f"[trace] {len(eng.runtime.tracer.spans)} spans -> "
                  f"{args.trace_json}")
    for r in reqs[:4]:
        print(f"  req{r.rid}: {r.out_tokens[:10]}{'...' if len(r.out_tokens) > 10 else ''}")
    assert all(r.done for r in reqs)
    return reqs


if __name__ == "__main__":
    main()
