"""Serving launcher: batched request serving against a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-fabric", action="store_true",
                    help="plan decode cache placement on the §5.2 fabric")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    fabric = None
    if args.kv_fabric:
        from repro.serve.disagg import kv_fabric
        fabric = kv_fabric()
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                      fabric=fabric)
    if eng.placement is not None:
        p = eng.placement
        print(f"[serve] decode cache placement: {p.location} "
              f"({p.rate / 1e6:.1f}M gets/s, "
              f"+{(p.rate / p.baseline_rate - 1) * 100:.0f}% vs baseline)")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        shape = ((args.prompt_len, cfg.num_codebooks)
                 if cfg.num_codebooks > 1 else (args.prompt_len,))
        prompt = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
        r = Request(rid=i, prompt=prompt, max_new_tokens=args.max_new,
                    temperature=args.temperature)
        reqs.append(r)
        eng.submit(r)

    t0 = time.monotonic()
    eng.run()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s); decode_steps={eng.stats['decode_steps']}")
    for r in reqs[:4]:
        print(f"  req{r.rid}: {r.out_tokens[:10]}{'...' if len(r.out_tokens) > 10 else ''}")
    assert all(r.done for r in reqs)
    return reqs


if __name__ == "__main__":
    main()
