"""Serve+train colocation launcher (the §6 multi-tenant study).

Runs a ``StagedServeEngine`` (latency tenant, real jax decode) and a
``TrainCluster`` (throughput tenant, timing-only) on one merged fabric
and one budget ledger, in three configurations:

  solo       each tenant alone on the fabric (the baselines);
  unmanaged  both tenants, equal fair shares — the §6 collapse;
  managed    QoS weights + the SLO-driven admission controller.

``--mode all`` (default) runs the sweep and prints the crossover table:
serve p50/p99 TTFT vs solo, train tokens/s retention, throttle count,
and the per-path occupancy attribution of the managed run.

Usage:
  PYTHONPATH=src python -m repro.launch.colocate --arch internlm2-1.8b \
      --reduced --requests 8 --train-steps 4 --serve-weight 16 \
      --slo-factor 1.2
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, StagedServeEngine
from repro.tenancy import (AdmissionConfig, Colocation, QoSPolicy, SERVE,
                           TRAIN, colocation_fabric, colocation_time_model,
                           solo_serve, solo_train)
from repro.train.cluster import ClusterTimeModel, TrainCluster


def build_pieces(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    fabric = lambda: colocation_fabric(  # noqa: E731 — fresh per run
        args.nodes, host_bw=args.host_bw, soc_frac=args.soc_frac,
        net_bw_per_node=100.0, decode_bw=4 * args.host_bw,
        concurrency_discount=args.discount)
    tm = colocation_time_model(0, prefill_units_per_token=args.prefill_units,
                               decode_units_per_slot=args.decode_units)
    ctm = ClusterTimeModel(compute_s=args.compute_s,
                           grad_bytes=args.grad_units,
                           ckpt_bytes=args.ckpt_units,
                           ckpt_path=args.ckpt_staging,
                           tokens_per_step=args.tokens_per_step)

    def make_engine(rt):
        return StagedServeEngine(cfg, params, slots=args.slots, max_len=64,
                                 impl="ref", runtime=rt, time_model=tm,
                                 tenant=SERVE)

    def make_cluster(rt):
        return TrainCluster(args.nodes, ctm, fabric=rt.fabric, runtime=rt,
                            ckpt_every=args.ckpt_every, tenant=TRAIN)

    def requests():
        rng = np.random.default_rng(args.seed)
        return [Request(rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, args.prompt_len).astype(np.int32),
                        max_new_tokens=args.max_new,
                        arrival=args.spacing * i)
                for i in range(args.requests)]

    return fabric, make_engine, make_cluster, requests


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU example mode)")
    ap.add_argument("--mode", default="all",
                    choices=["all", "solo", "unmanaged", "managed"])
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--train-steps", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--spacing", type=float, default=0.3,
                    help="request inter-arrival seconds")
    ap.add_argument("--serve-weight", type=float, default=16.0)
    ap.add_argument("--train-weight", type=float, default=1.0)
    ap.add_argument("--slo-factor", type=float, default=1.2,
                    help="SLO = factor x solo p99 TTFT")
    ap.add_argument("--occupancy-limit", type=float, default=None,
                    help="pre-emptive throttle: train share of the "
                         "prefill path (e.g. 0.4)")
    ap.add_argument("--host-bw", type=float, default=16.0,
                    help="path units/s of each host path (toy units)")
    ap.add_argument("--soc-frac", type=float, default=0.7)
    ap.add_argument("--discount", type=float, default=0.1)
    ap.add_argument("--prefill-units", type=float, default=0.25,
                    help="path units per prompt token on the shared "
                         "prefill path")
    ap.add_argument("--decode-units", type=float, default=0.25,
                    help="path units per active slot per decode step on "
                         "the serve-private decode path")
    ap.add_argument("--grad-units", type=float, default=16.0)
    ap.add_argument("--ckpt-units", type=float, default=8.0)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--ckpt-staging", default="soc",
                    choices=["soc", "host", "auto"])
    ap.add_argument("--compute-s", type=float, default=0.3)
    ap.add_argument("--tokens-per-step", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="write the managed run's span timeline (or the "
                         "unmanaged run's, with --mode unmanaged) as "
                         "Chrome-trace JSON + print the attribution "
                         "summary (load in chrome://tracing or "
                         "ui.perfetto.dev)")
    args = ap.parse_args(argv)

    fabric, make_engine, make_cluster, requests = build_pieces(args)
    out = {}

    solo_s = solo_serve(fabric(), make_engine, requests())
    solo_t = solo_train(fabric(), make_cluster, args.train_steps)
    out["solo"] = (solo_s, solo_t)
    print(f"[solo]      serve p50={solo_s['p50_ttft']:.4f}s "
          f"p99={solo_s['p99_ttft']:.4f}s | "
          f"train {solo_t['tokens_per_s']:,.0f} tokens/s")
    if args.mode == "solo":
        return out

    slo = args.slo_factor * solo_s["p99_ttft"]
    watch = (colocation_time_model(0).prefill_path,)

    def show(tag, rep):
        infl = rep.serve["p99_ttft"] / solo_s["p99_ttft"]
        keep = rep.train["tokens_per_s"] / solo_t["tokens_per_s"]
        print(f"[{tag:<9}] serve p50={rep.serve['p50_ttft']:.4f}s "
              f"p99={rep.serve['p99_ttft']:.4f}s ({infl:.2f}x solo) | "
              f"train {rep.train['tokens_per_s']:,.0f} tokens/s "
              f"({keep:.1%} of solo) | throttles={rep.throttles}")

    tracer = None
    if args.trace:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    if args.mode in ("all", "unmanaged"):
        rep = Colocation(fabric=fabric(), make_engine=make_engine,
                         make_cluster=make_cluster,
                         tracer=tracer if args.mode == "unmanaged" else None,
                         ).run(requests(), args.train_steps)
        out["unmanaged"] = rep
        show("unmanaged", rep)
    if args.mode in ("all", "managed"):
        rep = Colocation(
            fabric=fabric(), make_engine=make_engine,
            make_cluster=make_cluster,
            qos=QoSPolicy.serve_train(args.serve_weight, args.train_weight),
            admission=AdmissionConfig(
                slo_ttft=slo, occupancy_limit=args.occupancy_limit,
                watch_paths=watch if args.occupancy_limit else ()),
            tracer=tracer,
            ).run(requests(), args.train_steps)
        out["managed"] = rep
        show("managed", rep)
        print("[occupancy] " + "  ".join(
            f"{path}:{{{', '.join(f'{t}={f:.2f}' for t, f in sorted(per.items()))}}}"
            for path, per in sorted(rep.occupancy.items())))
        for e in rep.events:
            if e["event"] in ("throttle", "resume"):
                print(f"[admission] t={e['t']:.3f}s {e['event']} "
                      f"({e.get('reason', '')})")

    if tracer is not None:
        from repro.obs.export import dump, summary
        dump(tracer, args.trace)
        print(f"[trace] {len(tracer.spans)} spans -> {args.trace} "
              "(load in chrome://tracing or ui.perfetto.dev)")
        print(summary(tracer))
    return out


if __name__ == "__main__":
    main()
