"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation (the dry-run contract)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.model import init_cache_logical
from repro.models.params import abstract_params
from repro.parallel.sharding import (CONTEXT_PARALLEL_OVERRIDES,
                                     logical_to_spec, named_sharding,
                                     tree_shardings)

Spec = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Spec]:
    """Training/prefill batch: tokens/labels/mask (+ frontend embeds)."""
    b, s = shape.global_batch, shape.seq_len
    ft = cfg.frontend_tokens if cfg.frontend else 0
    s_text = s - ft
    cb = cfg.num_codebooks
    tok_shape = (b, s_text, cb) if cb > 1 else (b, s_text)
    lab_shape = (b, s, cb) if cb > 1 else (b, s)
    out = {
        "tokens": Spec(tok_shape, jnp.int32),
        "labels": Spec(lab_shape if ft else tok_shape, jnp.int32),
        "loss_mask": Spec((b, s), jnp.float32),
    }
    if ft:
        out["frontend_embeds"] = Spec((b, ft, cfg.d_model), jnp.float32)
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    specs = batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = named_sharding(logical, mesh, dim_sizes=v.shape)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig,
                 cache_dtype=jnp.bfloat16) -> Tuple[Dict[str, Spec], Any, Spec]:
    """(token specs, cache specs, pos spec) for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cb = cfg.num_codebooks
    tok_shape = (b, 1, cb) if cb > 1 else (b, 1)
    tokens = {"tokens": Spec(tok_shape, jnp.int32)}
    cache = M.abstract_cache(cfg, b, s, cache_dtype)[0]
    return tokens, cache, Spec((), jnp.int32)


def decode_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     context_parallel: bool = False):
    overrides = {}
    if context_parallel:
        overrides.update(CONTEXT_PARALLEL_OVERRIDES)
    elif cfg.num_kv_heads and "model" in mesh.shape and \
            cfg.num_kv_heads % mesh.shape["model"] != 0:
        # KV heads don't divide TP: shard the cache on its sequence dim
        # instead of replicating 16 copies (paper: place the value store
        # on the path where reads stay cheap; avoids the all-gather of
        # the entire cache every step).
        overrides["kv_seq"] = "model"
    overrides = overrides or None
    tokens, cache, _ = decode_specs(cfg, shape)
    tok_sh = {k: named_sharding(("batch",) + (None,) * (len(v.shape) - 1),
                                mesh, dim_sizes=v.shape, overrides=overrides)
              for k, v in tokens.items()}
    cache_logical = init_cache_logical(cfg)
    cache_sh = jax.tree.map(
        lambda lg, spec: named_sharding(lg, mesh, dim_sizes=spec.shape,
                                        overrides=overrides),
        cache_logical, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return tok_sh, cache_sh


def param_shardings(cfg: ModelConfig, mesh):
    shapes, logical = abstract_params(cfg)
    return shapes, logical, tree_shardings(logical, shapes, mesh)
