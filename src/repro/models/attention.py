"""Attention implementations.

- ``attention_ref``     : simple O(S^2) reference (tests, small shapes).
- ``attention_blocked`` : flash-style blocked scan in pure JAX. Memory
  O(B * block * H * hd); block-level skipping of fully-masked (causal /
  out-of-window) KV blocks via ``lax.cond`` so compiled FLOPs track the
  useful work. This is the CPU/dry-run stand-in for the Pallas kernel.
- ``decode_attention``  : single-token attention against a KV cache.
- ``decode_attention_context_parallel`` : KV cache sharded over a mesh
  axis (long-context serving); per-shard partial softmax merged with a
  log-sum-exp reduction — the DrTM-KV "index here, value there" pattern
  mapped onto TPU collectives.

Shapes: q (B, Sq, Hq, hd); k/v (B, Skv, Hkv, hd); GQA via Hq % Hkv == 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _softcap(scores: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def _expand_kv(x: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd) by repetition."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  q_offset: int = 0) -> jax.Array:
    """Quadratic reference. q_offset: absolute position of q[0] (for
    decode/suffix attention against a longer KV prefix)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    q = q.astype(jnp.float32)
    k = _expand_kv(k, hq // hkv).astype(jnp.float32)
    v = _expand_kv(v, hq // hkv).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(d).astype(jnp.float32)
    scores = _softcap(scores, softcap)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.astype(v.dtype)


def attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_block: int = 512,
                      kv_block: int = 512) -> jax.Array:
    """Flash-style attention with online softmax, blocked over q and kv.

    Fully-masked KV blocks are skipped with ``lax.cond`` (real HLO
    conditional inside the sequential scan), mirroring the block-skip the
    Pallas kernel does on TPU — compiled FLOPs stay close to useful FLOPs
    instead of paying the 2x dense-causal tax (paper Advice #2/#3:
    granularity-aware segmentation).
    """
    b, s, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert s == skv, "blocked path is for self-attention (train/prefill)"
    if s % q_block or s % kv_block:
        return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    groups = hq // hkv
    scale = 1.0 / (d ** 0.5)
    nq, nkv = s // q_block, s // kv_block

    # Work in (B, H, S, d): the head dim (sharded over `model`) never
    # moves, and q/kv blocks are dynamic slices on the local S dim —
    # no stacked reshapes for the scan, hence no SPMD resharding
    # (the per-layer all-to-alls the baseline paid for).
    qt = q.swapaxes(1, 2).astype(jnp.float32) * scale    # (B, Hq, S, d)
    kt = k.swapaxes(1, 2).astype(jnp.float32)            # (B, Hkv, S, d)
    vt = v.swapaxes(1, 2).astype(jnp.float32)

    def kv_expand(x):                                    # (B,Hkv,kb,d)->(B,Hq,kb,d)
        if groups == 1:
            return x
        bb, hh, ss, dd = x.shape
        return jnp.broadcast_to(x[:, :, None], (bb, hh, groups, ss, dd)) \
            .reshape(bb, hh * groups, ss, dd)

    def q_step(out_buf, qi):
        qblk = jax.lax.dynamic_slice_in_dim(qt, qi * q_block, q_block, axis=2)

        def kv_step(carry, ki):
            m, l, acc = carry

            def compute(args):
                m, l, acc = args
                kblk = kv_expand(jax.lax.dynamic_slice_in_dim(
                    kt, ki * kv_block, kv_block, axis=2))
                vblk = kv_expand(jax.lax.dynamic_slice_in_dim(
                    vt, ki * kv_block, kv_block, axis=2))
                sc = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk)
                sc = _softcap(sc, softcap)
                qpos = qi * q_block + jnp.arange(q_block)[:, None]
                kpos = ki * kv_block + jnp.arange(kv_block)[None, :]
                msk = jnp.ones((q_block, kv_block), dtype=bool)
                if causal:
                    msk &= kpos <= qpos
                if window is not None:
                    msk &= kpos > qpos - window
                sc = jnp.where(msk[None, None], sc, NEG_INF)
                m_new = jnp.maximum(m, sc.max(axis=-1))
                # mask-multiply: rows with no valid column contribute zero
                p = jnp.exp(sc - m_new[..., None]) * msk[None, None]
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk)
                return m_new, l_new, acc_new

            needed = jnp.array(True)
            if causal:       # block strictly above the diagonal -> skip
                needed &= ki * kv_block <= qi * q_block + (q_block - 1)
            if window is not None:  # block entirely left of the window -> skip
                needed &= (ki + 1) * kv_block - 1 > qi * q_block - window
            m, l, acc = jax.lax.cond(needed, compute, lambda a: a, (m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((b, hq, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_block), jnp.float32)
        a0 = jnp.zeros((b, hq, q_block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        oblk = acc / jnp.maximum(l, 1e-30)[..., None]    # (B, Hq, qb, d)
        out_buf = jax.lax.dynamic_update_slice_in_dim(
            out_buf, oblk.astype(out_buf.dtype), qi * q_block, axis=2)
        return out_buf, None

    out0 = jnp.zeros((b, hq, s, d), v.dtype)
    out, _ = jax.lax.scan(q_step, out0, jnp.arange(nq))
    return out.swapaxes(1, 2)                            # (B, S, Hq, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jax.Array:
    """One-token attention. q (B, 1, Hq, hd); caches (B, S, Hkv, hd);
    cache_len (scalar or (B,)) = number of valid cache slots (including
    the token written this step)."""
    b, _, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    groups = hq // hkv
    qf = q.astype(jnp.float32)[:, 0]                      # (B, Hq, d)
    kf = _expand_kv(k_cache, groups).astype(jnp.float32)  # (B, S, Hq, d)
    vf = _expand_kv(v_cache, groups).astype(jnp.float32)
    scores = jnp.einsum("bhd,bkhd->bhk", qf, kf) / (d ** 0.5)
    scores = _softcap(scores, softcap)
    kpos = jnp.arange(s)[None, :]
    clen = jnp.asarray(cache_len).reshape(-1, 1)          # (B or 1, 1)
    mask = kpos < clen
    if window is not None:
        mask &= kpos >= clen - window
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vf)
    return out[:, None].astype(v_cache.dtype)


def decode_attention_context_parallel(q, k_cache, v_cache, cache_len, *,
                                      mesh, axis: str = "data",
                                      batch_axes=("pod", "data"),
                                      window=None, softcap=None):
    """Sharded-cache decode: the KV cache's sequence dim is sharded over
    ``axis``; each shard computes a partial (m, l, o) and shards merge
    with a log-sum-exp reduction over the axis (flash-decoding).

    Used for (a) long-context serving (axis="data") and (b) GQA models
    whose KV heads don't divide the TP axis (axis="model") — instead of
    replicating the cache TP-fold, the *sequence* shards and the merge
    traffic is O(B*H*hd) per layer.

    Paper mapping: the query visits a *remote, sharded* value store and
    partial results are combined — DrTM-KV's multi-path get, with the LSE
    merge playing the role of the client-side combine.

    ``batch_axes``: mesh axes the cache batch dim shards over (filtered
    for divisibility automatically).
    """
    from jax import shard_map  # JAX >= 0.8

    b, _, hq, d = q.shape
    s_global, hkv = k_cache.shape[1], k_cache.shape[2]
    groups = hq // hkv
    bax, rem = [], b
    for a in batch_axes:
        if a != axis and a in mesh.shape and mesh.shape[a] > 1 \
                and rem % mesh.shape[a] == 0:
            bax.append(a)
            rem //= mesh.shape[a]
    bspec = tuple(bax) if len(bax) > 1 else (bax[0] if bax else None)

    def per_shard(q, kc, vc, clen):
        idx = jax.lax.axis_index(axis)
        s_local = kc.shape[1]
        qf = q.astype(jnp.float32)[:, 0]
        kf = _expand_kv(kc, groups).astype(jnp.float32)
        vf = _expand_kv(vc, groups).astype(jnp.float32)
        scores = jnp.einsum("bhd,bkhd->bhk", qf, kf) / (d ** 0.5)
        scores = _softcap(scores, softcap)
        kpos = idx * s_local + jnp.arange(s_local)[None, :]
        clen2 = jnp.asarray(clen).reshape(-1, 1)
        mask = kpos < clen2
        if window is not None:
            mask &= kpos >= clen2 - window
        scores = jnp.where(mask[:, None, :], scores, NEG_INF)
        m = scores.max(axis=-1)                                   # (B,H)
        # guard all-masked shards
        p = jnp.exp(scores - m[..., None])
        l = jnp.sum(p * mask[:, None, :], axis=-1)
        o = jnp.einsum("bhk,bkhd->bhd", p * mask[:, None, :], vf)
        # LSE-merge across shards
        m_glob = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * corr, axis)
        o_glob = jax.lax.psum(o * corr[..., None], axis)
        out = o_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        return out[:, None].astype(vc.dtype)

    return shard_map(
        per_shard, mesh=mesh,
        in_specs=(P(bspec), P(bspec, axis, None, None),
                  P(bspec, axis, None, None), P()),
        out_specs=P(bspec), check_vma=False,
    )(q, k_cache, v_cache, cache_len)


def attention(q, k, v, *, causal=True, window=None, softcap=None,
              impl: str = "auto", q_block: int = 512, kv_block: int = 512):
    """Dispatch: 'ref' | 'blocked' | 'pallas' | 'auto'."""
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      softcap=softcap)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    if impl == "blocked" or (impl == "auto" and q.shape[1] >= 2048):
        return attention_blocked(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_block=q_block, kv_block=kv_block)
    return attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
