"""Shared layer primitives: RMSNorm, RoPE, MLP, row-parallel projection."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import precision
from repro.parallel.sharding import get_abstract_mesh


def row_parallel(subscripts: str, x: jax.Array, w: jax.Array,
                 x_shard_dim: int, w_shard_dim: int = 0) -> jax.Array:
    """TP row-parallel einsum with an **explicit bf16 psum** over the
    `model` axis (§Perf "bf16 collectives": XLA-CPU otherwise emits the
    partial-sum all-reduce in f32 between its accumulating dot and the
    downcast — 2x wire bytes, plus a redundant backward AR).

    Inside the manual region the backward pass needs no collective at
    all (dy is replicated; dx/dw are shard-local), halving TP traffic
    again. Falls back to a plain einsum when the policy is off, there is
    no model axis, or the sharded dims don't divide.
    """
    mesh = get_abstract_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    seq = x.shape[1]
    applicable = (precision.enabled() and msize > 1
                  and x.shape[x_shard_dim] % msize == 0
                  and w.shape[w_shard_dim] % msize == 0
                  and seq % msize == 0)
    if not applicable:
        return jnp.einsum(subscripts, x, w)

    def inner(x_l, w_l):
        y_part = jnp.einsum(subscripts, x_l, w_l)       # (B, S, D) partial
        # One explicit forward psum (f32: XLA CPU's AllReducePromotion
        # crashes on narrower reduce collectives, and would promote them
        # anyway). The win vs leaving it to auto-SPMD: the backward of
        # psum is identity — dy is replicated and dx/dw are shard-local,
        # so the baseline's *paired* forward+backward all-reduce becomes
        # a single forward one. (A seq-sharded output variant was tried
        # and refuted: resharding churn cost 13x — see EXPERIMENTS §Perf.)
        return jax.lax.psum(y_part.astype(jnp.float32), "model")

    def spec_for(arr, dim):
        return P(*[("model" if i == dim else None) for i in range(arr.ndim)])

    # f32 at the manual boundary: bf16 values crossing a shard_map edge
    # trip the same promotion-pass bug (see variant matrix in §Perf log)
    y = shard_map(inner, mesh=mesh,
                  in_specs=(spec_for(x, x_shard_dim), spec_for(w, w_shard_dim)),
                  out_specs=P(), axis_names={"model"},
                  check_vma=False)(x.astype(jnp.float32), w.astype(jnp.float32))
    return y.astype(jnp.bfloat16)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(q: jax.Array, positions: jax.Array, theta: float,
         fraction: float = 1.0) -> jax.Array:
    """Rotary embedding, half-split (NeoX) layout on the first
    ``fraction`` of head dims. q (..., S, H, hd); positions (S,) or (B,S)."""
    hd = q.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return q
    qr, qp = q[..., :rot], q[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over heads: (..., S, 1, half)
    cos, sin = cos[..., None, :], sin[..., None, :]
    q1, q2 = qr[..., :half], qr[..., half:]
    out = jnp.concatenate([q1 * cos - q2 * sin, q2 * cos + q1 * sin], axis=-1)
    return jnp.concatenate([out.astype(q.dtype), qp], axis=-1)


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def mlp(x: jax.Array, params: dict, activation) -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU). w_in (D,2,F), w_out (F,D)."""
    xc = x.astype(jnp.bfloat16)
    h = jnp.einsum("bsd,dtf->bstf", xc, params["w_in"].astype(jnp.bfloat16))
    gate, up = h[..., 0, :], h[..., 1, :]
    h = activation(gate) * up
    out = row_parallel("bsf,fd->bsd", h, params["w_out"].astype(jnp.bfloat16),
                       x_shard_dim=2, w_shard_dim=0)
    return out.astype(x.dtype)
