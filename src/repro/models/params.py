"""Parameter initialization + logical sharding axes.

Layers are stacked for ``lax.scan``: the layer pattern has a *period*
(gemma2 local/global = 2, jamba = 8, others = 1); ``params["layers"]`` is
a tuple of per-slot trees whose leaves carry a leading ``G = L/period``
group dim. A parallel tree of logical-axis tuples drives sharding
(see parallel/sharding.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


def layer_period(cfg: ModelConfig) -> int:
    period = 1
    for p in (cfg.attn_period, cfg.local_global_period,
              cfg.moe_period if cfg.num_experts else 1):
        if p:
            period = math.lcm(period, p)
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    return period


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // layer_period(cfg)


def slot_kind(cfg: ModelConfig, slot: int) -> Dict[str, Any]:
    """Static description of the layer at period-slot `slot`."""
    return dict(
        kind=cfg.layer_kind(slot),
        local=cfg.is_local_layer(slot),
        moe=cfg.is_moe_layer(slot),
        has_ffn=bool(cfg.d_ff),
    )


# ----------------------------------------------------------------------
def _norm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": ("embed",)}


def _attn_shapes(cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "wq": ((d, hq, hd), ("fsdp", "heads", None)),
        "wk": ((d, hkv, hd), ("fsdp", "kv_heads", None)),
        "wv": ((d, hkv, hd), ("fsdp", "kv_heads", None)),
        "wo": ((hq, hd, d), ("heads", None, "fsdp")),
    }
    return shapes


def _mlp_shapes(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": ((d, 2, f), ("fsdp", None, "mlp")),
        "w_out": ((f, d), ("mlp", "fsdp")),
    }


def _moe_shapes(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ((d, e), ("fsdp", None)),
        "w_in": ((e, d, 2, f), ("experts", "fsdp", None, None)),
        "w_out": ((e, f, d), ("experts", None, "fsdp")),
    }


def _ssm_shapes(cfg: ModelConfig):
    d, din, n, h, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_conv)
    return {
        "w_xz": ((d, 2, din), ("fsdp", None, "ssm_inner")),
        "w_bc": ((d, 2, n), ("fsdp", None, None)),
        "w_dt": ((d, h), ("fsdp", "ssm_inner")),
        "conv_x": ((k, din), (None, "ssm_inner")),
        "conv_b": ((k, n), (None, None)),
        "conv_c": ((k, n), (None, None)),
        "A_log": ((h,), ("ssm_inner",)),
        "D": ((h,), ("ssm_inner",)),
        "dt_bias": ((h,), ("ssm_inner",)),
        "norm": ((din,), ("ssm_inner",)),
        "out": ((din, d), ("ssm_inner", "fsdp")),
    }


def _init_dense(key, shape, fan_in):
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / math.sqrt(max(fan_in, 1))))


def _init_slot(cfg: ModelConfig, slot: int, key) -> Tuple[dict, dict]:
    """One (un-stacked) layer's params + logical axes for period-slot."""
    kind = slot_kind(cfg, slot)
    params, logical = {}, {}
    params["norm1"], logical["norm1"] = _norm(cfg.d_model)

    keys = jax.random.split(key, 24)
    ki = iter(range(24))

    if kind["kind"] == "attn":
        shapes = _attn_shapes(cfg)
        sub_p, sub_l = {}, {}
        for name, (shp, lg) in shapes.items():
            fan_in = shp[0] if name != "wo" else cfg.q_dim
            sub_p[name] = _init_dense(keys[next(ki)], shp, fan_in)
            sub_l[name] = lg
        params["attn"], logical["attn"] = sub_p, sub_l
    else:
        shapes = _ssm_shapes(cfg)
        sub_p, sub_l = {}, {}
        for name, (shp, lg) in shapes.items():
            k = keys[next(ki)]
            if name == "A_log":
                # A in [1, 16] (mamba2 init)
                sub_p[name] = jnp.log(jax.random.uniform(k, shp, jnp.float32, 1.0, 16.0))
            elif name == "dt_bias":
                # dt in [1e-3, 1e-1] through softplus
                u = jax.random.uniform(k, shp, jnp.float32)
                dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
                sub_p[name] = dt + jnp.log(-jnp.expm1(-dt))
            elif name in ("D", "norm"):
                sub_p[name] = jnp.ones(shp, jnp.float32)
            elif name.startswith("conv"):
                sub_p[name] = _init_dense(k, shp, cfg.ssm_conv)
            else:
                sub_p[name] = _init_dense(k, shp, shp[0])
            sub_l[name] = lg
        params["ssm"], logical["ssm"] = sub_p, sub_l

    if kind["has_ffn"]:
        params["norm2"], logical["norm2"] = _norm(cfg.d_model)
        shapes = _moe_shapes(cfg) if kind["moe"] else _mlp_shapes(cfg)
        sub_p, sub_l = {}, {}
        for name, (shp, lg) in shapes.items():
            fan_in = cfg.d_model if name in ("router", "w_in") else cfg.d_ff
            sub_p[name] = _init_dense(keys[next(ki)], shp, fan_in)
            sub_l[name] = lg
        key_name = "moe" if kind["moe"] else "mlp"
        params[key_name], logical[key_name] = sub_p, sub_l

    return params, logical


def init_params(cfg: ModelConfig, key) -> Tuple[PyTree, PyTree]:
    """Returns (params, logical_axes) with identical tree structure.

    Logical-axis leaves are tuples with one entry per array dim (the
    stacked layer leaves get a leading "layer_group" entry).
    """
    period = layer_period(cfg)
    g = num_groups(cfg)
    kall = jax.random.split(key, period + 3)

    # embedding (+ codebooks for musicgen)
    vshape = ((cfg.num_codebooks, cfg.vocab_size, cfg.d_model)
              if cfg.num_codebooks > 1 else (cfg.vocab_size, cfg.d_model))
    vlogical = ((None, "vocab", "fsdp") if cfg.num_codebooks > 1
                else ("vocab", "fsdp"))
    params: dict = {"embed": {"table": jax.random.normal(kall[0], vshape, jnp.float32) * 0.02}}
    logical: dict = {"embed": {"table": vlogical}}

    layers_p, layers_l = [], []
    for slot in range(period):
        gk = jax.random.split(kall[1 + slot], g)
        stacked = jax.vmap(lambda k: _init_slot(cfg, slot, k)[0])(gk)
        _, slot_logical = _init_slot(cfg, slot, gk[0])
        slot_logical = jax.tree.map(
            lambda lg: ("layer_group",) + lg, slot_logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        layers_p.append(stacked)
        layers_l.append(slot_logical)
    params["layers"] = tuple(layers_p)
    logical["layers"] = tuple(layers_l)

    params["final_norm"], logical["final_norm"] = _norm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": jax.random.normal(kall[-1], vshape, jnp.float32) * 0.02}
        logical["lm_head"] = {"w": vlogical}
    return params, logical


def param_count_tree(params: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def abstract_params(cfg: ModelConfig) -> Tuple[PyTree, PyTree]:
    """ShapeDtypeStruct version of init_params — no allocation (dry-run)."""
    out_shape = jax.eval_shape(lambda k: init_params(cfg, k)[0],
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
    _, logical = _logical_only(cfg)
    return out_shape, logical


def _logical_only(cfg: ModelConfig):
    """Logical-axis tree without touching any arrays (dry-run safe)."""
    vlogical = ((None, "vocab", "fsdp") if cfg.num_codebooks > 1
                else ("vocab", "fsdp"))
    logical: dict = {"embed": {"table": vlogical}}
    logical["layers"] = tuple(_slot_logical(cfg, slot)
                              for slot in range(layer_period(cfg)))
    logical["final_norm"] = {"scale": ("embed",)}
    if not cfg.tie_embeddings:
        logical["lm_head"] = {"w": vlogical}
    return None, logical


def _slot_logical(cfg: ModelConfig, slot: int):
    kind = slot_kind(cfg, slot)
    logical = {"norm1": {"scale": ("embed",)}}
    if kind["kind"] == "attn":
        logical["attn"] = {n: lg for n, (s, lg) in _attn_shapes(cfg).items()}
    else:
        logical["ssm"] = {n: lg for n, (s, lg) in _ssm_shapes(cfg).items()}
    if kind["has_ffn"]:
        logical["norm2"] = {"scale": ("embed",)}
        if kind["moe"]:
            logical["moe"] = {n: lg for n, (s, lg) in _moe_shapes(cfg).items()}
        else:
            logical["mlp"] = {n: lg for n, (s, lg) in _mlp_shapes(cfg).items()}
    return jax.tree.map(
        lambda lg: ("layer_group",) + lg, logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
