"""Unified LM: dense / MoE / SSM / hybrid / VLM / audio backbones.

One forward covers train & prefill; ``decode_step`` covers single-token
serving against a cache. Layers run under ``lax.scan`` over period-groups
(HLO stays O(1) in depth) with optional remat.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import activation_fn, mlp, rmsnorm, rope, row_parallel
from repro.models.moe import moe_ffn
from repro.models.params import layer_period, num_groups, slot_kind
from repro.models import precision
from repro.parallel.sharding import constrain

PyTree = Any


class ForwardResult(NamedTuple):
    hidden: jax.Array          # (B, S, D)
    aux_loss: jax.Array        # MoE load-balance loss (0 for non-MoE)


# ----------------------------------------------------------------------
# embeddings
# ----------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    table = params["embed"]["table"]
    if cfg.num_codebooks > 1:
        # tokens (B, S, C): sum of per-codebook embeddings
        parts = [jnp.take(table[c], tokens[..., c], axis=0)
                 for c in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(table, tokens, axis=0)
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return constrain(x, "batch", "seq", "embed")


# ----------------------------------------------------------------------
# single layer
# ----------------------------------------------------------------------

def _attention_mixer(cfg: ModelConfig, kind: dict, p: dict, x: jax.Array, *,
                     positions, impl: str, cache=None, pos=None,
                     cp_axis=None, mesh=None):
    window = cfg.window_size if kind["local"] else None
    xc = x.astype(jnp.bfloat16)
    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(jnp.bfloat16))
    k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"].astype(jnp.bfloat16))
    v = jnp.einsum("bsd,dhk->bshk", xc, p["wv"].astype(jnp.bfloat16))
    q = constrain(q, "batch", "seq", "act_heads", None)
    q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

    new_cache = None
    if cache is None:
        out = attn_mod.attention(q, k, v, causal=True, window=window,
                                 softcap=cfg.attn_logit_softcap, impl=impl)
    else:
        if pos.ndim == 0:      # aligned batch: one shared position
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        else:                  # continuous batching: per-row positions
            bidx = jnp.arange(k.shape[0])
            k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cache["k"].dtype))
            v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": k_cache, "v": v_cache}
        cache_len = pos + 1
        if cp_axis:
            out = attn_mod.decode_attention_context_parallel(
                q, k_cache, v_cache, cache_len, mesh=mesh, axis=cp_axis,
                window=window, softcap=cfg.attn_logit_softcap)
        else:
            out = attn_mod.decode_attention(
                q, k_cache, v_cache, cache_len,
                window=window, softcap=cfg.attn_logit_softcap)
    out = constrain(out, "batch", "seq", "act_heads", None)
    y = row_parallel("bshk,hkd->bsd", out.astype(jnp.bfloat16),
                     p["wo"].astype(jnp.bfloat16), x_shard_dim=2, w_shard_dim=0)
    return y.astype(x.dtype), new_cache


def _ssm_mixer(cfg: ModelConfig, p: dict, x: jax.Array, *,
               cache=None, impl: str = "auto"):
    """Mamba2 block. cache: {"h": (B,H,P,N), "conv_x/b/c": states} for decode."""
    b, s, d = x.shape
    din, n, h_heads, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xc = x.astype(jnp.bfloat16)
    xz = jnp.einsum("bsd,dti->bsti", xc, p["w_xz"].astype(jnp.bfloat16))
    x_in, z = xz[..., 0, :], xz[..., 1, :]                  # (B,S,din)
    x_in = constrain(x_in, "batch", "seq", "act_mlp")
    bc = jnp.einsum("bsd,dtn->bstn", xc, p["w_bc"].astype(jnp.bfloat16))
    b_in, c_in = bc[..., 0, :], bc[..., 1, :]               # (B,S,N)
    dt_raw = jnp.einsum("bsd,dh->bsh", xc, p["w_dt"].astype(jnp.bfloat16))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if cache is None:
        x_conv, _ = ssm_mod.causal_conv(x_in, p["conv_x"].astype(x_in.dtype))
        b_conv, _ = ssm_mod.causal_conv(b_in, p["conv_b"].astype(b_in.dtype))
        c_conv, _ = ssm_mod.causal_conv(c_in, p["conv_c"].astype(c_in.dtype))
        x_conv, b_conv, c_conv = map(jax.nn.silu, (x_conv, b_conv, c_conv))
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        xh = x_conv.reshape(b, s, h_heads, hd)
        if impl == "pallas" and s % cfg.ssm_chunk == 0:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, _ = ssd_ops.ssd_scan(xh, dt, A, b_conv, c_conv,
                                    chunk=cfg.ssm_chunk)
        else:
            y, _ = ssm_mod.ssd_chunked(xh, dt, A, b_conv, c_conv, chunk=cfg.ssm_chunk)
        y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
        y = y.reshape(b, s, din)
    else:
        x_c, cs_x = ssm_mod.causal_conv_step(x_in[:, 0], p["conv_x"].astype(x_in.dtype), cache["conv_x"])
        b_c, cs_b = ssm_mod.causal_conv_step(b_in[:, 0], p["conv_b"].astype(b_in.dtype), cache["conv_b"])
        c_c, cs_c = ssm_mod.causal_conv_step(c_in[:, 0], p["conv_c"].astype(c_in.dtype), cache["conv_c"])
        x_c, b_c, c_c = map(jax.nn.silu, (x_c, b_c, c_c))
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        xh = x_c.reshape(b, h_heads, hd)
        yt, hnew = ssm_mod.ssd_decode_step(xh, dt, A, b_c, c_c, cache["h"])
        yt = yt + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
        y = yt.reshape(b, 1, din)
        new_cache = {"h": hnew, "conv_x": cs_x, "conv_b": cs_b, "conv_c": cs_c}

    # gated RMSNorm (mamba2)
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = row_parallel("bsi,id->bsd", y.astype(jnp.bfloat16),
                       p["out"].astype(jnp.bfloat16), x_shard_dim=2, w_shard_dim=0)
    return out.astype(x.dtype), new_cache


def apply_layer(cfg: ModelConfig, slot: int, p: dict, x: jax.Array, *,
                positions, impl: str = "auto", cache=None, pos=None,
                cp_axis=None, mesh=None,
                capacity_factor=1.25):
    kind = slot_kind(cfg, slot)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"]["scale"], cfg.norm_eps)
    if kind["kind"] == "attn":
        mix, new_cache = _attention_mixer(
            cfg, kind, p["attn"], h, positions=positions, impl=impl,
            cache=cache, pos=pos, cp_axis=cp_axis, mesh=mesh)
    else:
        mix, new_cache = _ssm_mixer(cfg, p["ssm"], h, cache=cache, impl=impl)
    x = x + mix
    if kind["has_ffn"]:
        h = rmsnorm(x, p["norm2"]["scale"], cfg.norm_eps)
        if kind["moe"]:
            y, metrics = moe_ffn(h, p["moe"], num_experts=cfg.num_experts,
                                 top_k=cfg.num_experts_per_tok,
                                 activation=activation_fn(cfg.mlp_activation),
                                 capacity_factor=capacity_factor)
            aux = aux + metrics.aux_loss
        else:
            y = mlp(h, p["mlp"], activation_fn(cfg.mlp_activation))
        x = x + y
    x = constrain(x, "batch", "seq", "embed")
    return x, new_cache, aux


# ----------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------

def forward(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
            frontend_embeds: Optional[jax.Array] = None, *,
            impl: str = "auto", remat: str = "minimal",
            capacity_factor: float = 1.25, unroll: int = 1) -> ForwardResult:
    x = embed_tokens(cfg, params, tokens, frontend_embeds)
    s_total = x.shape[1]
    positions = jnp.arange(s_total)
    period = layer_period(cfg)

    def group_body(carry, group_params):
        x, aux = carry
        for slot in range(period):
            x, _, a = apply_layer(cfg, slot, group_params[slot], x,
                                  positions=positions, impl=impl,
                                  capacity_factor=capacity_factor)
            aux = aux + a
        return (x, aux), None

    if remat == "full":
        group_body = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "minimal":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux), _ = jax.lax.scan(group_body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=unroll)
    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return ForwardResult(hidden=x, aux_loss=aux)


# ----------------------------------------------------------------------
# logits + loss (chunked, vocab-parallel)
# ----------------------------------------------------------------------

def _head_table(cfg: ModelConfig, params: PyTree) -> jax.Array:
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["lm_head"]["w"])


def logits_for(cfg: ModelConfig, params: PyTree, hidden: jax.Array) -> jax.Array:
    """Full logits — small vocab / decode only."""
    table = _head_table(cfg, params).astype(jnp.bfloat16)
    h = hidden.astype(jnp.bfloat16)
    if cfg.num_codebooks > 1:
        logits = jnp.einsum("bsd,cvd->bscv", h, table)
    else:
        logits = jnp.einsum("bsd,vd->bsv", h, table)
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
    return constrain(logits, "batch", "seq", "act_vocab") if cfg.num_codebooks == 1 \
        else constrain(logits, "batch", "seq", None, "act_vocab")


def cross_entropy(cfg: ModelConfig, params: PyTree, hidden: jax.Array,
                  labels: jax.Array, loss_mask: jax.Array, *,
                  chunk: int = 512, z_loss: float = 1e-4):
    """Chunked vocab-parallel CE: never materializes (B, S, V) at once.

    labels (B,S) int32 [(B,S,C) for codebooks]; loss_mask (B,S) f32.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    table = _head_table(cfg, params).astype(jnp.bfloat16)

    hs = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)      # (nc,B,C,D)
    if cfg.num_codebooks > 1:
        ls = labels.reshape(b, nc, chunk, cfg.num_codebooks).swapaxes(0, 1)
    else:
        ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    ms = loss_mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def chunk_body(carry, inp):
        tot, cnt, zacc = carry
        h, lab, msk = inp
        h = h.astype(jnp.bfloat16)
        if cfg.num_codebooks > 1:
            logits = jnp.einsum("bsd,cvd->bscv", h, table).astype(jnp.float32)
        else:
            logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
        if cfg.final_logit_softcap:
            logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)              # (B,C) or (B,C,cb)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        ce = lse - ll
        if cfg.num_codebooks > 1:
            ce = ce.mean(-1)
            lse_for_z = lse.mean(-1)
        else:
            lse_for_z = lse
        tot = tot + (ce * msk).sum()
        zacc = zacc + ((lse_for_z ** 2) * msk).sum()
        cnt = cnt + msk.sum()
        return (tot, cnt, zacc), None

    zero = jnp.zeros((), jnp.float32)
    (tot, cnt, zacc), _ = jax.lax.scan(chunk_body, (zero, zero, zero), (hs, ls, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt + z_loss * zacc / cnt


# ----------------------------------------------------------------------
# KV / state cache + decode
# ----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Tuple[PyTree, PyTree]:
    """Returns (cache, logical_axes). Leaves lead with G (scan dim)."""
    g = num_groups(cfg)
    period = layer_period(cfg)
    slots, slots_l = [], []
    for slot in range(period):
        kind = slot_kind(cfg, slot)
        if kind["kind"] == "attn":
            shp = (g, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            slots.append({"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)})
            lg = ("layer_group", "decode_batch", "kv_seq", "kv_heads", None)
            slots_l.append({"k": lg, "v": lg})
        else:
            din, n, h, hd, k = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                                cfg.ssm_head_dim, cfg.ssm_conv)
            slots.append({
                "h": jnp.zeros((g, batch, h, hd, n), jnp.float32),
                "conv_x": jnp.zeros((g, batch, k - 1, din), dtype),
                "conv_b": jnp.zeros((g, batch, k - 1, n), dtype),
                "conv_c": jnp.zeros((g, batch, k - 1, n), dtype),
            })
            slots_l.append({
                "h": ("layer_group", "decode_batch", "ssm_inner", None, None),
                "conv_x": ("layer_group", "decode_batch", None, "ssm_inner"),
                "conv_b": ("layer_group", "decode_batch", None, None),
                "conv_c": ("layer_group", "decode_batch", None, None),
            })
    return tuple(slots), tuple(slots_l)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """(ShapeDtypeStruct cache, logical axes) — no allocation (dry-run)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype)[0])
    return cache, init_cache_logical(cfg)


def init_cache_logical(cfg: ModelConfig):
    period = layer_period(cfg)
    slots_l = []
    for slot in range(period):
        kind = slot_kind(cfg, slot)
        if kind["kind"] == "attn":
            lg = ("layer_group", "decode_batch", "kv_seq", "kv_heads", None)
            slots_l.append({"k": lg, "v": lg})
        else:
            slots_l.append({
                "h": ("layer_group", "decode_batch", "ssm_inner", None, None),
                "conv_x": ("layer_group", "decode_batch", None, "ssm_inner"),
                "conv_b": ("layer_group", "decode_batch", None, None),
                "conv_c": ("layer_group", "decode_batch", None, None),
            })
    return tuple(slots_l)


def decode_step(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                cache: PyTree, pos: jax.Array, *,
                frontend_embeds: Optional[jax.Array] = None,
                cp_axis=None, mesh=None,
                impl: str = "auto", unroll: int = 1):
    """One decode step. tokens (B,1) [(B,1,C) codebooks]; pos scalar int32
    (aligned batch) or (B,) int32 (continuous batching).
    Returns (logits (B,1,V) [(B,1,C,V)], new_cache)."""
    x = embed_tokens(cfg, params, tokens, frontend_embeds)
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    period = layer_period(cfg)

    def group_body(x, inp):
        group_params, cache_slices = inp
        new_slices = []
        for slot in range(period):
            x, nc, _ = apply_layer(cfg, slot, group_params[slot], x,
                                   positions=positions, impl=impl,
                                   cache=cache_slices[slot], pos=pos,
                                   cp_axis=cp_axis, mesh=mesh,
                                   capacity_factor=None)
            new_slices.append(nc)
        return x, tuple(new_slices)

    x, new_cache = jax.lax.scan(group_body, x, (params["layers"], cache),
                                unroll=unroll)
    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = logits_for(cfg, params, x)
    return logits, new_cache


def prefill(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
            max_len: int, *, frontend_embeds=None, impl: str = "auto",
            cache_dtype=jnp.bfloat16, unroll: int = 1,
            length: Optional[jax.Array] = None):
    """Run the full prompt, building a cache for subsequent decode.
    Returns (last_hidden (B,1,D) logits, cache, next_pos).

    ``length`` (a traced scalar) supports right-padded prompts (the
    serving engine's power-of-two length buckets): logits come from the
    token at ``length - 1`` and ``next_pos`` is ``length``. Causal
    attention makes the pad tail inert for the real tokens, and decode
    masks cache rows ``>= pos``, so the pad K/V are never read. (SSM
    configs must pass exact-length prompts — recurrent state runs
    through every position.)"""
    x = embed_tokens(cfg, params, tokens, frontend_embeds)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    period = layer_period(cfg)
    g = num_groups(cfg)

    def group_body(x, group_params):
        new_slices = []
        for slot in range(period):
            kind = slot_kind(cfg, slot)
            h = rmsnorm(x, group_params[slot]["norm1"]["scale"], cfg.norm_eps)
            if kind["kind"] == "attn":
                p = group_params[slot]["attn"]
                xc = h.astype(jnp.bfloat16)
                q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(jnp.bfloat16))
                k = jnp.einsum("bsd,dhk->bshk", xc, p["wk"].astype(jnp.bfloat16))
                v = jnp.einsum("bsd,dhk->bshk", xc, p["wv"].astype(jnp.bfloat16))
                q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
                k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
                window = cfg.window_size if kind["local"] else None
                out = attn_mod.attention(q, k, v, causal=True, window=window,
                                         softcap=cfg.attn_logit_softcap, impl=impl)
                y = jnp.einsum("bshk,hkd->bsd", out.astype(jnp.bfloat16),
                               p["wo"].astype(jnp.bfloat16))
                x = x + y.astype(x.dtype)
                kc = jnp.zeros((b, max_len, cfg.num_kv_heads, cfg.head_dim), cache_dtype)
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(cache_dtype), 0, axis=1)
                vc = jnp.zeros((b, max_len, cfg.num_kv_heads, cfg.head_dim), cache_dtype)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(cache_dtype), 0, axis=1)
                new_slices.append({"k": kc, "v": vc})
            else:
                p = group_params[slot]["ssm"]
                # full-sequence mix, but also keep final ssm/conv states
                din, n, hh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
                xc = h.astype(jnp.bfloat16)
                xz = jnp.einsum("bsd,dti->bsti", xc, p["w_xz"].astype(jnp.bfloat16))
                x_in, z = xz[..., 0, :], xz[..., 1, :]
                bc = jnp.einsum("bsd,dtn->bstn", xc, p["w_bc"].astype(jnp.bfloat16))
                b_in, c_in = bc[..., 0, :], bc[..., 1, :]
                dt_raw = jnp.einsum("bsd,dh->bsh", xc, p["w_dt"].astype(jnp.bfloat16))
                A = -jnp.exp(p["A_log"].astype(jnp.float32))
                x_conv, st_x = ssm_mod.causal_conv(x_in, p["conv_x"].astype(x_in.dtype))
                b_conv, st_b = ssm_mod.causal_conv(b_in, p["conv_b"].astype(b_in.dtype))
                c_conv, st_c = ssm_mod.causal_conv(c_in, p["conv_c"].astype(c_in.dtype))
                x_conv, b_conv, c_conv = map(jax.nn.silu, (x_conv, b_conv, c_conv))
                dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
                xhh = x_conv.reshape(b, s, hh, hd)
                y, hfin = ssm_mod.ssd_chunked(xhh, dt, A, b_conv, c_conv, chunk=cfg.ssm_chunk)
                y = y + xhh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
                y = y.reshape(b, s, din)
                y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
                y = rmsnorm(y, p["norm"], cfg.norm_eps)
                out = jnp.einsum("bsi,id->bsd", y.astype(jnp.bfloat16),
                                 p["out"].astype(jnp.bfloat16))
                x = x + out.astype(x.dtype)
                new_slices.append({"h": hfin, "conv_x": st_x.astype(cache_dtype),
                                   "conv_b": st_b.astype(cache_dtype),
                                   "conv_c": st_c.astype(cache_dtype)})
            if kind["has_ffn"]:
                h2 = rmsnorm(x, group_params[slot]["norm2"]["scale"], cfg.norm_eps)
                if kind["moe"]:
                    y2, _ = moe_ffn(h2, group_params[slot]["moe"],
                                    num_experts=cfg.num_experts,
                                    top_k=cfg.num_experts_per_tok,
                                    activation=activation_fn(cfg.mlp_activation),
                                    capacity_factor=None)
                else:
                    y2 = mlp(h2, group_params[slot]["mlp"], activation_fn(cfg.mlp_activation))
                x = x + y2
        return x, tuple(new_slices)

    x, cache = jax.lax.scan(group_body, x, params["layers"], unroll=unroll)
    x = rmsnorm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if length is None:
        x_last = x[:, -1:]
        npos = jnp.asarray(s, jnp.int32)
    else:
        npos = jnp.asarray(length, jnp.int32)
        x_last = jax.lax.dynamic_slice_in_dim(x, npos - 1, 1, axis=1)
    logits = logits_for(cfg, params, x_last)
    return logits, cache, npos
