"""Numerics policy knobs for the §Perf hillclimb.

``bf16_collectives()``: make every TP-boundary matmul emit bf16 directly
(preferred_element_type), so the SPMD partitioner's partial-sum
all-reduces move bf16 instead of f32 — the "send compressed over the
contended path" advice applied to activation traffic. Accumulation
still happens in f32 inside the dot; only the materialized/psummed
result narrows.
"""
from __future__ import annotations

import contextlib

import jax.numpy as jnp

_BF16_COLLECTIVES = False


@contextlib.contextmanager
def bf16_collectives(enabled: bool = True):
    global _BF16_COLLECTIVES
    prev = _BF16_COLLECTIVES
    _BF16_COLLECTIVES = enabled
    try:
        yield
    finally:
        _BF16_COLLECTIVES = prev


def matmul_dtype():
    """preferred_element_type for TP-boundary einsums (None = default)."""
    return jnp.bfloat16 if _BF16_COLLECTIVES else None


def enabled() -> bool:
    return _BF16_COLLECTIVES
