"""Mamba2 / SSD (state-space duality) sequence mixing.

``ssd_chunked`` is the chunked-parallel pure-JAX algorithm (arXiv:2405.21060
Listing 1 structure): intra-chunk quadratic term + inter-chunk state
recurrence. It doubles as the oracle for the ``ssd_scan`` Pallas kernel.

Shapes: x (B,S,H,P) values; dt (B,S,H) post-softplus step sizes;
A (H,) negative; Bm/C (B,S,N) input/output state projections (ngroups=1);
state h (B,H,P,N).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, C: jax.Array, *,
                chunk: int = 256,
                h0: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    g, L = sp // chunk, chunk

    xf = x.astype(jnp.float32).reshape(b, g, L, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, g, L, h)
    Bf = Bm.astype(jnp.float32).reshape(b, g, L, n)
    Cf = C.astype(jnp.float32).reshape(b, g, L, n)

    dA = dtf * A.astype(jnp.float32)                    # (B,G,L,H)
    cum = jnp.cumsum(dA, axis=2)                        # (B,G,L,H)

    # ---- intra-chunk (the quadratic/"attention-like" branch) ----
    CB = jnp.einsum("bgtn,bgsn->bgts", Cf, Bf)          # (B,G,L,L)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,G,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), dtype=bool))
    scores = CB[..., None] * decay * dtf[:, :, None, :, :]
    scores = jnp.where(tri[None, None, ..., None], scores, 0.0)
    y_intra = jnp.einsum("bgtsh,bgshp->bgthp", scores, xf)

    # ---- chunk states ----
    last = cum[:, :, -1:, :]                            # (B,G,1,H)
    w = jnp.exp(last - cum) * dtf                       # (B,G,L,H)
    states = jnp.einsum("bgsh,bgsn,bgshp->bghpn", w, Bf, xf)  # (B,G,H,P,N)

    # ---- inter-chunk recurrence over G ----
    chunk_decay = jnp.exp(last[:, :, 0, :])             # (B,G,H)
    hinit = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(hprev, inp):
        dec, st = inp                                   # (B,H), (B,H,P,N)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev

    hfinal, hprevs = jax.lax.scan(
        step, hinit, (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)))
    hprevs = hprevs.swapaxes(0, 1)                      # (B,G,H,P,N) state entering chunk g

    y_inter = jnp.einsum("bgtn,bghpn->bgthp", Cf, hprevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), hfinal


def ssd_decode_step(xt: jax.Array, dtt: jax.Array, A: jax.Array,
                    Bt: jax.Array, Ct: jax.Array, hstate: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. xt (B,H,P); dtt (B,H); Bt/Ct (B,N);
    hstate (B,H,P,N). Returns (y (B,H,P), h')."""
    xt = xt.astype(jnp.float32)
    dtt = dtt.astype(jnp.float32)
    dA = jnp.exp(dtt * A.astype(jnp.float32))           # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt.astype(jnp.float32), xt)
    hnew = hstate * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), hnew)
    return y, hnew


def ssd_ref(x, dt, A, Bm, C, *, h0=None):
    """Sequential O(S) reference recurrence (oracle for tests)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    hstate = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp
        y, hstate = ssd_decode_step(xt, dtt, A, Bt, Ct, hstate)
        return hstate, y

    hfinal, ys = jax.lax.scan(
        step, hstate,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bm.swapaxes(0, 1), C.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hfinal


# ----------------------------------------------------------------------
# depthwise causal conv (width K) used on x/B/C streams
# ----------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array,
                state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,Ch), w (K,Ch) depthwise. Returns (y (B,S,Ch), new_state
    (B,K-1,Ch) = last K-1 inputs, for decode continuation)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, S+K-1, Ch)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[-1]), x.dtype)
    return y, new_state


def causal_conv_step(xt: jax.Array, w: jax.Array,
                     state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token conv. xt (B,Ch); state (B,K-1,Ch)."""
    k = w.shape[0]
    xp = jnp.concatenate([state, xt[:, None]], axis=1)  # (B,K,Ch)
    y = jnp.einsum("bkc,kc->bc", xp, w)
    return y, xp[:, 1:]
