"""Mixture-of-Experts with capacity-based dispatch.

Two execution paths:

- no mesh (CPU tests): plain local dispatch (`_moe_local`).
- mesh: **explicit expert parallelism** in a fully-manual shard_map.
  Activations are replicated across the TP ("model") axis in this
  framework, so every model shard already holds the tokens: each shard
  routes identically, selects only the tokens belonging to *its* experts
  (E/TP of them), computes locally, and a single psum over the model
  axis combines partial outputs. Token traffic per layer is exactly one
  all-reduce of the activation — no all-to-all, no cross-shard cumsum.
  FSDP weight gathers (data axis) happen explicitly inside the body so
  the collective schedule is fully visible to the characterizer.

Skew note (paper Advice #1): Zipfian routing collapses throughput on the
"wimpy" path exactly like DDIO-less SoC writes; capacity factors bound
the damage and benchmarks/bench_skew.py quantifies it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import get_abstract_mesh


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array        # load-balancing loss
    dropped_frac: jax.Array    # fraction of (token,k) assignments dropped
    expert_load: jax.Array     # (E,) fraction of assignments per expert


def router_topk(x2d: jax.Array, w_router: jax.Array, k: int):
    """x2d (T,D); returns (weights (T,k) renormalized, idx (T,k), probs (T,E))."""
    logits = (x2d.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    f = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(idx.size, 1)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def _capacity(t: int, k: int, e: int, capacity_factor: Optional[float]) -> int:
    if capacity_factor is None:
        return t
    return max(1, -(-int(capacity_factor * t * k) // e))


def _expert_compute(buf_e: jax.Array, w_in: jax.Array, w_out: jax.Array,
                    activation) -> jax.Array:
    """buf_e (E?, C, D) x w_in (E?, D, 2, F) -> (E?, C, D)."""
    h = jnp.einsum("ecd,edtf->ectf", buf_e.astype(jnp.bfloat16),
                   w_in.astype(jnp.bfloat16))
    gate, up = h[..., 0, :], h[..., 1, :]
    h = activation(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(jnp.bfloat16))


def _dispatch_compute_combine(x2d, weights, idx, *, lo, e_local, cap,
                              w_in, w_out, activation):
    """Scatter tokens routed to experts [lo, lo+e_local) into a capacity
    buffer, run them, and combine weighted outputs back to token order.
    `lo` may be a tracer (axis_index); `e_local` must be static.
    Returns (y (T,D) f32, kept mask, is_mine mask over (T*k,))."""
    t, d = x2d.shape
    k = idx.shape[1]
    flat_e = idx.reshape(t * k)
    is_mine = (flat_e >= lo) & (flat_e < lo + e_local)
    eff = jnp.where(is_mine, flat_e - lo, e_local)            # trash bucket
    onehot = jax.nn.one_hot(eff, e_local + 1, dtype=jnp.int32)[:, :e_local]
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1   # (T*k,)
    keep = is_mine & (pos < cap) & (pos >= 0)
    slot = jnp.where(keep, eff * cap + pos, e_local * cap)

    x_rep = jnp.repeat(x2d, k, axis=0)
    buf = jnp.zeros((e_local * cap + 1, d), x2d.dtype).at[slot].set(
        x_rep.astype(x2d.dtype))
    out = _expert_compute(buf[:e_local * cap].reshape(e_local, cap, d),
                          w_in, w_out, activation)
    out_flat = jnp.concatenate(
        [out.reshape(e_local * cap, d),
         jnp.zeros((1, d), out.dtype)], axis=0)
    y_rep = out_flat[slot] * keep[:, None]
    y = (y_rep.reshape(t, k, d).astype(jnp.float32)
         * weights[..., None]).sum(axis=1)
    return y, keep, is_mine


def replicate_hot_experts(idx: jax.Array, probs: jax.Array, *,
                          num_experts: int, replicas: int,
                          num_hot: int = 2):
    """Paper Advice #1 made executable: under skewed routing, assignments
    to the `num_hot` most-loaded experts are split round-robin across
    `replicas` *virtual* experts, each with its own capacity queue —
    DrTM-KV's "replicate a few hot keys to tame the skewness".

    Returns (virtual idx (T,k) over E + num_hot*(replicas-1) experts,
    parent map (E_virt,) so weights can be gathered per virtual expert).
    """
    e = num_experts
    if replicas <= 1 or num_hot <= 0:
        return idx, jnp.arange(e)
    t, k = idx.shape
    # hottest experts by realized assignment count
    counts = jnp.zeros((e,), jnp.int32).at[idx.reshape(-1)].add(1)
    _, hot = jax.lax.top_k(counts, num_hot)                   # (num_hot,)
    # virtual expert table: parents[e + h*(replicas-1) + r] = hot[h]
    parents = jnp.concatenate(
        [jnp.arange(e)] + [hot] * (replicas - 1))             # (E_virt,)
    # round-robin over (token, slot) — mixing row and column indices so
    # the cycle never locks to the top-k column parity
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(k)[None, :]
    rep = (rows + cols) % replicas                            # (T,k)
    hot_slot = jnp.argmax(idx[..., None] == hot[None, None, :], axis=-1)
    is_hot = (idx[..., None] == hot[None, None, :]).any(-1)
    virt = jnp.where(
        is_hot & (rep > 0),
        e + hot_slot * (replicas - 1) + (rep - 1),
        idx)
    return virt, parents


def _moe_local(x: jax.Array, params: dict, *, num_experts: int, top_k: int,
               activation, capacity_factor: Optional[float],
               hot_expert_replicas: int = 1):
    b, s, d = x.shape
    e, k = num_experts, top_k
    t = b * s
    x2d = x.reshape(t, d)
    weights, idx, probs = router_topk(x2d, params["router"], k)
    aux = load_balance_loss(probs, idx, e)
    cap = _capacity(t, k, e, capacity_factor)
    w_in, w_out = params["w_in"], params["w_out"]
    didx = idx
    if hot_expert_replicas > 1:
        didx, parents = replicate_hot_experts(
            idx, probs, num_experts=e, replicas=hot_expert_replicas)
        w_in = w_in[parents]
        w_out = w_out[parents]
        e = parents.shape[0]
    y, keep, _ = _dispatch_compute_combine(
        x2d, weights, didx, lo=0, e_local=e, cap=cap,
        w_in=w_in, w_out=w_out, activation=activation)
    flat_e = idx.reshape(-1)
    load = (jnp.zeros((num_experts,), jnp.float32).at[flat_e].add(1.0)
            / jnp.maximum(flat_e.size, 1))
    metrics = MoEMetrics(aux_loss=aux, dropped_frac=1.0 - keep.mean(),
                         expert_load=load)
    return y.reshape(b, s, d).astype(x.dtype), metrics


def moe_ffn(x: jax.Array, params: dict, *, num_experts: int, top_k: int,
            activation, capacity_factor: Optional[float] = 1.25,
            hot_expert_replicas: int = 1,
            ) -> tuple[jax.Array, MoEMetrics]:
    """x (B,S,D) -> (B,S,D). See module docstring for the EP scheme.
    hot_expert_replicas > 1 enables Advice-#1 hot-expert replication
    (local dispatch path; the EP path balances by shard ownership)."""
    mesh = get_abstract_mesh()
    e = num_experts
    if mesh is None or not mesh.shape:
        return _moe_local(x, params, num_experts=e, top_k=top_k,
                          activation=activation,
                          capacity_factor=capacity_factor,
                          hot_expert_replicas=hot_expert_replicas)

    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    batch_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.shape and mesh.shape[a] > 1)
    rem = x.shape[0]
    bax = []
    for a in batch_axes:
        if rem % mesh.shape[a] == 0:
            bax.append(a)
            rem //= mesh.shape[a]
    ep = msize > 1 and e % msize == 0
    if not (ep or bax):
        return _moe_local(x, params, num_experts=e, top_k=top_k,
                          activation=activation,
                          capacity_factor=capacity_factor,
                          hot_expert_replicas=hot_expert_replicas)

    e_local = e // msize if ep else e
    bspec = tuple(bax) if len(bax) > 1 else (bax[0] if bax else None)
    has_data = "data" in mesh.shape and dsize > 1

    def inner(x, router, w_in, w_out):
        # x (B_loc, S, D); router (D_loc?, E); w_in (E_loc, D_loc?, 2, F)
        if has_data:   # explicit FSDP gathers (visible to the characterizer)
            router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
            w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)
        b_loc, s_loc, d = x.shape
        t = b_loc * s_loc
        x2d = x.reshape(t, d)
        weights, idx, probs = router_topk(x2d, router, top_k)
        aux = load_balance_loss(probs, idx, e)
        cap = _capacity(t, top_k, e, capacity_factor)
        if ep:
            widx = jax.lax.axis_index("model")
            y, keep, is_mine = _dispatch_compute_combine(
                x2d, weights, idx, lo=widx * e_local, e_local=e_local,
                cap=cap, w_in=w_in, w_out=w_out, activation=activation)
            y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
            kept = jax.lax.psum(jnp.sum(keep), "model")
            dropped = 1.0 - kept / idx.size
        else:
            y, keep, _ = _dispatch_compute_combine(
                x2d, weights, idx, lo=0, e_local=e, cap=cap,
                w_in=w_in, w_out=w_out, activation=activation)
            dropped = 1.0 - keep.mean()
        flat_e = idx.reshape(-1)
        load = (jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
                / jnp.maximum(flat_e.size, 1))
        for ax in bax:
            aux = jax.lax.pmean(aux, ax)
            dropped = jax.lax.pmean(dropped, ax)
            load = jax.lax.pmean(load, ax)
        return y.reshape(b_loc, s_loc, d).astype(x.dtype), \
            MoEMetrics(aux_loss=aux, dropped_frac=dropped, expert_load=load)

    dspec = "data" if has_data else None
    especk = "model" if ep else None
    in_specs = (P(bspec, None, None),            # x: replicated over model
                P(dspec, None),                  # router (D fsdp)
                P(especk, dspec, None, None),    # w_in (E ep, D fsdp)
                P(especk, None, dspec))          # w_out (E ep, F, D fsdp)
    out_specs = (P(bspec, None, None),
                 MoEMetrics(aux_loss=P(), dropped_frac=P(), expert_load=P(None)))
    # fully manual: leaving any axis (e.g. pod when batch=1) to the auto
    # partitioner makes axis_index lower to a PartitionId the surrounding
    # SPMD pass refuses to partition.
    manual = set(mesh.axis_names)
    return shard_map(inner, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, axis_names=manual,
                     check_vma=False)(x, params["router"], params["w_in"],
                                      params["w_out"])


def moe_ffn_dense_ref(x: jax.Array, params: dict, *, num_experts: int,
                      top_k: int, activation) -> jax.Array:
    """Oracle: dense per-expert compute, no capacity drops. For tests."""
    b, s, d = x.shape
    e, k = num_experts, top_k
    x2d = x.reshape(b * s, d)
    weights, idx, _ = router_topk(x2d, params["router"], k)
    w_in, w_out = params["w_in"], params["w_out"]
    y = jnp.zeros((b * s, d), jnp.float32)
    for ei in range(e):
        h = jnp.einsum("xd,dgf->xgf", x2d.astype(jnp.float32),
                       w_in[ei].astype(jnp.float32))
        gate, up = h[..., 0, :], h[..., 1, :]
        o = (activation(gate) * up) @ w_out[ei].astype(jnp.float32)
        wsum = (jnp.where(idx == ei, weights, 0.0)).sum(-1)   # (T,)
        y += o * wsum[:, None]
    return y.reshape(b, s, d).astype(x.dtype)
