"""Logical-axis sharding: map logical tensor axes onto mesh axes.

MaxText-style: every parameter/activation carries a tuple of *logical*
axis names; `logical_to_physical` resolves them against the active mesh
through RULES. Axes absent from the mesh degrade to replication, so the
same model code runs on a 1-device CPU mesh, the 16x16 single-pod mesh
and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]

# logical axis -> mesh axis (or tuple of mesh axes)
RULES = {
    # weights
    "fsdp": "data",              # weight dim sharded ZeRO-3 style
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",         # only when divisible; see below
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",        # mamba2 heads/d_inner
    "layer_group": None,         # stacked-scan leading dim: never sharded
    "flat_shard": ("data", "model"),  # 1-D fully-sharded (int8 moments)
    "embed": None,               # d_model of activations / norm scales
    # activations
    "batch": ("pod", "data"),
    "decode_batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,              # becomes "data" under context parallelism
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
}

#: overrides for long-context decode (context parallelism): the KV cache /
#: sequence dim shards over `data`, batch stays on `pod` only.
CONTEXT_PARALLEL_OVERRIDES = {
    "kv_seq": "data",
    "batch": "pod",
    "decode_batch": "pod",
}


def mesh_axis_size(mesh: Mesh, axis: Union[str, Tuple[str, ...], None]) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_axis_size(mesh, a)
        return n
    return mesh.shape[axis] if axis in mesh.shape else 1


_RULE_OVERRIDES: dict = {}


def rule_overrides(overrides: dict):
    """Context manager: temporarily remap logical axes (e.g. inside a
    pod-manual shard_map region, "batch" must resolve to data only)."""
    import contextlib

    @contextlib.contextmanager
    def _ctx():
        global _RULE_OVERRIDES
        prev = dict(_RULE_OVERRIDES)
        _RULE_OVERRIDES.update(overrides)
        try:
            yield
        finally:
            _RULE_OVERRIDES = prev
    return _ctx()


def logical_to_spec(logical: Sequence[Optional[str]], mesh: Mesh,
                    dim_sizes: Optional[Sequence[int]] = None,
                    overrides: Optional[dict] = None) -> P:
    """Resolve logical axes to a PartitionSpec under `mesh`.

    A mesh axis is only used if (a) it exists in the mesh and (b) the
    corresponding tensor dim is divisible by its size (when dim_sizes is
    given) — otherwise that dim replicates. This implements e.g. the
    Megatron rule "replicate KV heads when kv_heads < TP".
    """
    rules = dict(RULES)
    rules.update(_RULE_OVERRIDES)
    if overrides:
        rules.update(overrides)
    spec = []
    for i, name in enumerate(logical):
        axis = rules.get(name) if name else None
        if axis is None:
            spec.append(None)
            continue
        # keep only mesh axes that exist
        axes = axis if isinstance(axis, tuple) else (axis,)
        axes = tuple(a for a in axes if a in mesh.shape and mesh.shape[a] > 1)
        if not axes:
            spec.append(None)
            continue
        if dim_sizes is not None:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim_sizes[i] % size != 0:
                spec.append(None)      # not divisible -> replicate
                continue
        spec.append(axes if len(axes) > 1 else axes[0])
    return P(*spec)


def named_sharding(logical: Sequence[Optional[str]], mesh: Mesh,
                   dim_sizes: Optional[Sequence[int]] = None,
                   overrides: Optional[dict] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, mesh, dim_sizes, overrides))


def constrain(x: jax.Array, *logical: Optional[str],
              overrides: Optional[dict] = None) -> jax.Array:
    """with_sharding_constraint by logical axis names.

    Looks up the ambient mesh (set via `jax.sharding.use_mesh` /
    `with mesh:`). No-op outside jit or without a mesh.
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    spec = logical_to_spec(logical, mesh, dim_sizes=x.shape, overrides=overrides)
    return jax.lax.with_sharding_constraint(x, spec)


def get_abstract_mesh() -> Optional[Mesh]:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


# ----------------------------------------------------------------------
# Parameter pytree sharding: params are dicts whose leaves are
# (array, logical_axes) pairs at init time; `tree_shardings` turns the
# logical tree into NamedShardings for jit in_shardings / out_shardings.
# ----------------------------------------------------------------------

def tree_shardings(logical_tree, shape_tree, mesh: Mesh, overrides=None):
    """Map a pytree of logical-axis tuples + matching shapes to NamedShardings."""
    return jax.tree.map(
        lambda lg, shp: named_sharding(lg, mesh, dim_sizes=shp.shape, overrides=overrides),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
