"""Batched serving example: continuous-batching decode over mixed-length
requests (the DrTM-KV case study's executable side).

    PYTHONPATH=src python examples/serve_batch.py --requests 8
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--reduced",
                "--requests", str(args.requests),
                "--prompt-len", "12", "--max-new", "12", "--slots", "4"])


if __name__ == "__main__":
    main()
