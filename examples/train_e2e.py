"""End-to-end training driver: a few hundred steps with checkpointing,
replication and restart — the LineFS case study running live.

CPU-friendly default (reduced model). On real hardware drop --reduced and
raise --steps; the same driver scales to the production mesh through
repro.launch.train.

    PYTHONPATH=src python examples/train_e2e.py --steps 200
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — real-hardware mode")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--lr", "3e-3",
            "--ckpt-dir", ckpt_dir, "--ckpt-every", "50",
            "--ckpt-replicas", "2"]
    if not args.full:
        argv.append("--reduced")
    tr = train_main(argv)
    first, last = tr.history[0]["loss"], tr.history[-1]["loss"]
    print(f"[example] loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(ckpts in {ckpt_dir}, 2 replicas)")
    assert last < first, "training did not improve the loss"

    # bucketed-DDP overlap dry run: the same launcher simulates the
    # config as 2 trainer nodes with K=4 per-layer-group gradient
    # buckets and prints the measured win over single-shot allreduce
    print("[example] simulating bucketed DDP overlap (K=4, 2 nodes)...")
    train_main(["--arch", args.arch, "--steps", "6", "--reduced",
                "--simulate", "2", "--buckets", "4"])


if __name__ == "__main__":
    main()
