"""The paper's §4.2 guideline, end to end, on three functionalities:

1. checkpoint replication (LineFS §5.1)  — measured compression ratio ->
   rank A1/A2/A3 -> greedy combine;
2. disaggregated KV get (DrTM-KV §5.2)  — rank A1..A5 -> combine A4+A5;
3. gradient sync across pods            — decide hierarchical vs
   compressed DCN sync from the path budgets.

    PYTHONPATH=src python examples/multipath_plan.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.ckpt.checkpoint import save_checkpoint
from repro.ckpt.replication import plan_replication
from repro.configs import get_config
from repro.core import hw
from repro.core.compression import compression_wins, grad_sync_seconds
from repro.models.params import init_params
from repro.serve.disagg import DisaggKV, KVStoreParams

import tempfile, os


def replication():
    print("== functionality 1: checkpoint replication (LineFS) ==")
    cfg = get_config("internlm2-1.8b").reduced(d_model=128, vocab_size=2048)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as tmp:
        st = save_checkpoint(os.path.join(tmp, "ck"), params, step=0)
    print(f" measured compression ratio: {st['ratio']:.2f}")
    plan = plan_replication(ratio=st["ratio"])
    print(f" ranked: {plan.ranked}  use_compression={plan.use_compression}")
    print(f" {plan.notes}")
    for a in plan.allocations:
        print(f"  alloc {a.alternative}: {a.rate/1e9:.2f} GB/s (until {a.bottleneck})")


def kv_store():
    print("== functionality 2: disaggregated KV get (DrTM-KV) ==")
    kv = DisaggKV(KVStoreParams())
    fabric, alts = kv.fabric(), kv.alternatives()
    router = fabric.router()
    for a in router.rank(list(alts.values())):
        print(f"  {a.name}: {a.solo_rate(fabric)/1e6:5.1f} M gets/s, "
              f"{a.criteria['latency_us']:.1f} us")
    total, allocs = kv.combined_a4_a5()
    print(f" combined A4+A5: {total/1e6:.1f} M gets/s "
          f"({', '.join(f'{al.alternative}={al.rate/1e6:.1f}M' for al in allocs)})")


def grad_sync():
    print("== functionality 3: cross-pod gradient sync ==")
    grad_bytes = 2 * 9.4e9 / 256            # bf16 grads, sharded over a pod
    for name, ratio, rate in [("fp32", 2.0, float("inf")),
                              ("bf16", 1.0, float("inf")),
                              ("int8+EF", 0.26, 50e9)]:
        t = grad_sync_seconds(grad_bytes, 2, hw.DCN_BW_PER_CHIP,
                              ratio=ratio, compress_rate=rate)
        print(f"  {name:8s}: {t*1e3:7.1f} ms/step over DCN")
    print(f"  compression wins on DCN: "
          f"{compression_wins(hw.DCN_BW_PER_CHIP, hw.ICI_BW_PER_LINK, 0.26)}")


if __name__ == "__main__":
    replication()
    kv_store()
    grad_sync()
