"""Fault-tolerance walkthrough: train -> node dies -> detect -> restore
from a surviving replica -> elastic re-mesh -> resume.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import RunConfig, get_config
from repro.configs.base import ShapeConfig
from repro.ft.elastic import best_mesh_for
from repro.ft.manager import FaultToleranceManager, NodeFailure
from repro.models.params import init_params
from repro.optim.adamw import adamw_init
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer


def main():
    cfg = get_config("internlm2-1.8b").reduced()
    run = RunConfig(learning_rate=2e-3, warmup_steps=2, total_steps=40)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")
    tmp = tempfile.mkdtemp(prefix="repro_ft_")
    ckpt = CheckpointManager(tmp, every=5, keep=3, replicas=2)

    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, run, impl="ref"))
    tr = Trainer(cfg, run, shape, step_fn=step_fn, params=params,
                 opt_state=adamw_init(params), ckpt=ckpt)
    try:
        tr.run_steps(20, fail_at=13)   # node goes silent; the event-driven
    except NodeFailure as e:           # watchdog detects it in sim time
        print(f"[ft] {e}")
    ckpt.wait()

    # failure detection via heartbeats
    clock = {"t": 0.0}
    ft = FaultToleranceManager(ckpt, timeout=5.0, clock=lambda: clock["t"])
    for h in ("host0", "host1", "host2", "host3"):
        ft.register(h, devices=2)
    clock["t"] = 6.0
    for h in ("host0", "host1", "host2"):
        ft.heartbeat(h)
    clock["t"] = 7.0
    failed = ft.check()
    print(f"[ft] failed nodes: {failed}; surviving devices: {ft.alive_devices()}")

    # primary checkpoint lost too? chain replica serves the restore
    last = ckpt.latest_step()
    shutil.rmtree(ckpt._step_dir(last))
    print(f"[ft] destroyed primary copy of step {last}; restoring from chain")

    params2, _ = init_params(cfg, jax.random.PRNGKey(0))
    like = (params2, adamw_init(params2))
    (params2, opt2), resume = ft.recover(like)
    print(f"[ft] restored; resuming at step {resume}")

    mesh_shape, names = best_mesh_for(ft.alive_devices(), model=2)
    print(f"[ft] elastic re-mesh for survivors: {dict(zip(names, mesh_shape))}")

    tr2 = Trainer(cfg, run, shape, step_fn=step_fn, params=params2,
                  opt_state=opt2, ckpt=ckpt)
    tr2.start_step = resume
    tr2.run_steps(5)
    print(f"[ft] resumed fine: steps {[h['step'] for h in tr2.history]} "
          f"loss={tr2.history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
