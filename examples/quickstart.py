"""Quickstart: build a model, take a train step, decode a few tokens.

    PYTHONPATH=src python examples/quickstart.py [--arch internlm2-1.8b]

Uses the reduced (CPU-sized) config of the chosen architecture; every
assigned arch works (--arch mamba2-2.7b, --arch jamba-1.5-large-398b, ...).
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, list_archs
from repro.models import model as M
from repro.models.params import init_params
from repro.optim.adamw import adamw_init
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name} family={cfg.family} params={cfg.param_count():,}")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))

    # ---- one train step ----
    b, s = 2, 32
    shape = (b, s, cfg.num_codebooks) if cfg.num_codebooks > 1 else (b, s)
    tokens = np.asarray(jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                           cfg.vocab_size))
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": np.ones((b, s), np.float32)}
    if cfg.frontend:
        ft = cfg.frontend_tokens
        batch["frontend_embeds"] = np.zeros((b, ft, cfg.d_model), np.float32)
        pad = np.zeros((b, ft) + tokens.shape[2:], tokens.dtype)
        batch["labels"] = np.concatenate([pad, tokens], axis=1)
        batch["loss_mask"] = np.concatenate(
            [np.zeros((b, ft), np.float32), batch["loss_mask"]], axis=1)

    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(cfg, run, impl="ref"))
    params, opt, metrics = step(params, adamw_init(params), batch, jnp.asarray(0))
    print(f"train step: loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # ---- decode 8 tokens ----
    prompt = tokens[:1, :8]
    logits, cache, pos = M.prefill(cfg, params, jnp.asarray(prompt), 64)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)
    for _ in range(8):
        out.append(int(np.asarray(tok).reshape(-1)[0]))
        t_in = tok.reshape(1, 1, -1) if cfg.num_codebooks > 1 else tok.reshape(1, 1)
        logits, cache = M.decode_step(cfg, params, t_in, cache, pos)
        pos = pos + 1
        tok = jnp.argmax(logits[:, -1], axis=-1)
    print(f"decoded: {out}")


if __name__ == "__main__":
    main()
