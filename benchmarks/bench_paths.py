"""Paper Figure 3: per-path latency + peak throughput across payload
sizes, from the calibrated TPU Fabric (core/paths.py -> core/fabric.py).

Each mesh path gets a latency/bandwidth curve vs payload; the derived
column reports the paper-analogue finding (path-2-style fast path vs
path-3-style double-crossing)."""
from __future__ import annotations

from repro.core.paths import collective_time, enumerate_paths

from benchmarks.common import row

PAYLOADS = [256, 4096, 65536, 1 << 20, 16 << 20, 256 << 20]


def main() -> None:
    paths = enumerate_paths({"pod": 2, "data": 16, "model": 16})  # a Fabric
    print("# fig3: path,payload_bytes -> us (model), bandwidth GB/s")
    for name, p in sorted(paths.items()):
        for payload in PAYLOADS:
            t = p.time_for(payload)
            row(f"fig3/{name}/{payload}", t * 1e6,
                f"bw={payload / t / 1e9:.1f}GB/s")
    print("# fig3b: collective time per op (64 MiB payload, per path)")
    for name, p in sorted(paths.items()):
        if p.axis is None:
            continue
        for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all"):
            t = collective_time(op, 64 << 20, p)
            row(f"fig3b/{name}/{op}", t * 1e6, f"n={p.size}")


if __name__ == "__main__":
    main()
