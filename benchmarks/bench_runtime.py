"""Event-driven fabric runtime: overlap, pipelining, staged serving.

Demonstrates the temporal behaviors the static router cannot express:
the §4.1 discount emerging from overlapping transfers, the LineFS §5.1
pipelining win as simulated latency, a charz TrafficSummary replayed on
the TPU fabric, and the staged serving pipeline's p50/p99 TTFT against
the synchronous engine under one bursty arrival trace."""
from __future__ import annotations

import numpy as np

from repro.core.charz import TrafficSummary, replay
from repro.core.fabric import Fabric, Path
from repro.core.paths import enumerate_paths
from repro.core.runtime import FabricRuntime
from repro.ckpt.replication import simulate_replication

from benchmarks.common import row


def overlap_part() -> None:
    cap, disc = 100e9, 0.125
    fabric = Fabric.of(Path("link", cap), concurrency_discount=disc)
    rt = FabricRuntime(fabric)
    solo = rt.transfer("link", 100e9)
    rt.clock.run()
    t_solo = solo.finished_at
    rt2 = FabricRuntime(fabric)
    a, b = rt2.transfer("link", 100e9), rt2.transfer("link", 100e9)
    rt2.clock.run()
    row("runtime/solo_transfer", t_solo * 1e6, "rate=100GB/s")
    row("runtime/overlapped_pair", b.finished_at * 1e6,
        f"per_flow_rate={a.amount / a.finished_at / 1e9:.1f}GB/s "
        f"emergent_discount={1 - 2 * t_solo / b.finished_at:.3f} "
        f"(configured {disc})")


def replication_part() -> None:
    kw = dict(chunks=8, net_bw=200e9 / 8, staging_bw=256e9 / 8, ratio=0.5)
    seq = simulate_replication(1e9, pipelined=False, **kw)
    pipe = simulate_replication(1e9, pipelined=True, **kw)
    row("runtime/replication_sequential", seq.seconds * 1e6,
        f"chunks=8 p50_done={seq.percentile(50) * 1e3:.2f}ms "
        f"p99_done={seq.percentile(99) * 1e3:.2f}ms")
    row("runtime/replication_pipelined", pipe.seconds * 1e6,
        f"win={1 - pipe.seconds / seq.seconds:.0%} (paper ~30%) "
        f"p50_done={pipe.percentile(50) * 1e3:.2f}ms "
        f"p99_done={pipe.percentile(99) * 1e3:.2f}ms")


def replay_part() -> None:
    fabric = enumerate_paths({"pod": 2, "data": 16, "model": 16})
    s = TrafficSummary(
        per_path={"ici:data": 4e9, "ici:model": 2e9, "dcn:pod": 0.5e9},
        per_op={}, op_counts={})
    static = sum(amount / fabric[p].capacity
                 for p, amount in s.per_path.items())
    sim = replay(s, fabric)
    row("runtime/charz_replay", sim * 1e6,
        f"static_sum={static * 1e6:.1f}us overlap_gain="
        f"{(static / sim - 1) * 100:.0f}%")


def serving_part() -> None:
    import jax
    from repro.configs import get_config
    from repro.models.params import init_params
    from repro.serve.engine import (Request, ServeEngine, ServeTimeModel,
                                    StagedServeEngine)
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    fab = lambda: Fabric.of(Path("prefill", 16.0), Path("decode", 10.0))
    tm = ServeTimeModel(prefill_path="prefill", decode_path="decode")

    def trace():
        rng = np.random.default_rng(7)
        return [Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=4)
            for i in range(8)]

    def pcts(reqs):
        t = sorted(r.ttft for r in reqs)
        return t[len(t) // 2], t[-1]

    sync = ServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                       runtime=FabricRuntime(fab()), time_model=tm)
    sreqs = trace()
    for r in sreqs:
        sync.submit(r)
    sync.run()
    staged = StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                               fabric=fab(), time_model=tm)
    preqs = trace()
    for r in preqs:
        staged.submit(r)
    staged.run()
    assert [r.out_tokens for r in sreqs] == [r.out_tokens for r in preqs]
    sp50, sp99 = pcts(sreqs)
    pp50, pp99 = pcts(preqs)
    row("runtime/serve_sync_ttft", sp99 * 1e6, f"p50={sp50:.2f}s p99={sp99:.2f}s")
    row("runtime/serve_staged_ttft", pp99 * 1e6,
        f"p50={pp50:.2f}s p99={pp99:.2f}s "
        f"p99_win={(1 - pp99 / sp99) * 100:.0f}% identical_tokens=True")


def main() -> None:
    print("# event-driven runtime: overlap / pipelining / replay / staged serve")
    overlap_part()
    replication_part()
    replay_part()
    serving_part()


if __name__ == "__main__":
    main()
