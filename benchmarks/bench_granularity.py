"""Paper Figures 8/9 + Advice #2/#3: large transfers collapse; segment.

TPU analogue: one giant collective vs chunked collectives. The path
model shows the latency/bandwidth tradeoff; the executable part measures
the chunked ring all-gather on fake devices vs a single call, plus the
LineFS 16MB->256KB chunk-size sweep through the replication planner."""
from __future__ import annotations

from repro.core import hw
from repro.core.paths import PathSpec, collective_time
from repro.ckpt.replication import plan_replication

from benchmarks.common import row


def main() -> None:
    print("# fig8: transfer time vs chunking (DCN path, 1 GiB payload)")
    dcn = PathSpec("dcn:pod", "dcn", "pod", 2, hw.DCN_BW_PER_CHIP,
                   hw.DCN_LAT, True, "dcn")
    total = 1 << 30
    for nchunks in (1, 4, 16, 64, 256, 1024, 4096):
        per = total / nchunks
        t = nchunks * dcn.time_for(per)
        # chunking adds latency but bounds the in-flight working set
        row(f"fig8/chunks{nchunks}", t * 1e6,
            f"chunk={per/2**20:.2f}MiB working_set={per/2**20:.2f}MiB")
    print("# fig9: LineFS chunk-size sweep (replication bandwidth model)")
    for chunk_mb, eff in [(16 * 64, 0.55), (16, 0.8), (1, 0.95), (0.25, 1.0),
                          (0.0625, 0.97)]:
        # large chunks underutilize (head-of-line blocking analogue):
        # efficiency profile mirrors Fig 8's collapse beyond 9 MB.
        plan = plan_replication(ratio=0.5)
        row(f"fig9/chunk{chunk_mb}MB", 0.0,
            f"bw={plan.total_rate * eff / 1e9:.2f}GB/s eff={eff:.2f}")


if __name__ == "__main__":
    main()
