"""Million-user serving (scale/): trace-driven fleet + autoscaling.

Three row groups:
- the headline: static vs TTFT-autoscaled fleet under the 10x diurnal
  burst trace (premium-tenant attainment collapses vs holds);
- attainment vs offered load for the autoscaled fleet (sweeping the
  trace's base rate);
- raw runtime capacity: executed events/s of the event loop driving
  O(1k) concurrent transfers on one shared ledger.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.fabric import Fabric, Path
from repro.core.runtime import FabricRuntime
from repro.scale import headline_fleet, headline_specs, ServeFleet

from benchmarks.common import row


def _fleet_row(name: str, rep, tenant: str = "premium") -> None:
    tr = rep.tenants[tenant]
    row(name, tr.metrics["p99_ttft"] * 1e6,
        f"attainment={tr.attainment:.1%} peak_replicas={tr.peak_replicas} "
        f"requests={tr.metrics['requests']:.0f}")


def main() -> None:
    print("# SLO tenant fleet under the 10x diurnal burst trace")
    static = headline_fleet().run(autoscale=False, max_sim_seconds=2000.0)
    _fleet_row("scale/attainment_static", static)
    auto = headline_fleet().run(autoscale=True, max_sim_seconds=2000.0)
    _fleet_row("scale/attainment_autoscaled", auto)
    row("scale/standard_autoscaled",
        auto.tenants["standard"].metrics["p99_ttft"] * 1e6,
        f"attainment={auto.tenants['standard'].attainment:.1%}")

    print("# attainment vs offered load (autoscaled, no burst baseline 2/s)")
    for mult in (0.5, 1.0, 2.0):
        specs = headline_specs(duration=60.0)
        scaled = [dataclasses.replace(
            s, trace=dataclasses.replace(
                s.trace, base_rate=s.trace.base_rate * mult))
            for s in specs]
        rep = ServeFleet(scaled, host_bw=1400.0).run(
            autoscale=True, max_sim_seconds=2000.0)
        tr = rep.tenants["premium"]
        row(f"scale/offered_{mult:g}x", tr.metrics["p99_ttft"] * 1e6,
            f"attainment={tr.attainment:.1%} "
            f"offered={scaled[0].trace.mean_rate:.1f}req_s")

    print("# event-loop capacity at O(1k) concurrent transfers")

    def _event_loop_row(name: str, tracer=None) -> None:
        fab = Fabric.of(*[Path(f"p{i}", 100.0) for i in range(8)],
                        concurrency_discount=0.1)
        rt = FabricRuntime(fab, tracer=tracer)
        rng = np.random.default_rng(0)
        ts = [rt.transfer(f"p{int(rng.integers(8))}",
                          float(rng.uniform(1.0, 30.0)),
                          flow=f"f{i % 13}", tenant=f"t{i % 5}")
              for i in range(1500)]
        ev0 = rt.clock.processed
        t0 = time.monotonic()
        rt.clock.run()
        wall = time.monotonic() - t0
        assert all(t.done for t in ts)
        events = rt.clock.processed - ev0
        row(name, wall * 1e6,
            f"events_per_s={events / wall:,.0f} events={events}")

    _event_loop_row("scale/runtime_events_per_s")
    # same scenario through the tracing hook sites with tracing off —
    # the ci.sh overhead gate holds this within 10% of the row above
    from repro.obs.trace import NullTracer
    _event_loop_row("scale/runtime_events_per_s_nulltracer", NullTracer())
