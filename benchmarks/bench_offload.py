"""SoC compute tier: compression-offload crossover + KV filter win.

Three rows of story, all on the shared ledger (timing-only; the numeric
stream is exercised by tests/test_offload.py):

* the host-vs-SoC checkpoint-compression crossover as a host-load
  sweep — idle, the host's fat cores and fast wire win; as background
  host-path load grows, compress-on-the-DCA-then-stage-over-the-SoC-wire
  takes over (nothing hardcodes the flip, it emerges from scheduling);
* the host-cycles-saved / offload-hit accounting of the SoC runs in the
  smartnic_offload.py idiom;
* the DrTM-KV get/put filter: host placement wins an idle fabric, SoC
  placement wins once a serving tenant holds the host path.
"""
from __future__ import annotations

import numpy as np

from repro.offload import (HOST_FILTER, SOC_FILTER, KVFilter,
                           plan_filter_placement)
from repro.serve.disagg import DisaggKV, KVStoreParams
from repro.train.cluster import (ClusterTimeModel, HOST_COMPRESS,
                                 SOC_COMPRESS, TrainCluster)

from benchmarks.common import row

STEPS, NODES, CKPT_EVERY = 2, 2, 2


def _ckpt_run(mode: str, load: float):
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e6, ckpt_bytes=8e9,
                          ckpt_path=mode, tokens_per_step=4096 * 16)
    host_load = {f"node{i}": load for i in range(NODES)} if load else None
    cluster = TrainCluster(NODES, tm, ckpt_every=CKPT_EVERY,
                           host_load=host_load)
    seconds = cluster.run(STEPS)["sim_seconds"]
    return cluster, seconds


def crossover_part() -> None:
    """Checkpoint compression placement vs background host-path load."""
    labels = {0.0: "idle", 0.3: "load30", 0.5: "load50", 0.7: "busy"}
    for load, label in labels.items():
        _, soc_s = _ckpt_run(SOC_COMPRESS, load)
        _, host_s = _ckpt_run(HOST_COMPRESS, load)
        winner = "soc-compress" if soc_s < host_s else "host-compress"
        row(f"offload/ckpt_soc_compress_{label}", soc_s * 1e6,
            f"host_load={load:.0%}")
        row(f"offload/ckpt_host_compress_{label}", host_s * 1e6,
            f"host_load={load:.0%} winner={winner} "
            f"delta={abs(soc_s - host_s) / max(soc_s, host_s):.1%}")


def cycles_part() -> None:
    """What the busy-regime SoC placement buys, in the
    smartnic_offload.py accounting idiom."""
    cluster, seconds = _ckpt_run(SOC_COMPRESS, 0.7)
    s = cluster.offload.get_performance_stats()
    row("offload/cycles_saved", s["cpu_cycles_saved"] / 1e6,
        f"ops_offhost={s['cpu_cycles_saved']:.3g} "
        f"compressions={s['compression_operations_offloaded']} "
        f"ratio={s['compression_ratio']:.2f}")
    auto_cluster, auto_s = _ckpt_run("auto", 0.7)
    best = min(auto_s, seconds, _ckpt_run(HOST_COMPRESS, 0.7)[1])
    row("offload/ckpt_auto_busy", auto_s * 1e6,
        f"vs_best={auto_s / best:.3f}x")


def kvfilter_part() -> None:
    """Filtered scans: same predicate, same results, placement-dependent
    seconds — and the flip once a serve tenant holds the host path."""
    kv = DisaggKV(KVStoreParams(n_keys=5000, soc_cache_keys=500), seed=0)
    keys = kv.zipf_keys(2000, seed=11)
    predicate = lambda vals: vals[:, 0] < 64          # noqa: E731  ~25% pass
    filt = KVFilter(kv)
    fab = kv.fabric()
    led = fab.ledger()
    led.reserve("host_read", out=0.8 * fab["host_read"].capacity,
                flow="serve")
    for label, ledger in (("idle", None), ("busy", led)):
        host = filt.scan(keys, predicate, where=HOST_FILTER, ledger=ledger)
        soc = filt.scan(keys, predicate, where=SOC_FILTER, ledger=ledger)
        plan = plan_filter_placement(fab, selectivity=soc.matched / soc.scanned,
                                     costs=kv.c, ledger=ledger)
        assert np.array_equal(host.keys, soc.keys)    # placement moves cycles
        row(f"offload/kvfilter_host_{label}", host.seconds * 1e6,
            f"scanned={host.scanned}")
        row(f"offload/kvfilter_soc_{label}", soc.seconds * 1e6,
            f"matched={soc.matched} plan={plan.location} "
            f"winner={HOST_FILTER if host.seconds < soc.seconds else SOC_FILTER}")
    s = filt.stats.get_performance_stats()
    row("offload/kvfilter_hit_rate", s["offload_hit_rate"] * 1e2,
        f"packets_offloaded={s['packets_offloaded']} "
        f"of {s['packets_total']}")


def main() -> None:
    print("# SoC compute tier: compression crossover / cycles saved / "
          "KV filter")
    crossover_part()
    cycles_part()
    kvfilter_part()


if __name__ == "__main__":
    main()
