"""Paper Figure 10 / Advice #4: doorbell batching = gradient bucketing.

B per-tensor collectives vs one fused flat collective: we lower both on
a fake 8-device mesh and count collective ops + bytes, then time them.
The analytic part applies the path latency model: B ops pay B latencies."""
from __future__ import annotations

import os
import subprocess
import sys

from repro.core import hw

from benchmarks.common import row


def model_part() -> None:
    nbytes = 64 << 20
    for b in (1, 8, 64, 256):
        t_unbucketed = b * (hw.ICI_LAT * 30 + (nbytes / b) / hw.ICI_BW_PER_LINK)
        t_bucketed = hw.ICI_LAT * 30 + nbytes / hw.ICI_BW_PER_LINK
        row(f"fig10/model/B{b}", t_unbucketed * 1e6,
            f"bucketed_us={t_bucketed*1e6:.1f} speedup={t_unbucketed/t_bucketed:.2f}x")


def executable_part() -> None:
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
grads = [jnp.ones((64, 64)) * i for i in range(32)]
with jax.set_mesh(mesh):
    def unbucketed(gs):
        return [jax.lax.psum(g, "data") for g in gs]
    def bucketed(gs):
        flat = jnp.concatenate([g.reshape(-1) for g in gs])
        out = jax.lax.psum(flat, "data")
        return out
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    for name, fn in (("unbucketed", unbucketed), ("bucketed", bucketed)):
        f = jax.jit(lambda gs, fn=fn: shard_map(fn, mesh=mesh,
                    in_specs=([P()]*32,), out_specs=(([P()]*32) if name=="unbucketed" else P()),
                    check_vma=False)(gs))
        co = f.lower(grads).compile()
        n_ar = co.as_text().count("all-reduce(")
        out = f(grads); jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(20):
            jax.block_until_ready(f(grads))
        dt = (time.perf_counter() - t0)/20
        print(f"fig10/exec/{name},{dt*1e6:.1f},all_reduces={n_ar}")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    print(out.stdout.strip())
    if out.returncode != 0:
        print(out.stderr[-1500:])


def main() -> None:
    print("# fig10: doorbell batching == gradient bucketing")
    model_part()
    executable_part()


if __name__ == "__main__":
    main()
