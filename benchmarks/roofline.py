"""Roofline table: aggregates runs/dryrun/*.json into the EXPERIMENTS.md
§Roofline table (one row per arch x shape x mesh)."""
from __future__ import annotations

import argparse
import glob
import json
import os

RUNS = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun")

HEADER = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
          "| dominant | useful | roofline | per-path |")
SEP = "|---" * 10 + "|"


def load(tag: str = ""):
    rows = []
    for fn in sorted(glob.glob(os.path.join(RUNS, "*.json"))):
        base = os.path.basename(fn)[:-5]
        is_tagged = "_opt" in base or "_base" in base
        if tag and not base.endswith(f"_{tag}"):
            continue
        if not tag and is_tagged:
            continue
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def table(rows):
    print(HEADER)
    print(SEP)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        coll = ", ".join(f"{k}={v*1e3:.1f}ms" for k, v in
                         sorted(r.get("collective_s_per_path", {}).items()))
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
              f"| {r['collective_s']*1e3:.1f} | {r['dominant']} "
              f"| {r['useful_flops_ratio']:.2f} | {r['roofline_frac']:.2f} "
              f"| {coll} |")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    # default to no args: the benchmark driver (run.py) owns sys.argv
    args = ap.parse_args([] if argv is None else argv)
    rows = load(args.tag)
    if not rows:
        print(f"# no dry-run artifacts under {RUNS} (run repro.launch.dryrun)")
        return
    table(rows)


if __name__ == "__main__":
    main()
