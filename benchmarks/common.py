"""Shared benchmark utilities. Every bench prints ``name,us_per_call,derived``
CSV rows (plus richer derived columns per figure); rows are also
collected in-process so drivers can emit machine-readable output
(benchmarks/run.py --json)."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

# every row() call lands here; run.py tags rows with their section and
# drains the list between sections.
RESULTS: List[Dict[str, object]] = []


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time (us) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    line = f"{name},{us:.2f},{derived}"
    print(line)
    RESULTS.append({"name": name, "us": us, "derived": derived})
    return line
