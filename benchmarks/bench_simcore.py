"""Fast event core: executed events/s at O(1k)-O(10k) concurrent
transfers, incremental vs global rebalancing, and the multi-pod fabric.

Three row groups:
- the sweep: n concurrent transfers (1k -> 10k) on the bench_scale
  fleet-scenario shape, with the path count growing alongside the
  population (a bigger fleet has more nodes and therefore more paths;
  ~125 transfers/path, the 1k point's density). The headline property
  is the *curve*: events/s must not collapse as n grows 10x, because
  per-(path,direction) bucket rebalancing makes per-event cost track
  bucket size, not total population. (Piling 10k transfers onto a
  fixed 8 paths is a different regime: every completion then
  legitimately reshapes ~1.2k fair shares, and no scheduler avoids
  that work.);
- the oracle check: the same schedule under rebalance="global"
  (settle-everything, the pre-rework semantics) vs the default
  incremental mode — identical simulated end time, with the speedup in
  the derived column;
- multi-pod: simulated tokens/s of a 4x8-pod cluster syncing gradients
  over the shared dcn:pod trunk, raw vs int8-compressed (train/pods.py)
  at thin and fat trunk bandwidths — the compressed-wins crossover in
  one table.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.fabric import Fabric, Path
from repro.core.runtime import FabricRuntime
from repro.train.cluster import ClusterTimeModel
from repro.train.pods import pod_cluster

from benchmarks.common import row

SWEEP = (1000, 2500, 5000, 10000)
DENSITY = 125  # transfers per path, the 1k fleet point's density


def _fabric(paths: int) -> Fabric:
    return Fabric.of(*[Path(f"p{i}", 100.0) for i in range(paths)],
                     concurrency_discount=0.1)


def _run(n: int, paths: int, mode: str = "incremental"):
    """Issue n transfers (bench_scale's fleet-scenario shape, scaled)
    and drain the event loop; returns (wall_s, events, sim_end_time)."""
    rt = FabricRuntime(_fabric(paths), rebalance=mode)
    rng = np.random.default_rng(0)
    ts = [rt.transfer(f"p{int(rng.integers(paths))}",
                      float(rng.uniform(1.0, 30.0)),
                      flow=f"f{i % 13}", tenant=f"t{i % 5}")
          for i in range(n)]
    ev0 = rt.clock.processed
    t0 = time.monotonic()
    rt.clock.run()
    wall = time.monotonic() - t0
    assert all(t.done for t in ts)
    return wall, rt.clock.processed - ev0, rt.clock.now


def sweep_part() -> None:
    """events/s vs concurrent-transfer population (non-collapsing)."""
    for n in SWEEP:
        paths = max(8, n // DENSITY)
        wall, events, _ = _run(n, paths)
        row(f"simcore/transfers_{n}", wall * 1e6,
            f"events_per_s={events / wall:,.0f} events={events} "
            f"paths={paths} wall_s={wall:.3f}")


def oracle_part() -> None:
    """Incremental vs global rebalancing on one schedule: identical
    simulated timeline, incremental faster."""
    n = 2500
    paths = n // DENSITY
    wi, ei, end_i = _run(n, paths, "incremental")
    wg, eg, end_g = _run(n, paths, "global")
    assert end_i == end_g, (end_i, end_g)
    assert ei == eg, (ei, eg)
    row("simcore/incremental_vs_global", wi * 1e6,
        f"speedup={wg / wi:.2f}x global_wall_s={wg:.3f} "
        f"sim_end={end_i:.6f} identical=True")


def multipod_part() -> None:
    """4 pods x 8 nodes over the shared trunk: the pod_sync tradeoff."""
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=1e9,
                          tokens_per_step=4096 * 16)
    for label, bw in (("thin", 25e9), ("fat", 400e9)):
        tks = {}
        for sync in ("auto", "compressed"):
            c = pod_cluster(4, 8, tm, sync=sync, trunk_bw=bw)
            tks[sync] = c.run(6)["tokens_per_s"]
        best = max(tks, key=tks.get)
        row(f"simcore/multipod_trunk_{label}", 1e12 / tks["auto"],
            f"raw_tokens_per_s={tks['auto']:,.0f} "
            f"compressed_tokens_per_s={tks['compressed']:,.0f} "
            f"winner={best}")


def main() -> None:
    print("# events/s sweep, 1k -> 10k concurrent transfers")
    sweep_part()
    print("# incremental vs global rebalancing (same schedule)")
    oracle_part()
    print("# multi-pod trunk: raw vs compressed pod_sync")
    multipod_part()


if __name__ == "__main__":
    main()
