"""Paper Figure 7 / Advice #1: skewed access collapses the wimpy path.

TPU analogue: Zipfian MoE routing. We measure expert-load imbalance and
dropped-token fraction vs skew, with and without hot-expert replication
(the paper's hot-key replication), on the real MoE layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import moe_ffn

from benchmarks.common import row


def biased_input(key, t, d, e, router, theta: float):
    """Construct inputs whose router logits follow a zipf-like skew."""
    x = jax.random.normal(key, (1, t, d)) * 0.1
    if theta > 0:
        # push tokens toward expert 0..2 proportional to skew
        boost = jnp.asarray(np.random.default_rng(0).zipf(1 + theta, t) % 3)
        bias = router[:, boost].T * 2.0 * theta        # (t, d)
        x = x + bias[None, :, :] * 0.05
    return x


def main() -> None:
    print("# fig7: MoE routing skew -> drop fraction / load imbalance")
    d, e, k, f, t = 64, 16, 2, 128, 4096
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"router": jax.random.normal(ks[0], (d, e)) * 0.5,
              "w_in": jax.random.normal(ks[1], (e, d, 2, f)) * 0.05,
              "w_out": jax.random.normal(ks[2], (e, f, d)) * 0.05}
    for theta in (0.0, 0.5, 1.0, 2.0):
        x = biased_input(ks[3], t, d, e, params["router"], theta)
        for cf, reps, tag in ((1.25, 1, "cap1.25"), (2.0, 1, "cap2.0"),
                              (1.25, 3, "cap1.25+3replicas"),
                              (None, 1, "lossless")):
            _, m = moe_ffn(x, params, num_experts=e, top_k=k,
                           activation=jax.nn.silu, capacity_factor=cf,
                           hot_expert_replicas=reps)
            load = np.asarray(m.expert_load)
            imb = float(load.max() / max(load.mean(), 1e-9))
            row(f"fig7/theta{theta}/{tag}", 0.0,
                f"dropped={float(m.dropped_frac):.3f} imbalance={imb:.2f}")


if __name__ == "__main__":
    main()
