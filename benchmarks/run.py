"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; ``--json out.json`` also
emits the rows as machine-readable records so the perf trajectory can be
tracked across PRs (BENCH_*.json)."""
from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (bench_bidirectional, bench_bucketing,
                        bench_colocation, bench_concurrent,
                        bench_granularity, bench_kernels, bench_kvserve,
                        bench_offload, bench_paths, bench_replication,
                        bench_runtime, bench_scale, bench_simcore,
                        bench_skew, bench_train, roofline)
from benchmarks import common

SECTIONS = [
    ("paths (Fig 3)", bench_paths.main),
    ("bidirectional (Fig 5)", bench_bidirectional.main),
    ("skew (Fig 7)", bench_skew.main),
    ("granularity (Fig 8/9)", bench_granularity.main),
    ("bucketing (Fig 10)", bench_bucketing.main),
    ("concurrent (Fig 12/§4.1)", bench_concurrent.main),
    ("runtime (event-driven fabric)", bench_runtime.main),
    ("train (§6.1 cluster)", bench_train.main),
    ("colocation (§6 multi-tenant)", bench_colocation.main),
    ("offload (SoC compute tier, LineFS §5.1 / DrTM-KV §5.2)",
     bench_offload.main),
    ("scale (million-user serving)", bench_scale.main),
    ("simcore (fast event core + multi-pod)", bench_simcore.main),
    ("replication (Fig 13/15, LineFS §5.1)", bench_replication.main),
    ("kvserve (Fig 17/18, DrTM-KV §5.2)", bench_kvserve.main),
    ("kernels", bench_kernels.main),
    ("roofline (§Roofline)", roofline.main),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write rows as a JSON list of records")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on section names")
    args = ap.parse_args(argv)
    only = [t for t in (args.only or "").split(",") if t]
    if args.json:                      # fail fast, not after minutes of work
        open(args.json, "w").close()

    failures = []
    records = []
    for name, fn in SECTIONS:
        if only and not any(t in name for t in only):
            continue
        print(f"\n==== {name} ====")
        common.RESULTS.clear()
        t0 = time.monotonic()
        try:
            fn()
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
        for r in common.RESULTS:
            records.append({"section": name, **r})
        records.append({"section": name, "name": "_section_wall_s",
                        "us": (time.monotonic() - t0) * 1e6, "derived": ""})

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records, "failures": failures}, f, indent=1)
        print(f"\nwrote {len(records)} rows to {args.json}")
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
