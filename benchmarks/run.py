"""Benchmark driver: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import traceback

from benchmarks import (bench_bidirectional, bench_bucketing, bench_concurrent,
                        bench_granularity, bench_kernels, bench_kvserve,
                        bench_paths, bench_replication, bench_skew, roofline)

SECTIONS = [
    ("paths (Fig 3)", bench_paths.main),
    ("bidirectional (Fig 5)", bench_bidirectional.main),
    ("skew (Fig 7)", bench_skew.main),
    ("granularity (Fig 8/9)", bench_granularity.main),
    ("bucketing (Fig 10)", bench_bucketing.main),
    ("concurrent (Fig 12/§4.1)", bench_concurrent.main),
    ("replication (Fig 13/15, LineFS §5.1)", bench_replication.main),
    ("kv-serve (Fig 17/18, DrTM-KV §5.2)", bench_kvserve.main),
    ("kernels", bench_kernels.main),
    ("roofline (§Roofline)", roofline.main),
]


def main() -> None:
    failures = []
    for name, fn in SECTIONS:
        print(f"\n==== {name} ====")
        try:
            fn()
        except Exception:  # noqa: BLE001 — report all sections
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark sections failed: {failures}")
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
