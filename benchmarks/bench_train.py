"""Simulated training cluster: checkpoint contention + straggler FT.

The §6.1 story as tokens/s: staging checkpoint shards over the SoC
path vs the host path while the host direction is busy with gradient
allreduce traffic (and the ordering flip when the fabric is idle),
occupancy-driven straggler mitigation under a loaded host path, and
the bucketed-DDP overlap sweep (K per-layer-group gradient buckets
issued during backward vs single-shot allreduce). All timing-only —
the numeric stream is exercised by tests/test_cluster.py and
tests/test_overlap.py.
"""
from __future__ import annotations

from repro.train.cluster import ClusterTimeModel, TrainCluster

from benchmarks.common import row

STEPS, NODES = 8, 2
CKPT_EVERY = 2


def _tokens_per_s(grad_bytes: float, ckpt_path: str, *, ckpt_bytes=8e9,
                  compute_s=0.05, **cluster_kw) -> float:
    tm = ClusterTimeModel(compute_s=compute_s, grad_bytes=grad_bytes,
                          ckpt_bytes=ckpt_bytes, ckpt_path=ckpt_path,
                          tokens_per_step=4096 * 16)
    cluster = TrainCluster(cluster_kw.pop("nodes", NODES), tm,
                           ckpt_every=CKPT_EVERY, **cluster_kw)
    return cluster.run(STEPS)["tokens_per_s"]


def contention_part() -> None:
    """Checkpoint staging path choice under busy vs idle host paths."""
    busy, idle = 8e9, 1e6
    for label, grad in (("busy", busy), ("idle", idle)):
        soc = _tokens_per_s(grad, "soc")
        host = _tokens_per_s(grad, "host")
        best = "soc" if soc > host else "host"
        row(f"train/ckpt_soc_{label}", 1e6 * STEPS * 4096 * 16 / soc / STEPS,
            f"tokens_per_s={soc:,.0f}")
        row(f"train/ckpt_host_{label}", 1e6 * STEPS * 4096 * 16 / host / STEPS,
            f"tokens_per_s={host:,.0f} winner={best} "
            f"delta={abs(soc - host) / max(soc, host):.1%}")


def straggler_part() -> None:
    """One node's host path is 80% spoken for: occupancy-driven
    rebalance shifts compute off it and the fleet speeds up."""
    kw = dict(nodes=3, host_load={"node2": 0.8}, ckpt_bytes=0.0,
              compute_s=0.5)
    plain = _tokens_per_s(1e9, "soc", mitigate_stragglers=False, **kw)
    mitigated = _tokens_per_s(1e9, "soc", mitigate_stragglers=True, **kw)
    row("train/straggler_unmitigated", 1e12 / plain,
        f"tokens_per_s={plain:,.0f}")
    row("train/straggler_mitigated", 1e12 / mitigated,
        f"tokens_per_s={mitigated:,.0f} "
        f"win={mitigated / plain - 1:.1%}")


def bucket_part() -> None:
    """Bucketed DDP overlap: K per-layer-group gradient buckets, each
    allreduce issued as its backward slice completes, vs single-shot
    allreduce — on the comm-bound headline config (comm ~ compute)."""
    def step_s(buckets):
        tm = ClusterTimeModel(compute_s=0.6, grad_bytes=2e9,
                              tokens_per_step=4096 * 16, buckets=buckets)
        cluster = TrainCluster(NODES, tm)
        s = cluster.run(STEPS)
        return s["sim_seconds"] / s["steps"]

    t1 = step_s(1)
    row("train/bucketed_k1", t1 * 1e6, "single-shot allreduce")
    for k in (2, 4, 8):
        tk = step_s(k)
        row(f"train/bucketed_k{k}", tk * 1e6,
            f"win={100 * (1 - tk / t1):.1f}% vs k1")

    # hierarchical: 2 pods over a thin trunk, per-bucket leader rings
    from repro.train.pods import pod_cluster

    def pod_step_s(buckets):
        tm = ClusterTimeModel(compute_s=0.6, grad_bytes=5e8,
                              tokens_per_step=4096 * 16, buckets=buckets)
        s = pod_cluster(2, 2, tm, sync="compressed",
                        trunk_bw=25e9).run(STEPS)
        return s["sim_seconds"] / s["steps"]

    p1, p4 = pod_step_s(1), pod_step_s(4)
    row("train/bucketed_pods_thin", p4 * 1e6,
        f"win={100 * (1 - p4 / p1):.1f}% vs k1 "
        f"(2x2 pods, compressed thin trunk)")


def elastic_part() -> None:
    """Node failure mid-run: detect -> resize -> resume, in sim time."""
    tm = ClusterTimeModel(compute_s=0.05, grad_bytes=2e9,
                          tokens_per_step=4096 * 16)
    cluster = TrainCluster(4, tm, fail_at=("node3", 4),
                           heartbeat_every=0.2, heartbeat_timeout=1.0)
    s = cluster.run(STEPS)
    detect = next(e["t"] for e in s["events"]
                  if e["event"] == "failure_detected")
    silent = next(e["t"] for e in s["events"] if e["event"] == "node_silent")
    row("train/elastic_detect", (detect - silent) * 1e6,
        f"survivors={s['nodes']} mesh={s['mesh']} "
        f"tokens_per_s={s['tokens_per_s']:,.0f}")


def main() -> None:
    print("# simulated train cluster: ckpt contention / stragglers / "
          "elastic / bucketed overlap")
    contention_part()
    straggler_part()
    bucket_part()
    elastic_part()


if __name__ == "__main__":
    main()
