"""Paper Figures 13/15 (§5.1 LineFS): checkpoint replication alternatives.

Executable: a real (reduced) model checkpoint is saved with/without
compression + chain-replicated; we report sizes, wall times, measured
compression ratio, and the planner's A1/A2/A3 analysis + greedy A2+A3
combination driven by the *measured* ratio — the full §4.2 loop."""
from __future__ import annotations

import os
import tempfile
import time

import jax

from repro.ckpt.checkpoint import CheckpointManager, save_checkpoint
from repro.ckpt.replication import plan_replication, simulate_replication
from repro.configs import get_config
from repro.core.fabric import (MultipathRouter, linefs_fabric,
                               linefs_replication_alternatives)
from repro.models.params import init_params

from benchmarks.common import row

N = 200e9 / 8
P_ = 256e9 / 8


def main() -> None:
    print("# fig13/15: LineFS-analogue checkpoint replication")
    cfg = get_config("internlm2-1.8b").reduced(d_model=256, d_ff=512,
                                               vocab_size=4096)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as tmp:
        for compress, tag in ((False, "A3_raw"), (True, "A2_compressed")):
            t0 = time.monotonic()
            st = save_checkpoint(os.path.join(tmp, tag), params, step=0,
                                 compress=compress)
            row(f"fig13/{tag}", st["seconds"] * 1e6,
                f"raw={st['raw_bytes']/2**20:.1f}MiB stored="
                f"{st['stored_bytes']/2**20:.1f}MiB ratio={st['ratio']:.2f}")
        ratio = st["ratio"]     # measured compression ratio of real weights

        # chain replication wall time (2 replicas)
        mgr = CheckpointManager(os.path.join(tmp, "chain"), every=1, replicas=2)
        t0 = time.monotonic()
        mgr.save(0, params, blocking=True)
        row("fig13/chain_2replicas", (time.monotonic() - t0) * 1e6,
            f"replicas=2 ratio={mgr.stats[-1]['ratio']:.2f}")

    # §5.1 analysis at the measured ratio (paper's Fig 14/15 math)
    fabric = linefs_fabric(N, P_)
    alts = linefs_replication_alternatives(N, P_, ratio)
    router = MultipathRouter(fabric)
    for a in alts:
        row(f"fig15/{a.name}_solo", 0.0,
            f"{a.solo_rate(fabric)*8/1e9:.0f}Gbps ratio={ratio:.2f}")
    allocs, total = router.allocate([alts[1], alts[2]])
    row("fig15/A2_plus_A3", 0.0,
        f"{total*8/1e9:.0f}Gbps "
        + " ".join(f"{al.alternative}={al.rate*8/1e9:.0f}Gbps" for al in allocs))
    plan = plan_replication(ratio=ratio)
    row("fig15/planner_decision", 0.0,
        f"ranked={plan.ranked} compress={plan.use_compression} | {plan.notes}")

    # paper headline: multi-path vs single-path improvement
    single = max(a.solo_rate(fabric) for a in alts)
    row("fig13/multipath_gain", 0.0,
        f"+{(total/single-1)*100:.0f}% vs best single path (paper: +7-30%)")

    # simulated-time execution at the *measured* ratio: chunked A2-style
    # staging + send, sequential vs pipelined (paper's ~30% win)
    ckpt_bytes = st["raw_bytes"]
    kw = dict(chunks=8, net_bw=N, staging_bw=P_, ratio=ratio)
    seq = simulate_replication(ckpt_bytes, pipelined=False, **kw)
    pipe = simulate_replication(ckpt_bytes, pipelined=True, **kw)
    for tag, sim in (("sequential", seq), ("pipelined", pipe)):
        row(f"fig13/sim_{tag}", sim.seconds * 1e6,
            f"chunks={sim.chunks} p50_done={sim.percentile(50)*1e6:.1f}us "
            f"p99_done={sim.percentile(99)*1e6:.1f}us")
    row("fig13/sim_pipelining_win", 0.0,
        f"{(1-pipe.seconds/seq.seconds)*100:.0f}% lower simulated latency "
        f"(paper ~30%)")


if __name__ == "__main__":
    main()
