"""Paper §4.1 / Figure 12: concurrent paths — interference + gains.

Budget-ledger reproduction of: ①+② concurrent gains (4-13%), ③'s hidden
bottleneck (P-N rule), and the DMA variant's reduced interference."""
from __future__ import annotations

from repro.core.fabric import Alternative, Fabric, Path, Use

from benchmarks.common import row

N = 200e9 / 8
P_ = 256e9 / 8


def fabric() -> Fabric:
    return Fabric.of(
        Path("net", N, latency=1e-6, kind="ici", shared_group="net"),
        Path("pcie", P_, latency=3e-7, kind="pcie", shared_group="pcie"),
        Path("dma", 0.7 * P_, latency=3e-7, kind="pcie", shared_group="pcie"),
    )


def main() -> None:
    print("# fig12/4.1: concurrent path combinations (budget ledger)")
    router = fabric().router()
    # ① + ③(H2S): intra-machine relay eats both pcie directions
    p1 = Alternative("p1_host", uses=[Use("net", out=1), Use("pcie", out=1)])
    p3 = Alternative("p3_relay", uses=[Use("pcie", out=1, in_=1)])
    p3dma = Alternative("p3_dma", uses=[Use("dma", out=1)])
    for name, combo in [("p1_alone", [p1]), ("p1_plus_p3", [p1, p3]),
                        ("p3_alone", [p3]), ("p1_plus_dma", [p1, p3dma])]:
        allocs, total = router.allocate(combo)
        parts = " ".join(f"{a.alternative}={a.rate*8/1e9:.0f}Gbps({a.bottleneck})"
                         for a in allocs)
        row(f"fig12/{name}", 0.0, f"total={total*8/1e9:.0f}Gbps {parts}")
    # the B_slow <= P - N slack rule
    slack = router.slack(p1, "pcie")
    row("fig12/slack_P_minus_N", 0.0,
        f"slack={slack*8/1e9:.0f}Gbps expected={(P_-N)*8/1e9:.0f}Gbps")


if __name__ == "__main__":
    main()
