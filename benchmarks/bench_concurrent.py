"""Paper §4.1 / Figure 12: concurrent paths — interference + gains.

Budget-model reproduction of: ①+② concurrent gains (4-13%), ③'s hidden
bottleneck (P-N rule), and the DMA variant's reduced interference."""
from __future__ import annotations

from repro.core.planner import Alternative, PathPlanner, PathUse
from repro.core.paths import PathSpec

from benchmarks.common import row

N = 200e9 / 8
P_ = 256e9 / 8


def paths():
    return {
        "net": PathSpec("net", "ici", None, 2, N, 1e-6, True, "net"),
        "pcie": PathSpec("pcie", "pcie", None, 2, P_, 3e-7, True, "pcie"),
        "dma": PathSpec("dma", "pcie", None, 2, 0.7 * P_, 3e-7, True, "pcie"),
    }


def main() -> None:
    print("# fig12/4.1: concurrent path combinations (budget model)")
    pl = PathPlanner(paths())
    # ① + ③(H2S): intra-machine relay eats both pcie directions
    p1 = Alternative("p1_host", uses=[PathUse("net", out_bytes=1),
                                      PathUse("pcie", out_bytes=1)])
    p3 = Alternative("p3_relay", uses=[PathUse("pcie", out_bytes=1, in_bytes=1)])
    p3dma = Alternative("p3_dma", uses=[PathUse("dma", out_bytes=1)])
    for name, combo in [("p1_alone", [p1]), ("p1_plus_p3", [p1, p3]),
                        ("p3_alone", [p3]), ("p1_plus_dma", [p1, p3dma])]:
        allocs, total = pl.combine_greedy(combo)
        parts = " ".join(f"{a.alternative}={a.rate*8/1e9:.0f}Gbps({a.bottleneck})"
                         for a in allocs)
        row(f"fig12/{name}", 0.0, f"total={total*8/1e9:.0f}Gbps {parts}")
    # the B_slow <= P - N slack rule
    slack = pl.slack(p1, "pcie")
    row("fig12/slack_P_minus_N", 0.0,
        f"slack={slack*8/1e9:.0f}Gbps expected={(P_-N)*8/1e9:.0f}Gbps")


if __name__ == "__main__":
    main()
