"""Paper Figures 17/18 (§5.2 DrTM-KV): disaggregated KV-store paths.

Executable data plane (real index + values + YCSB-C zipfian keys) with
the calibrated path model; reproduces the per-alternative latency and
throughput table and the A4+A5 combination, plus the paper's headline
deltas. Also benches the LLM-serving analogue: batched decode through
the real engine (the "value read" path that placement accelerates)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.disagg import DisaggKV, KVStoreParams
from repro.serve.engine import Request, ServeEngine

from benchmarks.common import row


def kv_part() -> None:
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    fabric, alts = kv.fabric(), kv.alternatives()
    keys = kv.zipf_keys(3000)
    for alt in ("A1", "A2", "A3", "A4", "A5"):
        lats = []
        t0 = time.monotonic()
        for k in keys[:1000]:
            v, lat = kv.get(int(k), alt)
            lats.append(lat)
        thr = alts[alt].solo_rate(fabric)
        p50, p99 = np.percentile(lats, [50, 99])
        row(f"fig17/{alt}", float(np.mean(lats)) * 1e6,
            f"model_thr={thr/1e6:.1f}M p50={p50*1e6:.2f}us p99={p99*1e6:.2f}us "
            f"data_plane_wall={time.monotonic()-t0:.2f}s")
    total, allocs = kv.combined_a4_a5()
    a1 = alts["A1"].solo_rate(fabric)
    a4 = alts["A4"].solo_rate(fabric)
    rnic = kv.c.rnic_read_rate / 2
    row("fig18/A4_plus_A5", 0.0,
        f"{total/1e6:.1f}M hit_mass={kv.cache_hit_mass():.2f} "
        f"vs_RNIC=+{(total/rnic-1)*100:.0f}% (paper +25%) "
        f"vs_A1=+{(total/a1-1)*100:.0f}% (paper +36%) "
        f"vs_A4=+{(total/a4-1)*100:.0f}% (paper +12%)")


def engine_part() -> None:
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    eng = ServeEngine(cfg, params, slots=4, max_len=96, impl="ref",
                      fabric=kv.fabric(), cache_hit_mass=kv.cache_hit_mass(),
                      placement_costs=kv.c)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=16) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    t0 = time.monotonic()
    done = eng.run()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    pl = eng.placement
    row("fig18/engine_decode", dt / max(toks, 1) * 1e6,
        f"tok_s={toks/dt:.1f} requests={len(done)} "
        f"decode_steps={eng.stats['decode_steps']} "
        f"placement={pl.location} rate={pl.rate/1e6:.1f}M "
        f"(+{(pl.rate/pl.baseline_rate-1)*100:.0f}% vs host)")


def staged_engine_part() -> None:
    """The event-driven pipeline on the §5.2 fabric: per-admit placement
    from live ledger occupancy + simulated TTFT percentiles."""
    from repro.serve.disagg import kv_serve_time_model
    from repro.serve.engine import StagedServeEngine
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    kv = DisaggKV(KVStoreParams(n_keys=100_000, soc_cache_keys=10_000))
    tm = kv_serve_time_model()
    eng = StagedServeEngine(cfg, params, slots=4, max_len=96, impl="ref",
                            fabric=kv.fabric(), time_model=tm,
                            plan_placement=True,
                            cache_hit_mass=kv.cache_hit_mass(),
                            placement_costs=kv.c)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=16, arrival=i * 1e-5) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    ttfts = np.asarray([r.ttft for r in reqs])
    p50, p99 = np.percentile(ttfts, [50, 99])
    row("fig18/staged_engine_ttft", p99 * 1e6,
        f"p50={p50*1e3:.3f}ms p99={p99*1e3:.3f}ms "
        f"makespan={eng.clock.now*1e3:.3f}ms placements={eng.placements} "
        f"prefill_compilations={eng.stats['prefill_compilations']:.0f}")


def main() -> None:
    print("# fig17/18: DrTM-KV alternatives + combined A4+A5")
    kv_part()
    engine_part()
    staged_engine_part()


if __name__ == "__main__":
    main()
