"""Multi-tenant colocation (§6): solo vs unmanaged vs QoS-managed.

The headline crossover as benchmark rows: unmanaged colocation inflates
the serve tenant's p99 TTFT >2x its solo baseline while QoS weights +
SLO-driven admission control hold it within ~1.2x, costing the train
tenant <20% of its solo tokens/s. Serve compute is real jax (reduced
config, ref impl); train is timing-only on the shared ledger.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.models.params import init_params
from repro.serve.engine import Request, StagedServeEngine
from repro.tenancy import (AdmissionConfig, Colocation, QoSPolicy, SERVE,
                           TRAIN, colocation_fabric, colocation_time_model,
                           solo_serve, solo_train)
from repro.train.cluster import ClusterTimeModel, TrainCluster

from benchmarks.common import row

N_REQS, TRAIN_STEPS = 8, 4


def _pieces():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    fabric = lambda: colocation_fabric(  # noqa: E731
        2, host_bw=16.0, soc_frac=0.7, net_bw_per_node=100.0, decode_bw=64.0,
        concurrency_discount=0.1)
    tm = colocation_time_model(0, prefill_units_per_token=0.25,
                               decode_units_per_slot=0.25)
    ctm = ClusterTimeModel(compute_s=0.3, grad_bytes=16.0, ckpt_bytes=8.0,
                           ckpt_path="soc", tokens_per_step=1024)

    def make_engine(rt):
        return StagedServeEngine(cfg, params, slots=2, max_len=64, impl="ref",
                                 runtime=rt, time_model=tm, tenant=SERVE)

    def make_cluster(rt):
        return TrainCluster(2, ctm, fabric=rt.fabric, runtime=rt,
                            ckpt_every=2, tenant=TRAIN)

    def requests():
        rng = np.random.default_rng(7)
        return [Request(rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=4, arrival=0.3 * i)
                for i in range(N_REQS)]

    return fabric, make_engine, make_cluster, requests


def main() -> None:
    print("# serve+train colocation on one ledger: solo / unmanaged / managed")
    fabric, make_engine, make_cluster, requests = _pieces()

    solo_s = solo_serve(fabric(), make_engine, requests())
    solo_t = solo_train(fabric(), make_cluster, TRAIN_STEPS)
    row("colocation/serve_solo_p99", solo_s["p99_ttft"] * 1e6,
        f"p50={solo_s['p50_ttft']:.4f}s")
    row("colocation/train_solo", 1e6 / solo_t["tokens_per_s"],
        f"tokens_per_s={solo_t['tokens_per_s']:,.0f}")

    un = Colocation(fabric=fabric(), make_engine=make_engine,
                    make_cluster=make_cluster).run(requests(), TRAIN_STEPS)
    row("colocation/serve_unmanaged_p99", un.serve["p99_ttft"] * 1e6,
        f"inflation={un.serve['p99_ttft'] / solo_s['p99_ttft']:.2f}x")
    row("colocation/train_unmanaged", 1e6 / un.train["tokens_per_s"],
        f"retention={un.train['tokens_per_s'] / solo_t['tokens_per_s']:.1%}")

    mg = Colocation(
        fabric=fabric(), make_engine=make_engine, make_cluster=make_cluster,
        qos=QoSPolicy.serve_train(16.0, 1.0),
        admission=AdmissionConfig(slo_ttft=1.2 * solo_s["p99_ttft"],
                                  occupancy_limit=0.4,
                                  watch_paths=("host:0",)),
        ).run(requests(), TRAIN_STEPS)
    row("colocation/serve_managed_p99", mg.serve["p99_ttft"] * 1e6,
        f"inflation={mg.serve['p99_ttft'] / solo_s['p99_ttft']:.2f}x "
        f"throttles={mg.throttles}")
    row("colocation/train_managed", 1e6 / mg.train["tokens_per_s"],
        f"retention={mg.train['tokens_per_s'] / solo_t['tokens_per_s']:.1%} "
        f"host0_train_occ={mg.occupancy.get('host:0', {}).get(TRAIN, 0.0):.2f}")


if __name__ == "__main__":
    main()
