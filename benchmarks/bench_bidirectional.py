"""Paper Figure 5: bidirectional multiplexing + path combinations.

(a) opposite-direction flows on one bidirectional link reach ~2x the
    one-way limit; same-direction flows split it (planner budget model);
(b) executable analogue: bidirectional ring all-gather vs one-way ring
    on a CPU mesh — wall time + the HLO-counted ppermute traffic."""
from __future__ import annotations

import os
import subprocess
import sys

from repro.core.fabric import Alternative, Fabric, Path, Use

from benchmarks.common import row

N = 200e9 / 8


def model_part() -> None:
    router = Fabric.of(Path("net", N, latency=1e-6, kind="ici")).router()
    read = Alternative("read", uses=[Use("net", out=1)])
    write = Alternative("write", uses=[Use("net", in_=1)])
    read2 = Alternative("read2", uses=[Use("net", out=1)])
    relay = Alternative("relay", uses=[Use("net", out=1, in_=1)])
    for name, combo in [("read_write", [read, write]),
                        ("read_read", [read, read2]),
                        ("relay_alone", [relay]),
                        ("relay_plus_read", [relay, read])]:
        _, total = router.allocate(combo)
        row(f"fig5/{name}", 0.0, f"GBps={total * 8 / 1e9:.0f}Gbps")


def executable_part() -> None:
    """Runs the ring-collective microbench on 8 fake devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.collectives import all_gather_bidirectional, ring_all_gather
from jax import shard_map
import functools
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.ones((1024, 256))
with jax.set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    for bidir in (False, True):
        fn = jax.jit(lambda a, b=bidir: shard_map(
            functools.partial(ring_all_gather, axis="data", bidirectional=b),
            mesh=mesh, in_specs=(P("data", None),), out_specs=P(None, None),
            check_vma=False)(a))
        out = fn(xs); jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(10):
            jax.block_until_ready(fn(xs))
        dt = (time.perf_counter() - t0) / 10
        hlo = fn.lower(xs).compile().as_text()
        nperm = hlo.count("collective-permute(")
        print(f"fig5b/ring_ag_bidir={bidir},{dt*1e6:.1f},permutes={nperm}")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    print(out.stdout.strip())
    if out.returncode != 0:
        print(out.stderr[-1500:])


def main() -> None:
    print("# fig5: bidirectional multiplexing (budget model + executable)")
    model_part()
    executable_part()


if __name__ == "__main__":
    main()
