"""Kernel wall times (interpret mode on CPU — correctness-path numbers,
not TPU perf; TPU perf comes from the roofline analysis)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ops import decode_attention_kernel
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.quant.ops import quantize_int8
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.models.attention import attention_blocked, attention_ref

from benchmarks.common import row, time_call


def main() -> None:
    print("# kernels: interpret-mode wall times vs jnp reference")
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, s, hq, hkv, d = 1, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    us = time_call(jax.jit(lambda a, b2, c: attention_ref(a, b2, c)), q, k, v)
    row("kern/attn_ref", us, f"S={s}")
    us = time_call(jax.jit(lambda a, b2, c: attention_blocked(a, b2, c, q_block=128, kv_block=128)), q, k, v)
    row("kern/attn_blocked", us, f"S={s}")
    us = time_call(lambda a, b2, c: flash_attention(a, b2, c, q_block=128, kv_block=128), q, k, v)
    row("kern/flash_pallas_interp", us, f"S={s}")

    kc = jax.random.normal(ks[1], (2, 2048, 2, 64))
    vc = jax.random.normal(ks[2], (2, 2048, 2, 64))
    qd = jax.random.normal(ks[0], (2, 1, 8, 64))
    us = time_call(lambda a, b2, c: decode_attention_kernel(a, b2, c, jnp.asarray(1500)), qd, kc, vc)
    row("kern/decode_pallas_interp", us, "S=2048")

    x = jax.random.normal(ks[0], (1, 256, 8, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 8)))
    A = -jnp.exp(jax.random.normal(ks[2], (8,)))
    Bm = jax.random.normal(ks[3], (1, 256, 32))
    C = jax.random.normal(ks[4], (1, 256, 32))
    us = time_call(lambda *a: ssd_scan(*a, chunk=64, head_tile=4), x, dt, A, Bm, C)
    row("kern/ssd_pallas_interp", us, "S=256 H=8")

    g = jax.random.normal(ks[0], (1 << 16,))
    us = time_call(lambda a: quantize_int8(a, block=256), g)
    row("kern/quant_pallas_interp", us, "n=65536")


if __name__ == "__main__":
    main()
