"""Generate EXPERIMENTS.md: dry-run + roofline tables from runs/dryrun
artifacts, plus the hand-authored validation/perf sections."""
import glob
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
RUNS = os.path.join(ROOT, "runs", "dryrun")

ARCH_ORDER = ["glm4-9b", "gemma2-9b", "gemma-7b", "internlm2-1.8b",
              "granite-moe-1b-a400m", "moonshot-v1-16b-a3b", "internvl2-2b",
              "musicgen-large", "mamba2-2.7b", "jamba-1.5-large-398b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SKIP_ARCHS = {"glm4-9b", "gemma2-9b", "gemma-7b", "internlm2-1.8b",
              "granite-moe-1b-a400m", "moonshot-v1-16b-a3b", "internvl2-2b",
              "musicgen-large"}


def load_all():
    out = {}
    for fn in glob.glob(os.path.join(RUNS, "*.json")):
        base = os.path.basename(fn)[:-5]
        with open(fn) as f:
            r = json.load(f)
        tag = ""
        for t in ("_opt_", "_diag"):
            if t in base:
                tag = base.split(t, 1)[1]
        key = (r["arch"], r["shape"], r["mesh"], tag)
        out[key] = r
    return out


def fmt_bytes(n):
    if n >= 2**30:
        return f"{n/2**30:.2f}GiB"
    return f"{n/2**20:.1f}MiB"


def dryrun_table(rows):
    lines = ["| arch | shape | mesh | compiled | args/chip | temp (module) | FLOPs/chip | coll. ops |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = rows.get((arch, shape, mesh, ""))
                if r is None:
                    if shape == "long_500k" and arch in SKIP_ARCHS:
                        lines.append(f"| {arch} | {shape} | {mesh} | SKIP (full attention at 524k) | — | — | — | — |")
                    else:
                        lines.append(f"| {arch} | {shape} | {mesh} | (pending) | — | — | — | — |")
                    continue
                ops = ", ".join(f"{k}:{v}" for k, v in sorted(r["collective_op_counts"].items()))
                lines.append(
                    f"| {arch} | {shape} | {mesh} | yes ({r['compile_s']:.0f}s) "
                    f"| {fmt_bytes(r['memory']['argument_bytes'])} "
                    f"| {fmt_bytes(r['memory']['temp_bytes'])} "
                    f"| {r['flops_per_chip']:.2e} | {ops} |")
    return "\n".join(lines)


def roofline_table(rows, mesh="16x16"):
    lines = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO | per-path |",
             "|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape, mesh, ""))
            if r is None:
                continue
            per = ", ".join(f"{k}={v*1e3:.1f}ms" for k, v in sorted(r["collective_s_per_path"].items()))
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | {r['dominant']} "
                f"| {r['useful_flops_ratio']:.2f} | {per} |")
    return "\n".join(lines)


def bottleneck_notes(rows):
    notes = []
    for arch in ARCH_ORDER:
        r = rows.get((arch, "train_4k", "16x16", ""))
        if r is None:
            continue
        dom = r["dominant"]
        fix = {
            "compute": "raise per-chip batch or cut recompute (remat policy)",
            "memory": "fuse elementwise chains / widen loss chunks / drop remat recompute reads",
            "collective": "narrow TP-boundary dtype (bf16 on TPU), shrink TP degree for this size, overlap with compute",
        }[dom]
        notes.append(f"- **{arch} x train_4k**: dominant={dom}; to move it: {fix}.")
    return "\n".join(notes)


HEADER = """# EXPERIMENTS

All numbers from this repository on the CPU container (TPU v5e is the
*target*: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 6.25 GB/s
DCN/chip — core/hw.py). Dry-run = `.lower().compile()` with
ShapeDtypeStructs on 512 fake host devices; every FLOP/byte/collective
figure is parsed from the compiled per-device HLO (layers fully
unrolled so scan bodies are counted; see DESIGN.md).

Known CPU-backend artifacts (affect absolute values, not comparisons):
XLA CPU's AllReducePromotion pass forces every reduce-collective to f32
(the TPU target moves bf16: collective terms here are ~2x TPU wire
bytes for activation reductions); CPU HLO does not fuse like TPU, so
"bytes accessed" (memory term) over-counts elementwise traffic; and
`memory_analysis().temp_size` aggregates the whole module.
"""


def main():
    rows = load_all()
    done = sum(1 for k in rows if not k[3])
    parts = [HEADER]
    parts.append("## §Dry-run (deliverable e) — every (arch x shape x mesh) cell\n")
    parts.append(f"{done} cells lowered+compiled (40 logical cells x 2 meshes; "
                 "8 archs skip long_500k by design).\n")
    parts.append(dryrun_table(rows))
    parts.append("\n## §Roofline (deliverable g) — single-pod 16x16\n")
    parts.append(roofline_table(rows, "16x16"))
    parts.append("\n### Multi-pod 2x16x16\n")
    parts.append(roofline_table(rows, "2x16x16"))
    parts.append("\n### Dominant-term notes (one per arch, train_4k)\n")
    parts.append(bottleneck_notes(rows))
    static = os.path.join(ROOT, "scripts", "experiments_static.md")
    if os.path.exists(static):
        parts.append("\n" + open(static).read())
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"EXPERIMENTS.md written ({done} baseline cells)")


if __name__ == "__main__":
    main()
