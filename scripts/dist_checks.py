"""Multi-device correctness checks (run under
XLA_FLAGS=--xla_force_host_platform_device_count=8 by test_distributed.py).

Covers: explicit collectives == lax oracles, EP MoE == dense ref,
context-parallel decode == local decode, compressed pod-sync training
step ~= exact, elastic resharding.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

assert len(jax.devices()) == 8, jax.devices()


def check_collectives():
    from repro.core.collectives import (all_gather_bidirectional,
                                        all_reduce_compressed,
                                        all_reduce_hierarchical)
    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    key = jax.random.PRNGKey(0)
    with jax.set_mesh(mesh):
        x = jax.random.normal(key, (16, 8))
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        got = jax.jit(lambda a: all_gather_bidirectional(a, mesh, "data"))(xs)
        assert float(jnp.abs(got - x).max()) == 0.0
        y = jax.random.normal(key, (12, 5))
        out = jax.jit(lambda a: all_reduce_hierarchical(a, mesh, "data", "pod"))(y)
        assert float(jnp.abs(out - 8 * y).max()) < 1e-5
        out2 = jax.jit(lambda a: all_reduce_compressed(a, mesh, "pod"))(y)
        rel = float(jnp.abs(out2 - 2 * y).max() / jnp.abs(2 * y).max())
        assert rel < 0.02, rel
    print("collectives OK")


def check_moe_ep():
    from repro.models.moe import moe_ffn, moe_ffn_dense_ref
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    k0 = jax.random.PRNGKey(2)
    B, S, D, E, K, F = 4, 8, 32, 8, 2, 64
    ks = jax.random.split(k0, 4)
    x = jax.random.normal(ks[0], (B, S, D)) * 0.5
    params = {"router": jax.random.normal(ks[1], (D, E)) * 0.02,
              "w_in": jax.random.normal(ks[2], (E, D, 2, F)) * 0.05,
              "w_out": jax.random.normal(ks[3], (E, F, D)) * 0.05}
    yref = moe_ffn_dense_ref(x, params, num_experts=E, top_k=K,
                             activation=jax.nn.silu)
    with jax.set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None, None)))
        ps = {"router": jax.device_put(params["router"], NamedSharding(mesh, P("data", None))),
              "w_in": jax.device_put(params["w_in"], NamedSharding(mesh, P("model", "data", None, None))),
              "w_out": jax.device_put(params["w_out"], NamedSharding(mesh, P("model", None, "data")))}
        y, m = jax.jit(lambda a, b: moe_ffn(a, b, num_experts=E, top_k=K,
                                            activation=jax.nn.silu,
                                            capacity_factor=None))(xs, ps)
    err = float(jnp.abs(jnp.asarray(y, jnp.float32) - yref.astype(jnp.float32)).max())
    assert err < 5e-2, err
    assert float(m.dropped_frac) == 0.0
    print("moe EP OK")


def check_cp_decode():
    from repro.models.attention import (decode_attention,
                                        decode_attention_context_parallel)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, Hq, Hkv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, Hq, d))
    kc = jax.random.normal(ks[1], (B, S, Hkv, d))
    vc = jax.random.normal(ks[2], (B, S, Hkv, d))
    ref = decode_attention(q, kc, vc, jnp.asarray(40))
    with jax.set_mesh(mesh):
        qs = jax.device_put(q, NamedSharding(mesh, P("data", None, None, None)))
        kcs = jax.device_put(kc, NamedSharding(mesh, P("data", "model", None, None)))
        vcs = jax.device_put(vc, NamedSharding(mesh, P("data", "model", None, None)))
        out = jax.jit(lambda a, b, c: decode_attention_context_parallel(
            a, b, c, jnp.asarray(40), mesh=mesh, axis="model",
            batch_axes=("data",)))(qs, kcs, vcs)
    err = float(jnp.abs(ref - jnp.asarray(out)).max())
    assert err < 1e-4, err
    print("context-parallel decode OK")


def check_compressed_pod_sync():
    from repro.configs import RunConfig, get_config
    from repro.models.params import init_params
    from repro.optim.adamw import adamw_init
    from repro.train.train_step import make_train_step
    cfg = get_config("internlm2-1.8b").reduced()
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 8, 32
    tokens = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                           cfg.vocab_size))
    batch = {"tokens": tokens, "labels": tokens,
             "loss_mask": np.ones((b, s), np.float32)}
    outs = {}
    with jax.set_mesh(mesh):
        for mode in ("auto", "compressed"):
            run = RunConfig(learning_rate=1e-3, warmup_steps=1,
                            total_steps=10, pod_sync=mode)
            step = jax.jit(make_train_step(cfg, run, impl="ref", mesh=mesh))
            bput = {k: jax.device_put(jnp.asarray(v),
                                      NamedSharding(mesh, P(("pod", "data"),) ))
                    for k, v in batch.items()}
            p2, _, m = step(params, adamw_init(params), bput, jnp.asarray(0))
            outs[mode] = (p2, float(m["loss"]))
    la, lc = outs["auto"][1], outs["compressed"][1]
    assert abs(la - lc) / abs(la) < 1e-3, (la, lc)
    # params close but not necessarily identical (int8 wire format)
    diffs = [float(jnp.abs(a - c).max()) for a, c in
             zip(jax.tree.leaves(outs["auto"][0]), jax.tree.leaves(outs["compressed"][0]))]
    assert max(diffs) < 5e-3, max(diffs)
    print("compressed pod sync OK")


def check_elastic_reshard():
    from repro.configs import get_config
    from repro.ft.elastic import best_mesh_for, make_mesh, reshard
    from repro.models.params import init_params, _logical_only
    cfg = get_config("internlm2-1.8b").reduced()
    params, logical = init_params(cfg, jax.random.PRNGKey(0))
    shape, names = best_mesh_for(8, model=2)
    m8 = make_mesh(shape, names)
    p8 = reshard(params, logical, m8)
    # "lose 4 devices" -> remesh to 4 and reshard
    shape2, names2 = best_mesh_for(4, model=2)
    m4 = make_mesh(shape2, names2)
    p4 = reshard(p8, logical, m4)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p4)):
        assert float(jnp.abs(a - jnp.asarray(b)).max()) == 0.0
    print("elastic reshard OK")


def _supports_partial_manual() -> bool:
    """Old XLA refuses PartitionId under partially-manual shard_map
    (`auto=` axes), which the pod-sync step relies on."""
    ver = tuple(int(x) for x in jax.__version__.split(".")[:2])
    return ver >= (0, 5)


if __name__ == "__main__":
    check_collectives()
    check_moe_ep()
    check_cp_decode()
    if _supports_partial_manual():
        check_compressed_pod_sync()
    else:
        print(f"compressed pod sync SKIPPED (jax {jax.__version__} "
              "lacks partial-manual SPMD support)")
    check_elastic_reshard()
    print("ALL DISTRIBUTED CHECKS PASSED")
