#!/usr/bin/env bash
# Tier-1 gate: the full test suite, exactly as ROADMAP.md specifies.
#   scripts/ci.sh            # run tests
#   scripts/ci.sh --bench    # also run the benchmark driver with JSON output
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

if [[ "${1:-}" == "--bench" ]]; then
    PYTHONPATH=src:. python benchmarks/run.py --json "BENCH_$(date +%Y%m%d).json"
fi
