#!/usr/bin/env bash
# Tier-1 gate: the full test suite, exactly as ROADMAP.md specifies,
# plus the runtime/train/colocation/kvserve/offload benchmark sections
# with schema-validated JSON output (BENCH_6.json — the PR-6 perf
# trajectory record).
#   scripts/ci.sh            # tests + runtime,train,colocation,kvserve,offload
#   scripts/ci.sh --bench    # also run the full benchmark driver
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src:. python benchmarks/run.py --json BENCH_6.json \
    --only runtime,train,colocation,kvserve,offload

# fail on schema-invalid benchmark output
PYTHONPATH=src python - <<'EOF'
import json, numbers, sys

with open("BENCH_6.json") as f:
    doc = json.load(f)
problems = []
if not isinstance(doc, dict) or set(doc) != {"rows", "failures"}:
    problems.append(f"top level must be {{rows, failures}}, got {type(doc)}")
else:
    if doc["failures"]:
        problems.append(f"failed sections: {doc['failures']}")
    if not doc["rows"]:
        problems.append("no benchmark rows recorded")
    for i, r in enumerate(doc.get("rows", [])):
        if not isinstance(r, dict) or \
                not {"section", "name", "us", "derived"} <= set(r):
            problems.append(f"row {i} missing keys: {r}")
        elif not (isinstance(r["name"], str) and isinstance(r["section"], str)
                  and isinstance(r["us"], numbers.Real)
                  and isinstance(r["derived"], str)):
            problems.append(f"row {i} has wrong types: {r}")
    names = {r.get("name") for r in doc.get("rows", [])}
    for required in ("runtime/replication_pipelined", "runtime/serve_staged_ttft",
                     "fig18/staged_engine_ttft",
                     "train/ckpt_soc_busy", "train/ckpt_host_busy",
                     "train/ckpt_soc_idle", "train/ckpt_host_idle",
                     "train/straggler_mitigated", "train/elastic_detect",
                     "colocation/serve_solo_p99",
                     "colocation/serve_unmanaged_p99",
                     "colocation/serve_managed_p99",
                     "colocation/train_solo", "colocation/train_unmanaged",
                     "colocation/train_managed",
                     "offload/ckpt_soc_compress_idle",
                     "offload/ckpt_host_compress_idle",
                     "offload/ckpt_soc_compress_busy",
                     "offload/ckpt_host_compress_busy",
                     "offload/cycles_saved",
                     "offload/kvfilter_host_busy",
                     "offload/kvfilter_soc_busy"):
        if required not in names:
            problems.append(f"required row {required!r} missing")
if problems:
    sys.exit("BENCH_6.json schema-invalid:\n  " + "\n  ".join(problems))
print(f"BENCH_6.json OK ({len(doc['rows'])} rows)")
EOF

if [[ "${1:-}" == "--bench" ]]; then
    PYTHONPATH=src:. python benchmarks/run.py --json "BENCH_$(date +%Y%m%d).json"
fi
