#!/usr/bin/env bash
# Tier-1 gate: the full test suite, exactly as ROADMAP.md specifies,
# plus the runtime/train/colocation/kvserve/offload/scale/simcore
# benchmark sections with schema-validated JSON output (BENCH_10.json —
# the PR-10 perf trajectory record), a trajectory check that the PR-9
# headline rows recorded in the committed BENCH_9.json have not
# regressed past tolerance, a simulator-speed floor (the event core
# must stay >= 334 events/s on the fleet scenario), the bucketed DDP
# overlap-win floor (K=4 must beat single-shot allreduce by >= 20% on
# the comm-bound headline config), and the tracer-overhead gate: the
# event loop with a NullTracer bound must stay within 10% of the
# untraced row (the hook sites are a cached-bool branch; tracing off
# must cost nothing).
#   scripts/ci.sh            # tests + runtime,...,offload,scale,simcore
#   scripts/ci.sh --bench    # also run the full benchmark driver
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

PYTHONPATH=src:. python benchmarks/run.py --json BENCH_10.json \
    --only runtime,train,colocation,kvserve,offload,scale,simcore

# fail on schema-invalid benchmark output
PYTHONPATH=src python - <<'EOF'
import json, numbers, sys

with open("BENCH_10.json") as f:
    doc = json.load(f)
problems = []
if not isinstance(doc, dict) or set(doc) != {"rows", "failures"}:
    problems.append(f"top level must be {{rows, failures}}, got {type(doc)}")
else:
    if doc["failures"]:
        problems.append(f"failed sections: {doc['failures']}")
    if not doc["rows"]:
        problems.append("no benchmark rows recorded")
    for i, r in enumerate(doc.get("rows", [])):
        if not isinstance(r, dict) or \
                not {"section", "name", "us", "derived"} <= set(r):
            problems.append(f"row {i} missing keys: {r}")
        elif not (isinstance(r["name"], str) and isinstance(r["section"], str)
                  and isinstance(r["us"], numbers.Real)
                  and isinstance(r["derived"], str)):
            problems.append(f"row {i} has wrong types: {r}")
    names = {r.get("name") for r in doc.get("rows", [])}
    for required in ("runtime/replication_pipelined", "runtime/serve_staged_ttft",
                     "fig18/staged_engine_ttft",
                     "train/ckpt_soc_busy", "train/ckpt_host_busy",
                     "train/ckpt_soc_idle", "train/ckpt_host_idle",
                     "train/straggler_mitigated", "train/elastic_detect",
                     "train/bucketed_k1", "train/bucketed_k2",
                     "train/bucketed_k4", "train/bucketed_k8",
                     "train/bucketed_pods_thin",
                     "colocation/serve_solo_p99",
                     "colocation/serve_unmanaged_p99",
                     "colocation/serve_managed_p99",
                     "colocation/train_solo", "colocation/train_unmanaged",
                     "colocation/train_managed",
                     "offload/ckpt_soc_compress_idle",
                     "offload/ckpt_host_compress_idle",
                     "offload/ckpt_soc_compress_busy",
                     "offload/ckpt_host_compress_busy",
                     "offload/cycles_saved",
                     "offload/kvfilter_host_busy",
                     "offload/kvfilter_soc_busy",
                     "scale/attainment_static",
                     "scale/attainment_autoscaled",
                     "scale/runtime_events_per_s",
                     "scale/runtime_events_per_s_nulltracer",
                     "simcore/transfers_1000",
                     "simcore/transfers_10000",
                     "simcore/incremental_vs_global",
                     "simcore/multipod_trunk_thin",
                     "simcore/multipod_trunk_fat"):
        if required not in names:
            problems.append(f"required row {required!r} missing")
if problems:
    sys.exit("BENCH_10.json schema-invalid:\n  " + "\n  ".join(problems))
print(f"BENCH_10.json OK ({len(doc['rows'])} rows)")
EOF

# trajectory check: PR-9 headline rows must stay within tolerance of
# the committed BENCH_9.json, the offload winner must still be
# soc-compress, the event core must not regress below the 334 events/s
# floor on the fleet scenario, bucketed DDP overlap (K=4) must keep
# >= 20% win over single-shot allreduce, and the NullTracer event loop
# must stay within 10% of the untraced one.  (Deterministic simulated
# timings, so 25% is generous — it only catches genuine model changes,
# not jitter.  The events/s floor is wall-clock, set ~10x below the
# post-rework speed so machine noise can't trip it.)
PYTHONPATH=src python - <<'EOF'
import json, re, sys

TOL = 0.25
EVENTS_PER_S_FLOOR = 334.0  # BENCH_7's scale/runtime_events_per_s
OVERLAP_WIN_FLOOR = 20.0    # % win of train/bucketed_k4 over k1
TRACER_OVERHEAD = 0.10      # NullTracer ev/s within 10% of untraced
HEADLINES = ("runtime/overlapped_pair", "colocation/serve_managed_p99",
             "offload/ckpt_soc_compress_busy", "offload/ckpt_host_compress_busy")

def by_name(path):
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)["rows"]}

old, new = by_name("BENCH_9.json"), by_name("BENCH_10.json")
problems = []
for name in HEADLINES:
    if name not in old:
        problems.append(f"baseline BENCH_9.json missing {name!r}")
        continue
    if name not in new:
        problems.append(f"BENCH_10.json missing {name!r}")
        continue
    o, n = old[name]["us"], new[name]["us"]
    drift = abs(n - o) / o
    status = "FAIL" if drift > TOL else "ok"
    print(f"  {name}: {o:,.1f}us -> {n:,.1f}us ({drift:+.1%}) {status}")
    if drift > TOL:
        problems.append(f"{name} drifted {drift:.1%} (>{TOL:.0%}): "
                        f"{o:,.1f}us -> {n:,.1f}us")
soc = new.get("offload/ckpt_soc_compress_busy", {}).get("us")
host = new.get("offload/ckpt_host_compress_busy", {}).get("us")
if soc is not None and host is not None and soc >= host:
    problems.append(f"offload winner flipped: soc-compress {soc:,.1f}us "
                    f">= host-compress {host:,.1f}us")

def events_per_s(name):
    evrow = new.get(name, {})
    m = re.search(r"events_per_s=([\d,]+)", evrow.get("derived", ""))
    if m is None:
        problems.append(f"{name} has no events_per_s= in derived: "
                        f"{evrow.get('derived')!r}")
        return None
    return float(m.group(1).replace(",", ""))

ev_s = events_per_s("scale/runtime_events_per_s")
if ev_s is not None:
    status = "FAIL" if ev_s < EVENTS_PER_S_FLOOR else "ok"
    print(f"  scale/runtime_events_per_s: {ev_s:,.0f} ev/s "
          f"(floor {EVENTS_PER_S_FLOOR:,.0f}) {status}")
    if ev_s < EVENTS_PER_S_FLOOR:
        problems.append(f"event core regressed: {ev_s:,.0f} events/s "
                        f"< floor {EVENTS_PER_S_FLOOR:,.0f}")
nt_s = events_per_s("scale/runtime_events_per_s_nulltracer")
if ev_s is not None and nt_s is not None:
    floor = (1.0 - TRACER_OVERHEAD) * ev_s
    status = "FAIL" if nt_s < floor else "ok"
    print(f"  scale/runtime_events_per_s_nulltracer: {nt_s:,.0f} ev/s "
          f"(>= {floor:,.0f}, 90% of untraced) {status}")
    if nt_s < floor:
        problems.append(f"tracing-off overhead: NullTracer {nt_s:,.0f} ev/s "
                        f"< {floor:,.0f} (90% of untraced {ev_s:,.0f})")
k4 = new.get("train/bucketed_k4", {})
m = re.search(r"win=([\d.]+)%", k4.get("derived", ""))
if m is None:
    problems.append("train/bucketed_k4 has no win= in derived: "
                    f"{k4.get('derived')!r}")
else:
    win = float(m.group(1))
    status = "FAIL" if win < OVERLAP_WIN_FLOOR else "ok"
    print(f"  train/bucketed_k4: overlap win {win:.1f}% "
          f"(floor {OVERLAP_WIN_FLOOR:.0f}%) {status}")
    if win < OVERLAP_WIN_FLOOR:
        problems.append(f"bucketed overlap win {win:.1f}% "
                        f"< floor {OVERLAP_WIN_FLOOR:.0f}%")
if problems:
    sys.exit("BENCH_9 -> BENCH_10 trajectory check failed:\n  "
             + "\n  ".join(problems))
print("trajectory check OK (PR-9 headline rows within "
      f"{TOL:.0%}, offload winner still soc-compress, event core above "
      f"{EVENTS_PER_S_FLOOR:,.0f} ev/s, NullTracer within "
      f"{TRACER_OVERHEAD:.0%} of untraced, bucketed overlap win above "
      f"{OVERLAP_WIN_FLOOR:.0f}%)")
EOF

if [[ "${1:-}" == "--bench" ]]; then
    PYTHONPATH=src:. python benchmarks/run.py --json "BENCH_$(date +%Y%m%d).json"
fi
